//! The end-to-end validation driver recorded in EXPERIMENTS.md.
//!
//! Trains the paper's full recipe — d=128, 16 epochs, CG solver, mixed
//! bf16/f32 precision, dense batching — on a synthetic WebGraph-in-dense
//! at 1% scale (~5000 nodes, ~6×10^5 model parameters) over an 8-core
//! simulated slice, logging the loss curve, per-epoch wall time, collective
//! traffic and final Recall@20/@50. With `--engine xla` the solve stage
//! runs through the AOT PJRT artifacts instead of the native engine,
//! proving all three layers compose on a real workload.
//!
//! ```bash
//! cargo run --release --example webgraph_e2e            # native engine
//! cargo run --release --example webgraph_e2e -- --engine xla
//! cargo run --release --example webgraph_e2e -- --scale 0.005   # quicker
//! ```

use alx::als::TrainConfig;
use alx::config::AlxConfig;
use alx::coordinator::Coordinator;
use alx::linalg::SolverKind;
use alx::util::stats::human_bytes;
use alx::webgraph::Variant;

fn arg(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.windows(2)
        .find(|w| w[0] == format!("--{name}"))
        .map(|w| w[1].clone())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let engine: String = arg("engine", "native");
    let scale: f64 = arg("scale", "0.01").parse()?;
    let epochs: usize = arg("epochs", "16").parse()?;
    // The production artifact shape is (cg, d=128, B=256, L=16) — large
    // batches pack many segments per solve (see aot.py).
    let dim: usize = arg("dim", "128").parse()?;

    let cfg = AlxConfig {
        variant: Variant::InDense,
        scale,
        cores: 8,
        engine: engine.clone(),
        train: TrainConfig {
            dim,
            epochs,
            lambda: 0.05,
            alpha: 0.005,
            solver: SolverKind::Cg,
            batch_rows: 256,
            batch_width: 16,
            compute_objective: true,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };

    println!("=== ALX end-to-end: WebGraph-in-dense @ scale {scale}, engine {engine} ===");
    let mut coord = Coordinator::prepare(cfg)?;
    let params = (coord.graph.nodes() * 2 * dim) as u64;
    println!(
        "graph: {} nodes / {} edges / locality {:.1}%  |  model: {} parameters",
        coord.graph.nodes(),
        coord.graph.edges(),
        100.0 * coord.graph.locality(),
        alx::util::stats::human_count(params),
    );

    let report = coord.run()?;

    println!("\nloss curve (training objective, Eq. 3):");
    println!("{:>5} {:>16} {:>9} {:>12} {:>12}", "epoch", "objective", "wall(s)", "sim-TPU(s)", "comm");
    for h in &report.history {
        println!(
            "{:>5} {:>16.2} {:>9.2} {:>12.2} {:>12}",
            h.epoch,
            h.objective.unwrap_or(f64::NAN),
            h.seconds,
            h.simulated_seconds,
            human_bytes(h.comm_bytes)
        );
    }
    println!("\nstrong-generalization eval ({} held-out rows):", coord.test.len());
    for r in &report.recalls {
        println!("  Recall@{:<3} = {:.4}", r.k, r.recall);
    }
    println!("\nprofiler:\n{}", coord.trainer.profiler.report());
    Ok(())
}
