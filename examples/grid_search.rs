//! Hyper-parameter grid search (paper §6.1): sweep (λ, α) on a WebGraph
//! variant and print the grid best-first — the procedure behind every
//! Table 2 row ("hyperparameter tuning over both λ and α has been
//! indispensable for good results").
//!
//! ```bash
//! cargo run --release --example grid_search                 # coarse 3×3
//! cargo run --release --example grid_search -- --full      # paper 6×7
//! ```

use alx::als::TrainConfig;
use alx::config::AlxConfig;
use alx::coordinator::{grid_search, GridSpec};
use alx::webgraph::Variant;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let base = AlxConfig {
        variant: Variant::InDense,
        scale: 0.0015,
        cores: 4,
        train: TrainConfig {
            dim: 32,
            epochs: 6,
            batch_rows: 64,
            batch_width: 8,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };
    let spec = if full {
        // The paper's exact §6.1 grids (42 cells — minutes at this scale).
        GridSpec::default()
    } else {
        GridSpec::coarse()
    };
    println!(
        "grid search on {} ({} λ × {} α = {} cells)",
        base.variant.name(),
        spec.lambdas.len(),
        spec.alphas.len(),
        spec.lambdas.len() * spec.alphas.len()
    );
    let points = grid_search(&base, &spec)?;
    println!("\n{:>10} {:>10} {:>9} {:>9}", "lambda", "alpha", "R@20", "R@50");
    for p in &points {
        println!(
            "{:>10.0e} {:>10.0e} {:>9.3} {:>9.3}",
            p.lambda, p.alpha, p.recall_at_20, p.recall_at_50
        );
    }
    let best = &points[0];
    println!(
        "\nbest cell: λ={:.0e} α={:.0e} → Recall@20={:.3} (a Table 2 row)",
        best.lambda, best.alpha, best.recall_at_20
    );
    Ok(())
}
