//! Quickstart: factorize a small synthetic WebGraph with the session API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release --example quickstart -- --scale 0.0008 --epochs 3  # CI-sized
//! ```

use alx::prelude::*;

fn main() -> anyhow::Result<()> {
    // Optional overrides so CI can run this at a tiny scale.
    let mut scale = 0.002; // ~1000 nodes of the paper's 0.5M-node variant
    let mut epochs = 8usize;
    let argv: Vec<String> = std::env::args().skip(1).collect();
    for pair in argv.chunks(2) {
        match (pair[0].as_str(), pair.get(1)) {
            ("--scale", Some(v)) => scale = v.parse()?,
            ("--epochs", Some(v)) => epochs = v.parse()?,
            ("--scale" | "--epochs", None) => anyhow::bail!("{} needs a value", pair[0]),
            (flag, _) => anyhow::bail!("unknown flag {flag} (expected --scale/--epochs)"),
        }
    }

    // 1. Describe the job: which dataset, how big, how many simulated
    //    TPU cores, and the iALS hyper-parameters. The `[data]` section
    //    (here: the default synthetic WebGraph source) decides where the
    //    matrix comes from; `--source edge-list --data edges.txt` would
    //    train on a file instead.
    let cfg = AlxConfig {
        variant: Variant::InDense,
        scale,
        cores: 8,
        train: TrainConfig {
            dim: 32,
            epochs,
            lambda: 0.05,
            alpha: 0.005,
            batch_rows: 64,
            batch_width: 8,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };

    // 2. The session loads the dataset, makes the strong-generalization
    //    split, checks HBM capacity and builds the trainer.
    let mut session = TrainSession::from_config(cfg.clone())?;
    println!(
        "dataset {}: {}x{}, {} edges ({} test rows)",
        session.dataset.name,
        session.dataset.rows,
        session.dataset.cols,
        session.dataset.nnz,
        session.test.len()
    );

    // 3. Step through training one epoch at a time — the session is in
    //    control between epochs (hooks, checkpoints, early exit).
    while session.remaining_epochs() > 0 {
        let stats = session.step()?;
        println!(
            "epoch {:>2}: objective {:>12.2}  ({:.2}s wall)",
            stats.epoch,
            stats.objective.unwrap_or(f64::NAN),
            stats.seconds
        );
    }
    for r in session.evaluate()? {
        println!("Recall@{} = {:.3}", r.k, r.recall);
    }

    // 4. Checkpoint, then resume into a fresh session — the resumed
    //    trainer continues from the same epoch with bitwise-identical
    //    tables (the `session_resume` integration test proves it).
    let ckpt = std::env::temp_dir().join("alx_quickstart.ckpt");
    session.checkpoint(&ckpt)?;
    let resumed = TrainSession::resume(&ckpt, cfg)?;
    println!(
        "resumed from {}: epoch {}, {} epochs remaining",
        ckpt.display(),
        resumed.trainer.current_epoch(),
        resumed.remaining_epochs()
    );
    std::fs::remove_file(&ckpt)?;
    Ok(())
}
