//! Quickstart: factorize a small synthetic WebGraph in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use alx::als::TrainConfig;
use alx::config::AlxConfig;
use alx::coordinator::Coordinator;
use alx::webgraph::Variant;

fn main() -> anyhow::Result<()> {
    // 1. Describe the job: which dataset, how big, how many simulated
    //    TPU cores, and the iALS hyper-parameters.
    let cfg = AlxConfig {
        variant: Variant::InDense,
        scale: 0.002, // ~1000 nodes of the paper's 0.5M-node variant
        cores: 8,
        train: TrainConfig {
            dim: 32,
            epochs: 8,
            lambda: 0.05,
            alpha: 0.005,
            batch_rows: 64,
            batch_width: 8,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };

    // 2. The coordinator generates the graph, makes the strong-
    //    generalization split, checks HBM capacity and builds the trainer.
    let mut coord = Coordinator::prepare(cfg)?;
    println!(
        "dataset: {} nodes, {} edges ({} test rows)",
        coord.graph.nodes(),
        coord.graph.edges(),
        coord.split.test.len()
    );

    // 3. Train and evaluate.
    let report = coord.run()?;
    for h in &report.history {
        println!(
            "epoch {:>2}: objective {:>12.2}  ({:.2}s wall)",
            h.epoch,
            h.objective.unwrap_or(f64::NAN),
            h.seconds
        );
    }
    for r in &report.recalls {
        println!("Recall@{} = {:.3}", r.k, r.recall);
    }
    Ok(())
}
