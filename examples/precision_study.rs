//! Precision study (paper §4.4 / Figure 4): train the same model under
//! f32, mixed (bf16 tables + f32 solves — the paper's recommendation) and
//! naive bf16 end-to-end, at a low regularization constant, and watch the
//! naive-bf16 run collapse mid-training.
//!
//! ```bash
//! cargo run --release --example precision_study
//! cargo run --release --example precision_study -- --lambda 5e-2  # stable regime
//! ```

use alx::harness;
use alx::webgraph::Variant;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let lambda: f32 = argv
        .windows(2)
        .find(|w| w[0] == "--lambda")
        .map(|w| w[1].parse())
        .transpose()?
        .unwrap_or(1e-4);

    println!("=== Figure 4 reproduction: precision policies at λ={lambda:.0e} ===");
    let series = harness::run_fig4(Variant::InDense, 0.002, 10, 32, lambda, 4, 7)?;
    harness::print_fig4(&series);

    println!("\ntraining objective by epoch (NaN/explosion = collapse):");
    print!("{:<8}", "epoch");
    for s in &series {
        print!("{:>16}", s.precision.name());
    }
    println!();
    for e in 0..series[0].objective_by_epoch.len() {
        print!("{:<8}", e + 1);
        for s in &series {
            print!("{:>16.3e}", s.objective_by_epoch[e]);
        }
        println!();
    }

    let final_of = |name: &str| {
        series
            .iter()
            .find(|s| s.precision.name() == name)
            .and_then(|s| s.recall_by_epoch.last().copied())
            .unwrap_or(0.0)
    };
    println!(
        "\nfinal recall@20: f32={:.3} mixed={:.3} naive-bf16={:.3}",
        final_of("f32"),
        final_of("mixed"),
        final_of("naive-bf16")
    );
    println!("(paper Fig. 4: naive bf16 collapses; mixed matches f32 at half the memory)");
    Ok(())
}
