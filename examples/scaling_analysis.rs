//! Scaling analysis (paper §6.2 / Figure 6): epoch time vs TPU core count.
//!
//! Two parts:
//!  1. The calibrated analytic model at **paper scale** for the four big
//!     variants — reproduces Fig. 6's linear-then-flat curves and the
//!     HBM floors (WebGraph-sparse needs ≥32 cores to start).
//!  2. A **measured** sweep on the real (simulated-shard) runtime at small
//!     scale, verifying the collective byte accounting grows the way the
//!     model assumes.
//!
//! ```bash
//! cargo run --release --example scaling_analysis
//! ```

use alx::als::{TrainConfig, Trainer};
use alx::harness;
use alx::sparse::split_strong_generalization;
use alx::topo::Topology;
use alx::util::stats::human_bytes;
use alx::webgraph::{generate, Variant, VariantSpec};

fn main() -> anyhow::Result<()> {
    // --- Part 1: paper-scale model (Fig. 6 proper) ----------------------
    let cores = [8, 16, 32, 64, 128, 256, 512, 1024, 2048];
    let variants = [Variant::Sparse, Variant::Dense, Variant::DeSparse, Variant::DeDense];
    let points = harness::run_fig6(&variants, &cores, 128);
    harness::print_fig6(&points);

    // Speedup table: where does each variant stop scaling linearly?
    println!("\nparallel efficiency vs 2x cores (1.0 = perfectly linear):");
    for v in variants {
        print!("{:<22}", v.name());
        for w in cores.windows(2) {
            let a = points.iter().find(|p| p.variant == v && p.cores == w[0]);
            let b = points.iter().find(|p| p.variant == v && p.cores == w[1]);
            match (a, b) {
                (Some(a), Some(b)) if a.feasible && b.feasible => {
                    print!("{:>8.2}", a.epoch_seconds / b.epoch_seconds / 2.0);
                }
                _ => print!("{:>8}", "-"),
            }
        }
        println!();
    }

    // --- Part 2: measured small-scale sweep -----------------------------
    println!("\nmeasured epoch wall time + collective traffic (in-dense @ 0.002):");
    let spec = VariantSpec::preset(Variant::InDense).scaled(0.002);
    let graph = generate(&spec, 7);
    let split = split_strong_generalization(&graph.adjacency, 0.9, 0.25, 9);
    println!("{:>6} {:>10} {:>14} {:>14}", "cores", "wall(s)", "comm/epoch", "sim-TPU(s)");
    for m in [1usize, 2, 4, 8, 16] {
        let cfg = TrainConfig {
            dim: 32,
            epochs: 1,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: false,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&split.train, cfg, Topology::new(m))?;
        let stats = tr.run_epoch()?;
        println!(
            "{:>6} {:>10.3} {:>14} {:>14.2}",
            m,
            stats.seconds,
            human_bytes(stats.comm_bytes),
            stats.simulated_seconds
        );
    }
    Ok(())
}
