//! Solver comparison (paper §4.5 / Figure 5): one training epoch per
//! solver across embedding dimensions, on both engines when artifacts are
//! available.
//!
//! On the paper's TPU, CG wins at large d because its inner loop is pure
//! MXU mat-vec work; on this CPU substrate the exact ordering differs
//! (documented in EXPERIMENTS.md), but the harness regenerates the same
//! series the figure plots.
//!
//! ```bash
//! cargo run --release --example solver_comparison
//! cargo run --release --example solver_comparison -- --engine xla
//! ```

use alx::harness;
use alx::linalg::SolverKind;
use alx::runtime::XlaEngine;
use alx::webgraph::Variant;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "xla")
        || std::env::args().collect::<Vec<_>>().windows(2).any(|w| w[0] == "--engine" && w[1] == "xla");

    let dims: Vec<usize> = if use_xla {
        vec![16, 32, 64, 128] // the compiled artifact grid
    } else {
        vec![16, 32, 64, 128]
    };

    let points = if use_xla {
        let mut builder = |solver: SolverKind, d: usize| -> anyhow::Result<Box<dyn alx::als::SolveEngine>> {
            Ok(Box::new(XlaEngine::new("artifacts", solver.name(), d, 64, 8)?))
        };
        harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, Some(&mut builder))?
    } else {
        harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, None)?
    };
    println!("engine: {}", if use_xla { "xla (AOT PJRT)" } else { "native" });
    harness::print_fig5(&points);

    // The paper's headline observation, restated for this run:
    let d_max = *dims.last().unwrap();
    let at = |s: SolverKind| {
        points
            .iter()
            .find(|p| p.solver == s && p.dim == d_max)
            .map(|p| p.epoch_seconds)
            .unwrap_or(f64::NAN)
    };
    println!(
        "\nat d={d_max}: cg={:.2}s cholesky={:.2}s lu={:.2}s qr={:.2}s",
        at(SolverKind::Cg),
        at(SolverKind::Cholesky),
        at(SolverKind::Lu),
        at(SolverKind::Qr)
    );
    Ok(())
}
