"""Scoring kernel vs reference (Top-K retrieval path, paper §4.6)."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import scoring


def rand(key, shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


class TestScores:
    @pytest.mark.parametrize("q,n,d,t", [(4, 100, 8, 32), (16, 512, 16, 128), (1, 7, 3, 8)])
    def test_matches_reference(self, q, n, d, t):
        k1, k2 = jax.random.split(jax.random.PRNGKey(q * n + d))
        qm, hm = rand(k1, (q, d)), rand(k2, (n, d))
        got = scoring.scores(qm, hm, tile_items=t)
        np.testing.assert_allclose(got, scoring.scores_ref(qm, hm), rtol=1e-4, atol=1e-4)

    def test_padding_does_not_leak(self):
        # n not divisible by the tile: padded items must not appear.
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        qm, hm = rand(k1, (2, 4)), rand(k2, (10, 4))
        got = scoring.scores(qm, hm, tile_items=8)
        assert got.shape == (2, 10)
        np.testing.assert_allclose(got, qm @ hm.T, rtol=1e-5, atol=1e-5)

    def test_topk_order_preserved(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        qm, hm = rand(k1, (3, 16)), rand(k2, (200, 16))
        got = scoring.scores(qm, hm, tile_items=64)
        want = scoring.scores_ref(qm, hm)
        np.testing.assert_array_equal(
            jnp.argsort(got, axis=1)[:, -20:], jnp.argsort(want, axis=1)[:, -20:]
        )

    @settings(deadline=None, max_examples=15)
    @given(q=st.integers(1, 8), n=st.integers(1, 200), d=st.integers(1, 32), seed=st.integers(0, 10**6))
    def test_property_random_shapes(self, q, n, d, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        qm, hm = rand(k1, (q, d)), rand(k2, (n, d))
        got = scoring.scores(qm, hm, tile_items=64)
        np.testing.assert_allclose(got, qm @ hm.T, rtol=1e-3, atol=1e-3)

    def test_vmem_budget(self):
        # Production shape must sit far under a v3 core's 16 MiB VMEM.
        assert scoring.vmem_bytes(64, 512, 128) < 1 << 20
