"""L1 Pallas kernels vs the pure-jnp reference — the core build-time
correctness signal, including hypothesis sweeps over shapes and values."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import als_stats, gramian, ref

hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
hypothesis.settings.load_profile("ci")


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


class TestBatchStats:
    @pytest.mark.parametrize("b,l,d", [(1, 1, 1), (2, 4, 3), (8, 8, 16), (16, 16, 32)])
    def test_matches_reference(self, b, l, d):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * 100 + l * 10 + d), 3)
        h = rand(k1, (b, l, d))
        y = rand(k2, (b, l))
        mask = (jax.random.uniform(k3, (b, l)) > 0.3).astype(jnp.float32)
        g, bv = als_stats.batch_stats(h, y, mask)
        g_ref, bv_ref = ref.batch_stats_ref(h, y, mask)
        np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(bv, bv_ref, rtol=1e-5, atol=1e-5)

    def test_full_mask_equals_unmasked_einsum(self):
        k = jax.random.PRNGKey(0)
        h = rand(k, (4, 8, 8))
        y = jnp.ones((4, 8), jnp.float32)
        mask = jnp.ones((4, 8), jnp.float32)
        g, bv = als_stats.batch_stats(h, y, mask)
        np.testing.assert_allclose(g, jnp.einsum("bli,blj->bij", h, h), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(bv, h.sum(axis=1), rtol=1e-5, atol=1e-5)

    def test_zero_mask_zeroes_stats(self):
        k = jax.random.PRNGKey(1)
        h = rand(k, (3, 4, 5))
        y = rand(k, (3, 4))
        g, bv = als_stats.batch_stats(h, y, jnp.zeros((3, 4), jnp.float32))
        assert float(jnp.abs(g).max()) == 0.0
        assert float(jnp.abs(bv).max()) == 0.0

    def test_gramians_are_symmetric_psd(self):
        k = jax.random.PRNGKey(2)
        h = rand(k, (4, 8, 6))
        mask = jnp.ones((4, 8), jnp.float32)
        g, _ = als_stats.batch_stats(h, jnp.ones((4, 8), jnp.float32), mask)
        np.testing.assert_allclose(g, jnp.swapaxes(g, 1, 2), rtol=1e-6, atol=1e-6)
        eigs = jnp.linalg.eigvalsh(g)
        assert float(eigs.min()) > -1e-4

    @given(
        b=st.integers(1, 8),
        l=st.integers(1, 16),
        d=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_random_shapes(self, b, l, d, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        h = rand(k1, (b, l, d), 2.0)
        y = rand(k2, (b, l), 3.0)
        mask = (jax.random.uniform(k3, (b, l)) > 0.5).astype(jnp.float32)
        g, bv = als_stats.batch_stats(h, y, mask)
        g_ref, bv_ref = ref.batch_stats_ref(h, y, mask)
        np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(bv, bv_ref, rtol=1e-4, atol=1e-4)


class TestGramianKernel:
    @pytest.mark.parametrize("n,d,t", [(256, 8, 256), (512, 16, 256), (100, 4, 32)])
    def test_matches_reference(self, n, d, t):
        x = rand(jax.random.PRNGKey(n + d), (n, d))
        got = gramian.gramian(x, tile_rows=t)
        np.testing.assert_allclose(got, ref.gramian_ref(x), rtol=1e-4, atol=1e-4)

    def test_padding_path_exact(self):
        # 100 rows with tile 32 → pads 28 zero rows; result must be exact.
        x = rand(jax.random.PRNGKey(9), (100, 4))
        got = gramian.gramian(x, tile_rows=32)
        np.testing.assert_allclose(got, x.T @ x, rtol=1e-5, atol=1e-5)

    @given(n=st.integers(1, 300), d=st.integers(1, 16), seed=st.integers(0, 10**6))
    def test_property_random_shapes(self, n, d, seed):
        x = rand(jax.random.PRNGKey(seed), (n, d))
        got = gramian.gramian(x, tile_rows=64)
        np.testing.assert_allclose(got, ref.gramian_ref(x), rtol=1e-3, atol=1e-3)


class TestVmemEstimates:
    def test_stats_kernel_fits_vmem(self):
        # Paper shapes: L = 16, d = 128 must fit far under 16 MiB.
        assert als_stats.vmem_bytes(16, 128) < 1 << 20
        assert als_stats.vmem_bytes(16, 512) < 16 << 20

    def test_gramian_tile_fits_vmem(self):
        assert gramian.vmem_bytes(256, 128) < 1 << 20

    def test_mxu_estimate_monotone_in_d(self):
        assert als_stats.mxu_utilization_estimate(16, 128) >= als_stats.mxu_utilization_estimate(16, 64)
        assert 0.0 < als_stats.mxu_utilization_estimate(16, 128) <= 1.0
