"""L2 solvers and solve_step vs the LAPACK-backed reference."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import model
from compile.kernels import ref


def random_problem(key, b, l, d, s=None):
    s = s or b
    k = jax.random.split(key, 5)
    h = jax.random.normal(k[0], (b, l, d), jnp.float32)
    y = jax.random.normal(k[1], (b, l), jnp.float32)
    mask = (jax.random.uniform(k[2], (b, l)) > 0.25).astype(jnp.float32)
    # Random segment assignment: dense row i -> segment (i % s).
    seg = jnp.arange(b) % s
    onehot = jax.nn.one_hot(seg, s, dtype=jnp.float32)
    hh = jax.random.normal(k[3], (4 * d, d), jnp.float32)
    gram = hh.T @ hh / (4 * d)
    return h, y, mask, onehot, gram


class TestSolvers:
    @pytest.mark.parametrize("solver", model.SOLVERS)
    @pytest.mark.parametrize("d", [1, 2, 8, 24])
    def test_matches_lapack_reference(self, solver, d):
        args = random_problem(jax.random.PRNGKey(d), b=8, l=4, d=d)
        lam, alpha = jnp.float32(0.5), jnp.float32(0.1)
        got = model.solve_step(solver, *args, lam, alpha)
        want = ref.solve_step_ref(*args, lam, alpha)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)

    @pytest.mark.parametrize("solver", model.SOLVERS)
    def test_residual_small(self, solver):
        d = 16
        args = random_problem(jax.random.PRNGKey(7), b=8, l=8, d=d)
        lam, alpha = jnp.float32(0.3), jnp.float32(0.05)
        a, c = model.segment_stats(*args, lam, alpha)
        x = model.solve_step(solver, *args, lam, alpha)
        resid = jnp.einsum("sij,sj->si", a, x) - c
        rel = jnp.linalg.norm(resid) / jnp.linalg.norm(c)
        assert float(rel) < 2e-3, f"{solver}: rel residual {rel}"

    def test_pure_regularizer_segments(self):
        # A segment with no valid slots must solve (alpha*G + lam*I) w = 0 → 0.
        b, l, d = 4, 2, 3
        h = jnp.ones((b, l, d), jnp.float32)
        y = jnp.ones((b, l), jnp.float32)
        mask = jnp.zeros((b, l), jnp.float32)
        onehot = jnp.eye(b, dtype=jnp.float32)
        gram = jnp.eye(d, dtype=jnp.float32)
        w = model.solve_step("cholesky", h, y, mask, onehot, gram, jnp.float32(1.0), jnp.float32(1.0))
        np.testing.assert_allclose(w, jnp.zeros((b, d)), atol=1e-6)

    def test_known_tiny_system(self):
        # Single segment, identity-ish design: (I + 0.5 I) w = [1, 1] → 2/3.
        h = jnp.array([[[1.0, 0.0], [0.0, 1.0]]], jnp.float32)  # (1, 2, 2)
        y = jnp.ones((1, 2), jnp.float32)
        mask = jnp.ones((1, 2), jnp.float32)
        onehot = jnp.ones((1, 1), jnp.float32)
        gram = jnp.zeros((2, 2), jnp.float32)
        for solver in model.SOLVERS:
            w = model.solve_step(solver, h, y, mask, onehot, gram, jnp.float32(0.5), jnp.float32(0.0))
            np.testing.assert_allclose(w, jnp.full((1, 2), 2.0 / 3.0), rtol=1e-4)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 10**6), d=st.integers(2, 12))
    def test_property_cg_equals_cholesky(self, seed, d):
        args = random_problem(jax.random.PRNGKey(seed), b=4, l=4, d=d)
        lam, alpha = jnp.float32(1.0), jnp.float32(0.2)
        cg = model.solve_step("cg", *args, lam, alpha)
        ch = model.solve_step("cholesky", *args, lam, alpha)
        np.testing.assert_allclose(cg, ch, rtol=5e-2, atol=5e-3)


class TestSegmentReduction:
    def test_multi_dense_row_segments_sum(self):
        # Two dense rows for one segment must equal one concatenated row.
        d = 4
        k = jax.random.PRNGKey(3)
        h = jax.random.normal(k, (2, 3, d), jnp.float32)
        y = jnp.ones((2, 3), jnp.float32)
        mask = jnp.ones((2, 3), jnp.float32)
        onehot = jnp.array([[1.0], [1.0]], jnp.float32)  # both rows → seg 0
        gram = jnp.zeros((d, d), jnp.float32)
        a2, c2 = model.segment_stats(h, y, mask, onehot, gram, jnp.float32(0.1), jnp.float32(0.0))

        h1 = h.reshape(1, 6, d)
        a1, c1 = model.segment_stats(
            h1, y.reshape(1, 6), mask.reshape(1, 6),
            jnp.ones((1, 1), jnp.float32), gram, jnp.float32(0.1), jnp.float32(0.0)
        )
        np.testing.assert_allclose(a2, a1, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c2, c1, rtol=1e-5, atol=1e-5)


class TestCgBudget:
    def test_budget_bounds(self):
        assert model.cg_iterations(2) == 8
        assert model.cg_iterations(16) == 32
        assert model.cg_iterations(128) == 40  # clamped (perf: see §Perf)
