"""AOT pipeline: lowered HLO must be custom-call-free and numerically
identical to the eager path (what rust will execute)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from tests.test_model import random_problem


class TestLowering:
    @pytest.mark.parametrize("solver", model.SOLVERS)
    def test_no_custom_calls(self, solver):
        text = aot.lower_solve(solver, d=4, b=4, l=2)
        assert "custom-call" not in text, f"{solver} lowers to a custom-call"
        assert "ENTRY" in text

    def test_hlo_text_stable_shapes(self):
        text = aot.lower_solve("cg", d=8, b=4, l=2)
        # The entry computation must mention the static parameter shapes.
        assert "f32[4,2,8]" in text  # h
        assert "f32[8,8]" in text    # gramian

    @pytest.mark.parametrize("solver", model.SOLVERS)
    def test_roundtrip_numerics_through_hlo(self, solver):
        """Compile the lowered StableHLO with jax's own runtime and compare
        against eager — catches lowering bugs without needing the rust side."""
        d, b, l = 6, 4, 3
        fn = model.make_solve_fn(solver)
        args = random_problem(jax.random.PRNGKey(11), b=b, l=l, d=d)
        lam, alpha = jnp.float32(0.4), jnp.float32(0.05)
        eager = fn(*args, lam, alpha)[0]
        compiled = jax.jit(fn)(*args, lam, alpha)[0]
        np.testing.assert_allclose(compiled, eager, rtol=1e-4, atol=1e-5)
