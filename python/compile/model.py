"""L2: the per-batch ALS compute graph (paper Algorithm 2, "Solve" stage).

`solve_step` consumes one dense batch — gathered embeddings, labels, mask,
segment one-hot — plus the global Gramian and hyper-parameters, and
returns the solved embeddings per segment. The sufficient statistics come
from the L1 Pallas kernel (`kernels.als_stats`); the segment reduction is
a one-hot matmul so every shape stays static (the paper's XLA constraint,
§4.3) and the contraction lands on the MXU.

All four §4.5 solvers are provided. IMPORTANT: the deployment target is
the rust PJRT bridge on xla_extension 0.5.1, which rejects typed-FFI
custom-calls — so `jnp.linalg.*` (LAPACK-backed on CPU) is off limits
here. Every solver below lowers to plain HLO ops (while/fori loops,
dynamic slices, dot-generals):

  * cholesky — left-looking column algorithm, one (D,D)@(D,) dot per step.
  * lu       — Gaussian elimination without pivoting (valid: the ALS
               normal matrix is SPD, where pivot-free LU is stable).
  * qr       — Householder reflections, two rank-1 updates per column.
  * cg       — fixed-iteration conjugate gradients; each iteration is one
               batched (S,D,D)@(S,D) mat-vec, the most MXU-friendly shape,
               which is why the paper finds CG fastest on TPU.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import als_stats

SOLVERS = ("cholesky", "lu", "qr", "cg")


# --------------------------------------------------------------- cholesky
def _cholesky_solve_one(a, b):
    """Solve a x = b for SPD a via a fori-loop Cholesky (plain HLO ops)."""
    d = a.shape[0]
    idx = jnp.arange(d)

    def chol_col(j, l):
        # Column j of L, left-looking: s = a[:, j] - L @ L[j, :]^T.
        lj = l[j]  # row j (cols < j populated)
        s = a[:, j] - l @ lj
        diag = jnp.sqrt(jnp.maximum(s[j], 0.0))
        col = jnp.where(idx > j, s / jnp.where(diag > 0, diag, 1.0), 0.0)
        col = col.at[j].set(diag)
        return l.at[:, j].set(col)

    l = jax.lax.fori_loop(0, d, chol_col, jnp.zeros_like(a))

    # Forward substitution L y = b.
    def fwd(i, y):
        yi = (b[i] - l[i] @ y) / l[i, i]
        return y.at[i].set(yi)

    y = jax.lax.fori_loop(0, d, fwd, jnp.zeros_like(b))

    # Backward substitution L^T x = y.
    lt = l.T

    def bwd(k, x):
        i = d - 1 - k
        xi = (y[i] - lt[i] @ x) / lt[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, d, bwd, jnp.zeros_like(b))


# --------------------------------------------------------------------- lu
def _lu_solve_one(a, b):
    """Gaussian elimination without pivoting (SPD-safe) + two substitutions."""
    d = a.shape[0]
    idx = jnp.arange(d)

    def elim(k, carry):
        l, u = carry
        pivot = u[k, k]
        m = jnp.where(idx > k, u[:, k] / jnp.where(pivot != 0, pivot, 1.0), 0.0)
        u = u - m[:, None] * u[k][None, :]
        l = l.at[:, k].add(m)
        return l, u

    l0 = jnp.eye(d, dtype=a.dtype)
    l, u = jax.lax.fori_loop(0, d, elim, (l0, a))

    def fwd(i, y):
        yi = b[i] - l[i] @ y  # l[i, i] == 1
        return y.at[i].set(yi)

    y = jax.lax.fori_loop(0, d, fwd, jnp.zeros_like(b))

    def bwd(k, x):
        i = d - 1 - k
        xi = (y[i] - u[i] @ x) / u[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, d, bwd, jnp.zeros_like(b))


# --------------------------------------------------------------------- qr
def _qr_solve_one(a, b):
    """Householder QR: reduce [A|b] to [R|Q^T b], back-substitute."""
    d = a.shape[0]
    idx = jnp.arange(d)

    def house(k, carry):
        r, qtb = carry
        x = jnp.where(idx >= k, r[:, k], 0.0)
        norm = jnp.sqrt(jnp.sum(x * x))
        sign = jnp.where(x[k] >= 0.0, 1.0, -1.0)
        alpha = -sign * norm
        v = x.at[k].add(-alpha)
        vsq = jnp.sum(v * v)
        vsq = jnp.where(vsq > 0, vsq, 1.0)
        # H = I - 2 v v^T / (v^T v), applied to R and qtb.
        r = r - (2.0 / vsq) * jnp.outer(v, v @ r)
        qtb = qtb - (2.0 / vsq) * v * (v @ qtb)
        return r, qtb

    r, qtb = jax.lax.fori_loop(0, d, house, (a, b))

    def bwd(k, x):
        i = d - 1 - k
        xi = (qtb[i] - r[i] @ x) / r[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, d, bwd, jnp.zeros_like(b))


# --------------------------------------------------------------------- cg
def cg_iterations(d: int) -> int:
    """Fixed CG budget (no early exit inside the static HLO graph).

    The regularized ALS normal equations are well conditioned; the native
    engine's early-stopping CG converges to 1e-4 relative residual in
    ~20-30 iterations at d=128 (EXPERIMENTS.md §Perf), so 40 is a safe
    static budget — cutting it from 96 sped the AOT hot path 2.2× with no
    measurable recall/objective change."""
    return int(min(max(2 * d, 8), 40))


def _cg_solve_batched(a, b, iters):
    """All-segments-at-once CG: every iteration is one (S,D,D)x(S,D)
    batched mat-vec — a single big dot-general that fills the MXU."""

    def matvec(p):
        return jnp.einsum("sij,sj->si", a, p)

    x0 = jnp.zeros_like(b)
    r0 = b
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=-1)

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        pap = jnp.sum(p * ap, axis=-1)
        alpha = rs / jnp.where(pap != 0.0, pap, 1.0)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * ap
        rs_new = jnp.sum(r * r, axis=-1)
        beta = rs_new / jnp.where(rs != 0.0, rs, 1.0)
        p = r + beta[:, None] * p
        return x, r, p, rs_new

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x0, r0, p0, rs0))
    return x


# ------------------------------------------------------------- solve step
def segment_stats(h, y, mask, onehot, gram, lam, alpha):
    """Per-segment normal equations from the L1 kernel's statistics."""
    g, bvec = als_stats.batch_stats(h, y, mask)
    d = h.shape[-1]
    a = jnp.einsum("bs,bij->sij", onehot, g)
    a = a + alpha * gram[None] + lam * jnp.eye(d, dtype=h.dtype)[None]
    c = jnp.einsum("bs,bi->si", onehot, bvec)
    return a, c


def solve_step(solver: str, h, y, mask, onehot, gram, lam, alpha):
    """One dense-batch ALS solve (Fig. 1 "Solve" stage).

    Args:
      solver: one of SOLVERS.
      h:      (B, L, D) gathered embeddings (f32 — the paper casts the
              bf16 tables up before solving, §4.4).
      y:      (B, L) labels.
      mask:   (B, L) slot validity.
      onehot: (B, S) dense-row→segment one-hot (S = B).
      gram:   (D, D) global Gramian.
      lam, alpha: scalars.

    Returns:
      (S, D) solved embeddings.
    """
    a, c = segment_stats(h, y, mask, onehot, gram, lam, alpha)
    if solver == "cg":
        return _cg_solve_batched(a, c, cg_iterations(h.shape[-1]))
    one = {"cholesky": _cholesky_solve_one, "lu": _lu_solve_one, "qr": _qr_solve_one}[solver]
    return jax.vmap(one)(a, c)


def make_solve_fn(solver: str):
    """A jit-able `f(h, y, mask, onehot, gram, lam, alpha) -> (w,)` whose
    output is a 1-tuple (the AOT pipeline lowers with return_tuple=True)."""

    @functools.wraps(solve_step)
    def fn(h, y, mask, onehot, gram, lam, alpha):
        return (solve_step(solver, h, y, mask, onehot, gram, lam, alpha),)

    return fn
