"""L1 Pallas kernel: per-dense-row sufficient statistics (the ALS hot-spot).

One ALS solve step needs, per dense row of the batch (paper Algorithm 2
lines 13-16):

    G_dr = sum_l mask[l] * h[l] (x) h[l]     in R^{D x D}
    b_dr = sum_l mask[l] * y[l] * h[l]       in R^{D}

This is O(B*L*D^2) work — the dominant statistics cost O(|S| d^2) of the
whole algorithm — and it is a pure contraction, so we express it as two
matmuls per dense row. On a real TPU each (L x D)^T @ (L x D) product maps
straight onto the MXU systolic array; `hm.T @ hm` is the exact analogue of
the paper's bfloat16 MAC pipeline.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid = (B,): one program per dense row — embarrassingly parallel,
    mirrors the paper's per-row `parfor`.
  * BlockSpec keeps one (L, D) tile of gathered embeddings in VMEM at a
    time: VMEM footprint = L*D + D*D + 2L floats (L=16, D=128 → ~73 KiB),
    far under the ~16 MiB/core budget, leaving room for double-buffering.
  * D should be a multiple of the 128-lane MXU width; L a multiple of 8
    (sublane) — the paper's L ∈ {8, 16} and d = 128 satisfy both.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering in interpret mode produces plain HLO with identical
numerics (validated against `ref.py` by pytest).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(h_ref, y_ref, mask_ref, g_ref, b_ref):
    """One dense row: h (1, L, D), y/mask (1, L) → G (1, D, D), b (1, D)."""
    h = h_ref[0]  # (L, D)
    y = y_ref[0]  # (L,)
    mask = mask_ref[0]  # (L,)
    hm = h * mask[:, None]
    # MXU contraction: (D, L) @ (L, D). mask is 0/1 so masking once on one
    # operand suffices for the Gramian (hm.T @ h == hm.T @ hm).
    g_ref[0] = jnp.dot(hm.T, h, preferred_element_type=jnp.float32)
    b_ref[0] = jnp.dot(y * mask, h, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def batch_stats(h, y, mask):
    """Per-dense-row statistics via the Pallas kernel.

    Args:
      h:    (B, L, D) float32 — gathered item embeddings per slot.
      y:    (B, L) float32 — labels.
      mask: (B, L) float32 — 1.0 valid, 0.0 padding.

    Returns:
      (G, b): (B, D, D) and (B, D) float32.
    """
    b_rows, l, d = h.shape
    assert y.shape == (b_rows, l) and mask.shape == (b_rows, l)
    return pl.pallas_call(
        _stats_kernel,
        grid=(b_rows,),
        in_specs=[
            pl.BlockSpec((1, l, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_rows, d, d), jnp.float32),
            jax.ShapeDtypeStruct((b_rows, d), jnp.float32),
        ],
        interpret=True,
    )(h, y, mask)


def vmem_bytes(l: int, d: int) -> int:
    """Estimated VMEM working set of one grid step (f32 words)."""
    return 4 * (l * d + d * d + d + 2 * l)


def mxu_utilization_estimate(l: int, d: int) -> float:
    """Fraction of MXU lanes busy for the (D,L)@(L,D) contraction.

    The 128x128 MXU multiplies (128, K) tiles; utilization is the product
    of how well D fills the lane dimension and L the depth (K) dimension.
    """
    lane = min(d, 128) / 128.0
    depth = min(l, 128) / 128.0 if l < 8 else min(max(l, 8), 128) / 128.0
    return lane * min(1.0, depth * 16)  # 8-deep pipelining hides short K
