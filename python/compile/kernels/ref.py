"""Pure-jnp correctness oracles for the L1 Pallas kernels and L2 solvers.

These are the reference semantics the pytest suite checks everything
against. They may use any jax op (including LAPACK-backed jnp.linalg —
fine under the jax runtime, though NOT loadable through the rust PJRT
bridge, which is why the production solvers in model.py avoid
custom-calls).
"""

import jax.numpy as jnp


def batch_stats_ref(h, y, mask):
    """Reference for kernels.als_stats.batch_stats.

    G[b] = sum_l mask[b,l] h[b,l] (x) h[b,l];  b[b] = sum_l mask*y*h.
    """
    hm = h * mask[..., None]
    g = jnp.einsum("bli,blj->bij", hm, h)
    bvec = jnp.einsum("bl,bli->bi", y * mask, h)
    return g, bvec


def gramian_ref(x):
    """Reference for kernels.gramian.gramian."""
    return x.T @ x


def segment_stats_ref(h, y, mask, onehot, gram, lam, alpha):
    """Per-segment normal equations (paper Eq. 4, dense-batched).

    A[s] = alpha*gram + lam*I + sum_{dr: seg(dr)=s} G[dr]
    c[s] = sum_{dr: seg(dr)=s} b[dr]
    """
    g, bvec = batch_stats_ref(h, y, mask)
    d = h.shape[-1]
    a = jnp.einsum("bs,bij->sij", onehot, g)
    a = a + alpha * gram[None] + lam * jnp.eye(d, dtype=h.dtype)[None]
    c = jnp.einsum("bs,bi->si", onehot, bvec)
    return a, c


def solve_step_ref(h, y, mask, onehot, gram, lam, alpha):
    """Reference ALS solve step: LAPACK-backed batched solve."""
    a, c = segment_stats_ref(h, y, mask, onehot, gram, lam, alpha)
    return jnp.linalg.solve(a, c[..., None])[..., 0]
