"""L1 Pallas kernel: tiled Gramian H^T H (paper Algorithm 2 line 5).

Each core computes the Gramian of its local embedding shard; the global
Gramian is the all-reduce-sum of the locals (line 6). The shard can be
large (millions of rows), so the kernel streams row tiles through VMEM and
accumulates into a (D, D) output tile that stays resident:

  grid = (N / T,): program i loads tile (T, D), adds its (D, D) product.

TPU mapping: each tile product is a (D, T) @ (T, D) MXU contraction;
T = 256 rows of d = 128 floats is a 128 KiB tile — comfortably VMEM-sized
with double buffering. The output accumulator (64 KiB at d = 128) never
leaves VMEM until the last step — this is the revolving-accumulator
pattern the paper's gramian stage uses.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gramian_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (T, D)
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)


def gramian(x, tile_rows: int = 256):
    """Tiled X^T X for a (N, D) float32 matrix; N must divide by the tile."""
    n, d = x.shape
    if n % tile_rows != 0:
        # Pad with zero rows — zeros contribute nothing to the Gramian.
        pad = tile_rows - n % tile_rows
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
        n = x.shape[0]
    grid = (n // tile_rows,)
    return pl.pallas_call(
        _gramian_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(x)


def vmem_bytes(tile_rows: int, d: int) -> int:
    """VMEM working set: input tile + resident accumulator (f32)."""
    return 4 * (tile_rows * d + d * d)
