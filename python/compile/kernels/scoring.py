"""L1 Pallas kernel: batched scoring for Top-K retrieval (paper §4.6).

Evaluation scores a batch of query (user) embeddings against the full item
shard: `S = Q @ H^T`, a (Q, D) x (D, N) contraction. On TPU this is the
one stage of Fig. 1 that is *throughput*-bound on the MXU rather than
gather-bound, so the kernel tiles N and keeps the (Q, D) query block
resident in VMEM across the whole sweep:

  grid = (N / T,): program i computes the (Q, T) score tile against item
  tile (T, D). VMEM/step = Q*D + T*D + Q*T floats (Q=64, T=512, D=128
  → 416 KiB), leaving headroom for double-buffered item tiles.

The exact/approximate Top-K selection itself stays on the host (rust
`eval/`): the paper notes Top-K is slow on TPU (§4.6) and recommends MIPS
for the largest variants — our rust MipsIndex implements that path.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, h_ref, o_ref):
    # (Q, D) @ (D, T) — one MXU contraction per item tile.
    o_ref[...] = jnp.dot(
        q_ref[...], h_ref[...].T, preferred_element_type=jnp.float32
    )


def scores(q, h, tile_items: int = 512):
    """All-pairs inner-product scores via the tiled Pallas kernel.

    Args:
      q: (Q, D) float32 query embeddings.
      h: (N, D) float32 item embeddings.
    Returns:
      (Q, N) float32 score matrix.
    """
    n, d = h.shape
    pad = (-n) % tile_items
    if pad:
        h = jnp.concatenate([h, jnp.zeros((pad, d), h.dtype)], axis=0)
    nq = q.shape[0]
    npad = h.shape[0]
    out = pl.pallas_call(
        _score_kernel,
        grid=(npad // tile_items,),
        in_specs=[
            pl.BlockSpec((nq, d), lambda i: (0, 0)),
            pl.BlockSpec((tile_items, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nq, tile_items), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nq, npad), jnp.float32),
        interpret=True,
    )(q, h)
    return out[:, :n]


def scores_ref(q, h):
    """Pure-jnp oracle."""
    return q @ h.T


def vmem_bytes(nq: int, tile_items: int, d: int) -> int:
    """VMEM working set per grid step (f32)."""
    return 4 * (nq * d + tile_items * d + nq * tile_items)
