//! Bench target for **Table 1**: generate all six WebGraph variants and
//! report their statistics next to the paper's full-scale numbers, plus
//! generation throughput.
//!
//! ```bash
//! cargo bench --bench table1_webgraph
//! ```

use alx::harness;
use alx::util::Timer;

fn main() {
    let scale = std::env::var("ALX_T1_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.002);
    let timer = Timer::start();
    let rows = harness::run_table1(scale, 7);
    let secs = timer.elapsed_secs();
    harness::print_table1(&rows, scale);
    let edges: usize = rows.iter().map(|r| r.edges).sum();
    println!(
        "\ngenerated {} edges total in {:.2}s ({:.1}M edges/s)",
        edges,
        secs,
        edges as f64 / secs / 1e6
    );
}
