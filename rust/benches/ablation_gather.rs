//! Ablation for §4.2 "Alternatives": ALX's sharded_gather (communicate
//! *embeddings*, O(|S|·d) bytes) vs the local-statistics alternative
//! (communicate *sufficient statistics*, O(|U|·d²) bytes). The paper
//! chose sharded_gather after finding the alternative slower on almost
//! every dataset; this bench shows the crossover structure that explains
//! why.
//!
//! ```bash
//! cargo bench --bench ablation_gather
//! ```

use alx::als::{TrainConfig, Trainer};
use alx::topo::Topology;
use alx::webgraph::{generate, Variant, VariantSpec};

fn main() {
    let spec = VariantSpec::preset(Variant::InDense).scaled(0.002);
    let graph = generate(&spec, 7);
    let n = graph.nodes() as u64;
    let nnz = graph.edges() as u64;

    println!(
        "dataset: {} nodes, {} edges (mean degree {:.1})",
        n,
        nnz,
        nnz as f64 / n as f64
    );
    println!(
        "\n{:>6} {:>20} {:>20} {:>10}  {}",
        "d", "sharded_gather", "local-stats alt", "ratio", "winner"
    );
    for d in [16u64, 32, 64, 128, 256, 512] {
        // ALX: gather |S| embeddings + scatter |U| solutions, bf16.
        let gather_bytes = 2 * nnz * d * 2 + 2 * n * d * 2;
        // Alternative: all-reduce one d×d statistic + d vector per solved
        // row, f32 (statistics need full precision, §4.4).
        let alt_bytes = 2 * n * (d * d + d) * 4;
        let ratio = alt_bytes as f64 / gather_bytes as f64;
        println!(
            "{:>6} {:>20} {:>20} {:>10.2}  {}",
            d,
            alx::util::stats::human_bytes(gather_bytes),
            alx::util::stats::human_bytes(alt_bytes),
            ratio,
            if ratio > 1.0 { "sharded_gather" } else { "local-stats" }
        );
    }
    println!(
        "\ncrossover: local-stats wins only when mean degree >> d (d²·|U| < d·|S|),\n\
         i.e. extremely dense matrices — on WebGraph (degree ≈ 82-244, d = 128)\n\
         sharded_gather moves less data, matching the paper's experience."
    );

    // Measured: actual collective bytes per epoch from the runtime.
    let cfg = TrainConfig {
        dim: 64,
        epochs: 1,
        batch_rows: 64,
        batch_width: 8,
        compute_objective: false,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&graph.adjacency, cfg, Topology::new(8)).expect("trainer");
    let stats = tr.run_epoch().expect("epoch");
    let snap = tr.comm.snapshot();
    println!(
        "\nmeasured (d=64, 8 cores): {} all-gathers ({}), {} all-reduces ({}), total {}/epoch",
        snap.all_gather_ops,
        alx::util::stats::human_bytes(snap.all_gather_bytes),
        snap.all_reduce_ops,
        alx::util::stats::human_bytes(snap.all_reduce_bytes),
        alx::util::stats::human_bytes(stats.comm_bytes)
    );
}
