//! Distributed compute-placement scaling: epoch wall time for a
//! solve-bound configuration (d = 128) under the two `[dist]` compute
//! placements, against the same in-process worker fleet.
//!
//! The coordinator-solve baseline runs every solve on the coordinator's
//! single solver thread — workers are pure parameter servers, so adding
//! workers cannot make the epoch faster. Worker-solve ships each batch to
//! its shard owner: the coordinator degrades to a scheduler (its threads
//! just wait on RPCs) and solve throughput scales with the fleet. The
//! target for this PR: >= 1.8x at 4 workers over the coordinator-solve
//! baseline.
//!
//! ```bash
//! cargo bench --bench dist_scaling
//! ```

use alx::als::TrainConfig;
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::dist::{DistCompute, DistConfig, DistMode, Worker};
use alx::sparse::Csr;
use alx::util::Pcg64;
use std::time::Instant;

const USERS: usize = 768;
const ITEMS: usize = 512;
const NNZ_PER_USER: usize = 24;
const DIM: usize = 128;
const SHARDS: usize = 4;

fn matrix() -> Csr {
    let mut rng = Pcg64::new(42);
    let mut t = Vec::new();
    for u in 0..USERS as u32 {
        for _ in 0..NNZ_PER_USER {
            let item = rng.range(0, ITEMS) as u32;
            t.push((u, item, 1.0 + rng.next_f64() as f32));
        }
    }
    Csr::from_coo(USERS, ITEMS, &t)
}

fn cfg(threads: usize) -> AlxConfig {
    AlxConfig {
        cores: SHARDS,
        train: TrainConfig {
            dim: DIM,
            epochs: 1,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 64,
            batch_width: 8,
            threads,
            compute_objective: false,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

struct Fleet {
    addrs: Vec<String>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_fleet(n: usize) -> Fleet {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let w = Worker::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(w.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || w.serve().expect("serve")));
    }
    Fleet { addrs, handles }
}

/// One measured epoch against a fresh fleet; returns (seconds, wire
/// bytes) — wire bytes are 0 for the local backend.
fn epoch(m: &Csr, compute: Option<DistCompute>, workers: usize, threads: usize) -> (f64, u64) {
    let fleet = compute.map(|_| spawn_fleet(workers));
    let mut c = cfg(threads);
    if let (Some(compute), Some(fleet)) = (compute, fleet.as_ref()) {
        c.dist = DistConfig {
            mode: DistMode::Tcp,
            topology: "parameter-server".to_string(),
            workers: fleet.addrs.clone(),
            heartbeat_ms: 0,
            compute,
        };
    }
    let source = InMemorySource::new("scaling", m.clone());
    let mut s = TrainSession::new(&source, c).expect("session");
    let t0 = Instant::now();
    s.step().expect("epoch");
    let secs = t0.elapsed().as_secs_f64();
    let wire = s.trainer.collectives().wire_snapshot().map_or(0, |w| w.total_bytes());
    s.trainer.collectives().shutdown().expect("shutdown");
    if let Some(fleet) = fleet {
        for h in fleet.handles {
            h.join().expect("worker thread");
        }
    }
    (secs, wire)
}

fn main() {
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let m = matrix();
    println!(
        "dist compute-placement scaling: {USERS}x{ITEMS}, {} nnz, d={DIM}, {SHARDS} shards \
         ({cores} host cores)",
        m.nnz()
    );
    if cores < 5 {
        println!("note: < 5 host cores — fleet solves share cores and the ratio understates");
    }

    println!("{:>32} {:>10} {:>14}", "placement", "epoch(s)", "wire/epoch");
    let (local, _) = epoch(&m, None, 0, 1);
    println!("{:>32} {:>10.3} {:>14}", "local (1 thread)", local, "-");

    // Baseline: coordinator solves everything on one thread; the fleet
    // only hosts shards. One point — worker count cannot change it.
    let (base, base_wire) = epoch(&m, Some(DistCompute::Coordinator), 4, 1);
    println!(
        "{:>32} {:>10.3} {:>14}",
        "tcp coordinator-solve, 4 wkrs",
        base,
        alx::util::stats::human_bytes(base_wire)
    );

    // Worker-solve: scheduler threads = fleet size (they block on RPCs,
    // not on compute), solves land on the shard owners in parallel.
    let mut at4 = base;
    for n in [1usize, 2, 4] {
        let (secs, wire) = epoch(&m, Some(DistCompute::Worker), n, n);
        if n == 4 {
            at4 = secs;
        }
        println!(
            "{:>32} {:>10.3} {:>14}",
            format!("tcp worker-solve, {n} wkrs"),
            secs,
            alx::util::stats::human_bytes(wire)
        );
    }

    let speedup = base / at4;
    println!(
        "\nworker-solve @4 workers vs coordinator-solve: {speedup:.2}x (target >= 1.8x) — {}",
        if speedup >= 1.8 { "PASS" } else { "MISS" }
    );
}
