//! Spilled-model training — the out-of-core model-residency measurement.
//!
//! Proves the acceptance bar for the table-spill tentpole: a model
//! sharded 4× over the table-residency budget (8 shards per table,
//! `resident_table_shards = 2`) trains end-to-end out of read-write
//! mapped `ALXTAB01` banks with a bitwise identical objective, and
//! reports the demand-paging traffic (table-shard faults, prefetch
//! hits) plus the resident-vs-spilled epoch time and footprint.
//!
//! ```bash
//! cargo bench --bench table_spill
//! ```
//! Record the printed table in EXPERIMENTS.md §Perf. Note on RSS: both
//! runs share this process and `VmHWM` is a high-water mark, so the
//! spilled-model run executes *first*; its peak is the honest spilled
//! figure (the generator's transient is reported separately). For a
//! clean-process demonstration use the CI smoke:
//! `alx generate --out g.csr02` then
//! `alx train --stream --spill --spill-model`.

use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::prelude::*;
use alx::util::{mem, Pcg64, Timer};

fn build_matrix(users: usize, items: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        for _ in 0..per_row {
            t.push((u, rng.next_zipf(items, 1.1) as u32, 1.0f32));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn session_cfg(spill_model: bool) -> AlxConfig {
    AlxConfig {
        cores: 8,
        model_spill: spill_model,
        resident_table_shards: 2,
        train: TrainConfig {
            dim: 32,
            epochs: 1,
            lambda: 1e-3,
            alpha: 1e-4,
            batch_rows: 64,
            batch_width: 8,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn main() {
    let m = build_matrix(30_000, 15_000, 12, 7);
    let gen_rss = mem::peak_rss_bytes();
    // W + H at bf16 (the Mixed default): rows × dim × 2 per side.
    let table_bytes = (m.rows as u64 + m.cols as u64) * 32 * 2;
    println!(
        "table_spill: {}x{}, {} nnz; model = {} of tables (8 shards/table, \
         resident_table_shards = 2)",
        m.rows,
        m.cols,
        m.nnz(),
        human(table_bytes)
    );
    println!("peak RSS after generation (pre-training transient): {}", human(gen_rss));

    // --- spilled-model run FIRST (VmHWM is monotone in-process) ---------
    let spill_dir =
        std::env::temp_dir().join(format!("alx_table_spill_bench_{}", std::process::id()));
    let mut cfg = session_cfg(true);
    cfg.model_spill_dir = spill_dir.display().to_string();
    let t = Timer::start();
    let source = InMemorySource::new("bench", m.clone());
    let mut s_spill = TrainSession::new(&source, cfg).unwrap();
    let spill_build_s = t.elapsed_secs();
    let spill_stats = s_spill.step().unwrap();
    let spill_epoch_s = spill_stats.seconds;
    let obj_spill = spill_stats.objective.unwrap();
    let table = s_spill.trainer.table_spill_stats();
    let spill_rss = mem::peak_rss_bytes();
    drop(s_spill);

    // --- resident reference --------------------------------------------
    let t = Timer::start();
    let source = InMemorySource::new("bench", m.clone());
    let mut s_res = TrainSession::new(&source, session_cfg(false)).unwrap();
    let res_build_s = t.elapsed_secs();
    let res_stats = s_res.step().unwrap();
    let res_epoch_s = res_stats.seconds;
    let obj_res = res_stats.objective.unwrap();
    let res_rss = mem::peak_rss_bytes();
    drop(s_res);

    assert_eq!(
        obj_spill.to_bits(),
        obj_res.to_bits(),
        "spilled-model epoch objective must be bitwise identical"
    );
    assert!(table.shard_faults > 0, "over-budget run must fault table shards: {table:?}");
    assert!(table.prefetch_hits > 0, "residency cache must land hits: {table:?}");

    println!("epoch-1 objective: {obj_spill:.4} (bitwise identical spilled vs resident)");
    println!(
        "table banks      : {} on disk; residency cap 2 of 8 shards per table",
        human(table.bank_bytes)
    );
    println!(
        "paging traffic   : {} table-shard faults, {} prefetch hits ({:.0}% hit rate), \
         {} prefetches",
        table.shard_faults,
        table.prefetch_hits,
        100.0 * table.hit_rate(),
        table.prefetches,
    );
    println!(
        "epoch wall clock : spilled {spill_epoch_s:.3}s vs resident {res_epoch_s:.3}s \
         ({:.2}x overhead)",
        spill_epoch_s / res_epoch_s.max(1e-9)
    );
    println!("session build    : spilled {spill_build_s:.3}s vs resident {res_build_s:.3}s");
    println!(
        "peak RSS         : after spilled run {}, after resident run {} (tables {})",
        human(spill_rss),
        human(res_rss),
        human(table_bytes)
    );

    let _ = std::fs::remove_dir_all(&spill_dir);
}

fn human(b: u64) -> String {
    alx::util::stats::human_bytes(b)
}
