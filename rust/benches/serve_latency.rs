//! Serving latency/throughput — the batching acceptance measurement.
//!
//! A bank-backed model (items demand-paged from an `ALXTAB01` bank, 2 of
//! 8 shards resident) serves 8 closed-loop clients issuing a seeded
//! zipfian query mix, for four batcher settings: the unbatched baseline
//! (`batch_max = 1`, every request is its own scoring pass) and batch
//! windows of 0, 100µs and 1ms with `batch_max = 64`. The cache is off —
//! this measures the scoring path, not memoization.
//!
//! Reported per config: p50/p99 latency and QPS, plus the batch shapes
//! actually formed. Asserts the acceptance bar: best batched QPS ≥ 2×
//! the unbatched baseline at 8 concurrent clients (coalescing decodes
//! each paged shard once per *batch* instead of once per *query*, so the
//! win is mostly the removed paging churn).
//!
//! ```bash
//! cargo bench --bench serve_latency
//! ```
//! Record the printed table in EXPERIMENTS.md §Serving.

use alx::serving::{serve, Client, Response, ServeConfig, ServeModel, TopKRequest};
use alx::sharding::{ShardedTable, Storage};
use alx::util::{Pcg64, Timer};
use std::sync::Arc;
use std::time::Instant;

const USERS: usize = 4_096;
const ITEMS: usize = 12_288;
const DIM: usize = 32;
const SHARDS: usize = 8;
const CLUSTERS: usize = 64;
const PROBES: usize = 8;
const CLIENTS: usize = 8;
const PER_CLIENT: usize = 150;

struct RunResult {
    p50_us: f64,
    p99_us: f64,
    qps: f64,
    batches: u64,
    largest_batch: u64,
}

fn run_config(model: &Arc<ServeModel>, window_us: u64, batch_max: usize) -> RunResult {
    let cfg = ServeConfig {
        threads: 2,
        batch_window_us: window_us,
        batch_max,
        cache_entries: 0,
        mips_probes: PROBES,
        ..ServeConfig::default()
    };
    let mut handle = serve(Arc::clone(model), &cfg).unwrap();
    let addr = handle.addr();

    let wall = Timer::start();
    let joins: Vec<_> = (0..CLIENTS as u64)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(0xC0FFEE ^ t);
                let mut c = Client::connect(&addr).unwrap();
                let mut lat_us = Vec::with_capacity(PER_CLIENT);
                for _ in 0..PER_CLIENT {
                    let user = rng.next_zipf(USERS, 1.2) as u64;
                    let req = TopKRequest {
                        user,
                        k: 10,
                        probes: PROBES as u32,
                        deadline_us: 0,
                        exclude: vec![],
                    };
                    let t0 = Instant::now();
                    match c.topk(&req).unwrap() {
                        Response::TopK(items) => assert_eq!(items.len(), 10),
                        other => panic!("unexpected reply: {other:?}"),
                    }
                    lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
                }
                lat_us
            })
        })
        .collect();
    let mut lat: Vec<f64> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let secs = wall.elapsed_secs();
    handle.stop();
    let stats = handle.stats();
    assert_eq!(stats.requests, (CLIENTS * PER_CLIENT) as u64);

    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    RunResult {
        p50_us: alx::util::stats::quantile_sorted(&lat, 0.50),
        p99_us: alx::util::stats::quantile_sorted(&lat, 0.99),
        qps: lat.len() as f64 / secs.max(1e-9),
        batches: stats.batches,
        largest_batch: stats.largest_batch,
    }
}

fn main() {
    // Bank-backed model: H spills to an ALXTAB01 bank and serves with 2
    // of 8 shards resident, so every scoring pass pages. W stays
    // resident (one row read per request either way).
    let mut rng = Pcg64::new(17);
    let users = ShardedTable::randn(USERS, DIM, SHARDS, Storage::Bf16, &mut rng);
    let items = ShardedTable::randn(ITEMS, DIM, SHARDS, Storage::Bf16, &mut rng);
    let dir = std::env::temp_dir().join(format!("alx_serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bank = dir.join("h.alxtab");
    items.spill_to_bank(&bank).unwrap();
    let items = ShardedTable::open_bank(&bank, 2).unwrap();

    let t = Timer::start();
    let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, 0x5eed));
    println!(
        "serve_latency: {USERS} users × {ITEMS} items, d={DIM}, bf16; H bank-backed \
         ({SHARDS} shards, 2 resident); index {CLUSTERS} clusters / {PROBES} probes \
         (built streamed in {:.3}s)",
        t.elapsed_secs()
    );
    println!(
        "{CLIENTS} closed-loop clients × {PER_CLIENT} requests, k=10, zipf(1.2) users, \
         cache off, 2 scoring workers\n"
    );

    println!(
        "{:<22} {:>10} {:>10} {:>9} {:>8} {:>8}",
        "config", "p50(us)", "p99(us)", "QPS", "batches", "largest"
    );
    let print = |name: &str, r: &RunResult| {
        println!(
            "{:<22} {:>10.0} {:>10.0} {:>9.0} {:>8} {:>8}",
            name, r.p50_us, r.p99_us, r.qps, r.batches, r.largest_batch
        );
    };

    let unbatched = run_config(&model, 0, 1);
    print("unbatched (max=1)", &unbatched);
    let mut best_qps = 0.0f64;
    for (name, window) in [("window 0", 0u64), ("window 100us", 100), ("window 1ms", 1_000)] {
        let r = run_config(&model, window, 64);
        print(&format!("batched {name}"), &r);
        assert!(r.largest_batch > 1, "{name}: batching must actually coalesce");
        best_qps = best_qps.max(r.qps);
    }

    println!(
        "\nbest batched QPS {:.0} vs unbatched {:.0} ({:.2}x)",
        best_qps,
        unbatched.qps,
        best_qps / unbatched.qps.max(1e-9)
    );
    assert!(
        best_qps >= 2.0 * unbatched.qps,
        "acceptance: batched QPS must be >= 2x unbatched ({best_qps:.0} vs {:.0})",
        unbatched.qps
    );
    let _ = std::fs::remove_dir_all(&dir);
}
