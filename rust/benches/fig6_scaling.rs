//! Bench target for **Figure 6**: epoch time vs TPU core count for the
//! four biggest WebGraph variants at paper scale (calibrated topology
//! model), plus the measured small-scale shard sweep that validates the
//! model's traffic assumptions.
//!
//! ```bash
//! cargo bench --bench fig6_scaling
//! ```

use alx::als::{TrainConfig, Trainer};
use alx::harness;
use alx::topo::Topology;
use alx::webgraph::{generate, Variant, VariantSpec};

fn main() {
    let cores = [8usize, 16, 32, 64, 128, 256, 512, 1024, 2048];
    let variants = [Variant::Sparse, Variant::Dense, Variant::DeSparse, Variant::DeDense];
    let points = harness::run_fig6(&variants, &cores, 128);
    harness::print_fig6(&points);

    // Paper anchors (§7): sparse @256 ≈ 20 min/epoch; dense 16 epochs on
    // 8 cores in < 1 day.
    if let Some(p) = points.iter().find(|p| p.variant == Variant::Sparse && p.cores == 256) {
        println!(
            "\nWebGraph-sparse @256 cores: {:.0}s/epoch (paper: ~1200s) — {:.1}x",
            p.epoch_seconds,
            p.epoch_seconds / 1200.0
        );
    }
    if let Some(p) = points.iter().find(|p| p.variant == Variant::Dense && p.cores == 8) {
        println!(
            "WebGraph-dense @8 cores: {:.1}h for 16 epochs (paper: < 24h)",
            16.0 * p.epoch_seconds / 3600.0
        );
    }

    // Measured validation: collective bytes per epoch vs core count on the
    // real runtime (shape check for the model's constant-per-core claim).
    println!("\nmeasured collective traffic vs cores (in-dense @ 0.002, d=32):");
    let spec = VariantSpec::preset(Variant::InDense).scaled(0.002);
    let graph = generate(&spec, 7);
    println!("{:>6} {:>14} {:>12}", "cores", "comm/epoch", "wall(s)");
    for m in [1usize, 2, 4, 8, 16] {
        let cfg = TrainConfig {
            dim: 32,
            epochs: 1,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: false,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&graph.adjacency, cfg, Topology::new(m)).expect("trainer");
        let stats = tr.run_epoch().expect("epoch");
        println!(
            "{:>6} {:>14} {:>12.3}",
            m,
            alx::util::stats::human_bytes(stats.comm_bytes),
            stats.seconds
        );
    }
}
