//! Ablation for §4.3 Dense Batching: padding waste of the dense-batch
//! strategy vs naive pad-to-max, across dense row widths — reproducing the
//! paper's "dense row length of 8 or 16 works quite well" guidance.
//!
//! ```bash
//! cargo bench --bench ablation_densebatch
//! ```

use alx::densebatch::DenseBatcher;
use alx::util::stats::summarize;
use alx::util::Timer;
use alx::webgraph::{generate, Variant, VariantSpec};

fn main() {
    let spec = VariantSpec::preset(Variant::InSparse).scaled(0.005);
    let graph = generate(&spec, 7);
    let m = &graph.adjacency;
    let lens = m.row_length_histogram();
    let s = summarize(&lens);
    println!(
        "row lengths: mean={:.1} p50={} p90={} p99={} max={} (long tail → naive padding wasteful)",
        s.mean, s.p50, s.p90, s.p99, s.max
    );

    println!(
        "\n{:>7} {:>14} {:>14} {:>12} {:>14}",
        "width", "dense waste", "naive waste", "batches", "batch time"
    );
    let rows: Vec<u32> = (0..m.rows as u32).collect();
    for width in [4usize, 8, 16, 32, 64, 128] {
        let batcher = DenseBatcher::new(256, width);
        let (dense_waste, naive_waste) = batcher.waste_comparison(m);
        let timer = Timer::start();
        let batches = batcher.batch_rows_of(m, &rows);
        let secs = timer.elapsed_secs();
        println!(
            "{:>7} {:>13.1}% {:>13.1}% {:>12} {:>12.1}ms",
            width,
            100.0 * dense_waste,
            100.0 * naive_waste,
            batches.len(),
            1e3 * secs
        );
    }
    println!(
        "\nsmall widths waste little padding but cost more dense rows (the\n\
         segment-mapping overhead the paper describes); width 8-16 is the\n\
         sweet spot, matching §4.3."
    );
}
