//! Bench target for **Figure 5**: training time per epoch by linear solver
//! (LU, QR, Cholesky, CG) as the embedding dimension grows — on the
//! native engine and, when artifacts exist, on the XLA/PJRT engine.
//!
//! Paper context: on TPU the MXU makes CG the fastest at large d. On this
//! CPU substrate the native engine favours Cholesky (lowest flop count);
//! the XLA engine shows CG's batched-matvec advantage. EXPERIMENTS.md
//! discusses the mapping.
//!
//! ```bash
//! cargo bench --bench fig5_solvers
//! ```

use alx::harness;
use alx::linalg::SolverKind;
use alx::runtime::XlaEngine;
use alx::webgraph::Variant;

fn main() {
    let dims = [16usize, 32, 64, 128];
    println!("== native engine ==");
    let points = harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, None).expect("fig5");
    harness::print_fig5(&points);

    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("\n== xla engine (AOT L2 graph + L1 Pallas kernel via PJRT) ==");
        let mut builder = |solver: SolverKind,
                           d: usize|
         -> anyhow::Result<Box<dyn alx::als::SolveEngine>> {
            Ok(Box::new(XlaEngine::new("artifacts", solver.name(), d, 64, 8)?))
        };
        match harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, Some(&mut builder)) {
            Ok(points) => harness::print_fig5(&points),
            Err(e) => println!("xla sweep failed: {e}"),
        }
    } else {
        println!("\n(xla engine sweep skipped: run `make artifacts`)");
    }
}
