//! Bench target for **Figure 5**: training time per epoch by linear solver
//! (LU, QR, Cholesky, CG) as the embedding dimension grows — on the
//! native engine and, when artifacts exist, on the XLA/PJRT engine.
//! Also races the direct engine against the iALS++ subspace engine and
//! asserts the headline bar: same recall@20 in ≤ 0.5× solve busy-time.
//!
//! Paper context: on TPU the MXU makes CG the fastest at large d. On this
//! CPU substrate the native engine favours Cholesky (lowest flop count);
//! the XLA engine shows CG's batched-matvec advantage. EXPERIMENTS.md
//! discusses the mapping.
//!
//! ```bash
//! cargo bench --bench fig5_solvers
//! ```

use alx::harness;
use alx::linalg::SolverKind;
use alx::runtime::XlaEngine;
use alx::webgraph::Variant;

fn main() {
    let dims = [16usize, 32, 64, 128];
    println!("== native engine ==");
    let points = harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, None).expect("fig5");
    harness::print_fig5(&points);

    // Headline race: the iALS++ subspace engine must reach the direct
    // engine's epoch-8 recall@20 in at most half the solve busy-time.
    println!("\n== solver race (direct vs iALS++) ==");
    let race = harness::run_solver_race(Variant::InDense, 0.002, 64, 16, 8, 4, 7)
        .expect("solver race");
    harness::print_solver_race(&race);
    let qr = &race[0];
    let pp = &race[1];
    assert!(
        pp.recall_at_20 >= qr.recall_at_20,
        "iALS++ never reached the direct engine's recall@20 \
         ({:.4} < {:.4} after {} epochs)",
        pp.recall_at_20,
        qr.recall_at_20,
        pp.epochs_run
    );
    assert!(
        pp.solve_ms <= 0.5 * qr.solve_ms,
        "iALS++ solve time not under the 0.5× bar: {:.1} ms vs {:.1} ms direct",
        pp.solve_ms,
        qr.solve_ms
    );
    println!(
        "iALS++ matched recall@20 {:.4} in {} epochs at {:.2}x the direct engine's solve time",
        pp.recall_at_20,
        pp.epochs_run,
        pp.solve_ms / qr.solve_ms
    );

    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("\n== xla engine (AOT L2 graph + L1 Pallas kernel via PJRT) ==");
        let mut builder = |solver: SolverKind,
                           d: usize|
         -> anyhow::Result<Box<dyn alx::als::SolveEngine>> {
            Ok(Box::new(XlaEngine::new("artifacts", solver.name(), d, 64, 8)?))
        };
        match harness::run_fig5(Variant::InDense, 0.002, &dims, 4, 7, Some(&mut builder)) {
            Ok(points) => harness::print_fig5(&points),
            Err(e) => println!("xla sweep failed: {e}"),
        }
    } else {
        println!("\n(xla engine sweep skipped: run `make artifacts`)");
    }
}
