//! Bench target for **Table 2**: train every WebGraph variant with the
//! paper's recipe (d=128→scaled, 16 epochs→scaled, CG, mixed precision,
//! per-variant hyper-parameters) and report Recall@20/@50 beside the
//! paper's numbers.
//!
//! The two largest variants are evaluated with approximate MIPS, like the
//! paper (the `*` rows). Hyper-parameters: λ from the paper's grid; α is
//! the paper's value rescaled by the item-count ratio (α multiplies the
//! all-items gramian, so its magnitude scales ~1/n — see DESIGN.md).
//!
//! ```bash
//! cargo bench --bench table2_recall                 # ~2 min at default scale
//! ALX_T2_SCALE=0.001 cargo bench --bench table2_recall
//! ```

use alx::als::TrainConfig;
use alx::harness;
use alx::util::Timer;
use alx::webgraph::Variant;

fn main() {
    let scale: f64 = std::env::var("ALX_T2_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.002);
    let epochs: usize = std::env::var("ALX_T2_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);

    let mut rows = Vec::new();
    for v in Variant::ALL {
        // λ, α per variant — λ from the paper's grid; α is the paper's
        // best value rescaled by the item-count ratio (~1/n scaling, see
        // doc comment), then refined with `alx grid --coarse`.
        let (lambda, alpha) = match v {
            Variant::Sparse => (5e-2, 5e-3),
            Variant::Dense => (1e-2, 1e-2),
            Variant::DeSparse => (1e-2, 5e-3),
            Variant::DeDense => (2e-2, 1e-2),
            Variant::InSparse => (5e-3, 5e-3),
            Variant::InDense => (5e-2, 1e-2),
        };
        let train = TrainConfig {
            dim: 96,
            epochs,
            lambda,
            alpha,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: false,
            ..TrainConfig::default()
        };
        let timer = Timer::start();
        // The full variants are 365M/136M nodes; scale them harder so all
        // six land at comparable (tiny) sizes.
        let vscale = match v {
            Variant::Sparse => scale * 1.5e-3,
            Variant::Dense => scale * 4e-3,
            Variant::DeSparse => scale * 0.03,
            Variant::DeDense => scale * 0.1,
            Variant::InSparse => scale * 0.4,
            Variant::InDense => scale,
        };
        match harness::run_table2_row(v, vscale, &train, 8, 7) {
            Ok(row) => {
                println!(
                    "{}: R@20={:.3} R@50={:.3} ({:.1}s)",
                    v.name(),
                    row.recall_at_20,
                    row.recall_at_50,
                    timer.elapsed_secs()
                );
                rows.push(row);
            }
            Err(e) => println!("{}: failed: {e}", v.name()),
        }
    }
    harness::print_table2(&rows);
}
