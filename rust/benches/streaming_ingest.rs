//! Streaming ingestion — the out-of-core tentpole measurement.
//!
//! Proves the acceptance bar: a dataset whose full `Csr` is ~2× larger
//! than the configured ingest budget trains end-to-end through the
//! streaming path, with peak ingestion memory bounded by the chunk size
//! (not the matrix size) and an objective bitwise identical to the
//! in-memory path. Also times epoch-0 load for the bulk-IO `ALXCSR01`
//! codec and the chunked cursor.
//!
//! ```bash
//! cargo bench --bench streaming_ingest
//! ```
//! Record the printed table in EXPERIMENTS.md §Perf.

use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::{InMemorySource, StreamingSource};
use alx::prelude::*;
use alx::util::{mem, Pcg64, Timer};

fn build_matrix(users: usize, items: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        for _ in 0..per_row {
            t.push((u, rng.next_zipf(items, 1.1) as u32, 1.0f32));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn session_cfg(epochs: usize) -> AlxConfig {
    AlxConfig {
        cores: 8,
        train: TrainConfig {
            dim: 16,
            epochs,
            lambda: 1e-3,
            alpha: 1e-4,
            batch_rows: 64,
            batch_width: 8,
            threads: 1,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn main() {
    let m = build_matrix(60_000, 30_000, 16, 7);
    let matrix_bytes = m.memory_bytes();
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let path01 = dir.join(format!("alx_ingest_bench_{pid}.csr01"));
    let path02 = dir.join(format!("alx_ingest_bench_{pid}.csr02"));
    let chunk_rows = 4096usize;

    println!(
        "streaming_ingest: {}x{}, {} nnz, in-memory Csr = {}",
        m.rows,
        m.cols,
        m.nnz(),
        human(matrix_bytes)
    );

    // --- epoch-0 load time: bulk-IO ALXCSR01 round trip ------------------
    {
        let t = Timer::start();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path01).unwrap());
        m.write_to(&mut f).unwrap();
        use std::io::Write;
        f.flush().unwrap();
        let write_s = t.elapsed_secs();
        let t = Timer::start();
        let file = std::fs::File::open(&path01).unwrap();
        let len = file.metadata().unwrap().len();
        let mut r = std::io::BufReader::new(file);
        let m2 = Csr::read_from_limited(&mut r, Some(len)).unwrap();
        let read_s = t.elapsed_secs();
        assert_eq!(m2, m);
        println!("ALXCSR01 bulk IO : write {write_s:.3}s, read {read_s:.3}s ({len} bytes)");
    }

    // --- chunked write + streaming cursor --------------------------------
    {
        let t = Timer::start();
        let f = std::io::BufWriter::new(std::fs::File::create(&path02).unwrap());
        alx::sparse::write_chunked(&m, f, chunk_rows).unwrap();
        let write_s = t.elapsed_secs();
        println!("ALXCSR02 write   : {write_s:.3}s ({chunk_rows} rows/chunk)");
    }

    // --- the acceptance bar ---------------------------------------------
    // Budget = half the in-memory matrix: the full Csr is 2x over budget,
    // yet the streaming cursor must ingest within it.
    let budget = matrix_bytes / 2;
    let t = Timer::start();
    let streamed = StreamingSource::new(&path02, budget)
        .load_split(8, 0.9, 0.25, AlxConfig::default().data_seed ^ 0x9)
        .unwrap();
    let ingest_s = t.elapsed_secs();
    let peak = streamed.ingest.peak_chunk_bytes;
    assert!(
        peak <= budget,
        "peak chunk {} exceeded the {} budget",
        human(peak),
        human(budget)
    );
    println!(
        "streaming ingest : {ingest_s:.3}s, {} chunks, peak chunk {} (budget {}, matrix {})",
        streamed.ingest.chunks,
        human(peak),
        human(budget),
        human(matrix_bytes)
    );
    drop(streamed);

    // --- end-to-end equivalence on a one-epoch run -----------------------
    let mut cfg = session_cfg(1);
    cfg.ingest_budget_mb = ((budget >> 20) as usize).max(1);
    let t = Timer::start();
    let mut s_stream = TrainSession::from_streaming(&path02, cfg, None).unwrap();
    let stream_build_s = t.elapsed_secs();
    let obj_stream = s_stream.step().unwrap().objective.unwrap();

    let t = Timer::start();
    let source = InMemorySource::new("bench", m.clone());
    let mut s_mem = TrainSession::new(&source, session_cfg(1)).unwrap();
    let mem_build_s = t.elapsed_secs();
    let obj_mem = s_mem.step().unwrap().objective.unwrap();

    assert_eq!(
        obj_stream.to_bits(),
        obj_mem.to_bits(),
        "streaming epoch objective must be bitwise identical"
    );
    println!(
        "epoch-1 objective: {obj_stream:.4} (bitwise identical streaming vs in-memory)"
    );
    println!(
        "session build    : streaming {stream_build_s:.3}s vs in-memory {mem_build_s:.3}s"
    );
    println!("peak RSS         : {}", human(mem::peak_rss_bytes()));

    let _ = std::fs::remove_file(&path01);
    let _ = std::fs::remove_file(&path02);
}

fn human(b: u64) -> String {
    alx::util::stats::human_bytes(b)
}
