//! Cost of the fault-injection hooks on the IO hot paths.
//!
//! The contract is that a production build (no `failpoints` feature)
//! pays nothing: every hook is an inlined `Ok(())`. With the feature on,
//! each hook is one registry lock + hash lookup; this bench puts numbers
//! on both states and on a chunked-write path threaded with hooks.
//!
//! ```bash
//! cargo bench --bench fault_overhead                        # no-op hooks
//! cargo bench --bench fault_overhead --features failpoints  # live hooks
//! ```

use alx::sparse::{write_chunked, Csr};
use alx::util::{fault, Pcg64, Timer};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let timer = Timer::start();
    for _ in 0..iters {
        f();
    }
    let per = timer.elapsed_secs() / iters as f64;
    println!("{name:<44} {:>12.1} ns/iter", per * 1e9);
    per
}

fn main() {
    println!(
        "fault hooks compiled {}: fault::ENABLED = {}\n",
        if fault::ENABLED { "IN (--features failpoints)" } else { "OUT" },
        fault::ENABLED
    );

    // Raw hook cost, unconfigured name (the production steady state even
    // in a failpoints build: nothing armed).
    bench("failpoint(), unconfigured", 2_000_000, || {
        let _ = std::hint::black_box(fault::failpoint(std::hint::black_box("bench.nop")));
    });
    bench("failpoint_bytes(), unconfigured", 2_000_000, || {
        let _ = std::hint::black_box(fault::failpoint_bytes(std::hint::black_box("bench.nop"), 4096));
    });

    // An armed-but-never-firing failpoint (trigger far out of reach) — the
    // worst case a torture run pays on the paths it is not killing.
    if fault::ENABLED {
        fault::configure("bench.armed=hit:18446744073709551615").unwrap();
        bench("failpoint(), armed non-firing", 2_000_000, || {
            let _ = std::hint::black_box(fault::failpoint(std::hint::black_box("bench.armed")));
        });
        fault::reset();
    }

    // End-to-end: a chunked-format write (hooks at every chunk flush)
    // into an in-memory sink, so the delta is hook cost, not disk.
    let mut rng = Pcg64::new(7);
    let mut triplets = Vec::new();
    for r in 0..4000u32 {
        for _ in 0..8 {
            triplets.push((r, rng.range(0, 2000) as u32, 1.0f32));
        }
    }
    let m = Csr::from_coo(4000, 2000, &triplets);
    bench("write_chunked 4000x2000 (64-row chunks)", 50, || {
        let mut sink = Vec::with_capacity(1 << 20);
        write_chunked(&m, &mut sink, 64).unwrap();
        std::hint::black_box(sink.len());
    });
}
