//! Bench target for **Figure 4**: eval metric by epoch for f32, mixed and
//! naive-bf16 precision — in the low-λ collapse regime (Fig. 4a) and the
//! high-λ stable regime (Fig. 4b).
//!
//! Note on calibration: the collapse threshold sits at the λ that bf16's
//! 8-bit mantissa can still represent against the normal-matrix diagonal
//! (∝ row-degree/d). Our scaled dataset uses a smaller d than the paper,
//! so the regime boundary sits at a larger λ — the *mechanism* and the
//! qualitative split are identical (see EXPERIMENTS.md).
//!
//! ```bash
//! cargo bench --bench fig4_precision
//! ALX_F4_LAMBDA=1e-2 cargo bench --bench fig4_precision  # single custom run
//! ```

use alx::harness;
use alx::webgraph::Variant;

fn run(lambda: f32, label: &str) {
    println!("\n=== {label} (λ={lambda:.0e}) ===");
    let series = harness::run_fig4(Variant::InDense, 0.002, 10, 32, lambda, 4, 7)
        .expect("fig4 run");
    harness::print_fig4(&series);

    let last = |name: &str| {
        series
            .iter()
            .find(|s| s.precision.name() == name)
            .and_then(|s| s.recall_by_epoch.last().copied())
            .unwrap_or(0.0)
    };
    println!(
        "final R@20: f32={:.3} mixed={:.3} naive-bf16={:.3}",
        last("f32"),
        last("mixed"),
        last("naive-bf16"),
    );
}

fn main() {
    if let Some(lambda) = std::env::var("ALX_F4_LAMBDA").ok().and_then(|s| s.parse().ok()) {
        run(lambda, "custom λ");
        return;
    }
    run(1e-4, "Fig. 4a — low regularization: naive bf16 collapses");
    run(5e-1, "Fig. 4b — high regularization: naive bf16 tracks f32");
    println!(
        "\nconclusion (paper §4.4): store tables in bf16, cast solver inputs\n\
         to f32, cast solutions back — 'mixed' matches f32 at half the\n\
         memory and collective traffic in BOTH regimes."
    );
}
