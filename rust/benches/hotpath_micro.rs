//! Microbenchmarks of the per-batch hot path (the §Perf working set):
//! sharded_gather, sufficient statistics, each solver, sharded_scatter —
//! native vs XLA engine at the production shape (B=64, L=8, d=128).
//!
//! ```bash
//! cargo bench --bench hotpath_micro
//! ```

use alx::als::{NativeEngine, SolveEngine};
use alx::collectives::{sharded_gather, sharded_scatter, CommStats};
use alx::densebatch::DenseBatcher;
use alx::linalg::{Mat, SolveOptions, SolverKind};
use alx::runtime::XlaEngine;
use alx::sharding::{ShardedTable, Storage};
use alx::sparse::Csr;
use alx::util::{Pcg64, Timer};

const B: usize = 64;
const L: usize = 8;
const D: usize = 128;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let timer = Timer::start();
    for _ in 0..iters {
        f();
    }
    let per = timer.elapsed_secs() / iters as f64;
    println!("{name:<38} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    let mut rng = Pcg64::new(7);
    let n_items = 4000;

    // A realistic batch from a zipf-ish matrix.
    let mut triplets = Vec::new();
    for r in 0..B as u32 {
        for _ in 0..L {
            triplets.push((r, rng.next_zipf(n_items, 1.2) as u32, 1.0f32));
        }
    }
    let m = Csr::from_coo(B, n_items, &triplets);
    let batcher = DenseBatcher::new(B, L);
    let batch = batcher.batch_rows_of(&m, &(0..B as u32).collect::<Vec<_>>())[0].clone();

    let table = ShardedTable::randn(n_items, D, 8, Storage::Bf16, &mut rng);
    let items_dense = table.to_dense();
    let gram = items_dense.gramian();
    let stats = CommStats::new();

    println!("hot path @ B={B} L={L} d={D}, {n_items} items, 8 shards\n");

    bench("sharded_gather (collective emu)", 200, || {
        let _ = sharded_gather(&table, &batch.items, &stats);
    });

    let gathered = sharded_gather(&table, &batch.items, &stats);

    bench("sufficient statistics (native)", 50, || {
        let _ = alx::als::stats::accumulate(&batch, &gathered, &gram, 0.01, 0.001, false);
    });

    for solver in SolverKind::ALL {
        let eng = NativeEngine::new(solver, SolveOptions::default());
        bench(&format!("solve_batch native/{}", solver.name()), 10, || {
            let _ = eng.solve_batch(&batch, &gathered, &gram, 0.01, 0.001).unwrap();
        });
    }

    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        for solver in SolverKind::ALL {
            match XlaEngine::new("artifacts", solver.name(), D, B, L) {
                Ok(eng) => {
                    bench(&format!("solve_batch xla/{}", solver.name()), 10, || {
                        let _ = eng.solve_batch(&batch, &gathered, &gram, 0.01, 0.001).unwrap();
                    });
                }
                Err(e) => println!("xla/{}: unavailable ({e})", solver.name()),
            }
        }
    } else {
        println!("(xla engine benches skipped: run `make artifacts`)");
    }

    let mut table_mut = ShardedTable::randn(n_items, D, 8, Storage::Bf16, &mut rng);
    let solutions = Mat::randn(batch.num_segments(), D, 1.0, &mut rng);
    bench("sharded_scatter (collective emu)", 200, || {
        sharded_scatter(&mut table_mut, &batch.segment_rows, &solutions, &stats);
    });

    // Throughput summary for the stats kernel (the O(|S|d²) hot spot).
    let slots = batch.valid_slots();
    let flops_per = 2.0 * slots as f64 * (D * D + D) as f64;
    let per = bench("stats throughput probe", 50, || {
        let _ = alx::als::stats::accumulate(&batch, &gathered, &gram, 0.01, 0.001, false);
    });
    println!(
        "\nstatistics kernel: {:.2} GFLOP/s on {} valid slots",
        flops_per / per / 1e9,
        slots
    );

    // Gramian accumulation: row-at-a-time rank-1 updates vs the blocked
    // rank-k kernel both engines now feed. The blocked kernel keeps each
    // G entry in a register across a 16-row chunk, so it must win on
    // memory traffic alone; the headline bar is ≥ 1.5× at d ≥ 128.
    println!("\ngramian accumulation: rank-1 row loop vs blocked rank-k kernel");
    let k_rows = 256;
    for d in [64usize, 128, 256] {
        let rows: Vec<f32> = (0..k_rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut g = vec![0.0f32; d * d];
        let rank1 = bench(&format!("  rank-1 loop       d={d}"), 20, || {
            g.iter_mut().for_each(|v| *v = 0.0);
            for row in rows.chunks(d) {
                alx::linalg::syrk_update(&mut g, row, 1.0);
            }
        });
        let mut g2 = vec![0.0f32; d * d];
        let blocked = bench(&format!("  blocked rank-k    d={d}"), 20, || {
            g2.iter_mut().for_each(|v| *v = 0.0);
            for chunk in rows.chunks(alx::linalg::SYRK_CHUNK_ROWS * d) {
                alx::linalg::syrk_rankk_upper(&mut g2, d, chunk);
            }
        });
        // The rank-1 loop touches the full square; compare on the shared
        // upper triangle only (the blocked kernel's contract).
        for i in 0..d {
            assert_eq!(g[i * d + i..(i + 1) * d], g2[i * d + i..(i + 1) * d], "d={d} row {i}");
        }
        let speedup = rank1 / blocked;
        println!("  speedup           d={d}: {speedup:.2}x");
        if d >= 128 {
            assert!(
                speedup >= 1.5,
                "blocked gramian kernel below the 1.5x bar at d={d}: {speedup:.2}x"
            );
        }
    }
}
