//! Pipelined epoch throughput — the tentpole measurement: one full ALS
//! epoch (user pass + item pass) through the serial reference
//! (`threads = 1`) vs the pipelined multi-threaded engine
//! (`threads = 0` → auto), same problem, same numerics (the determinism
//! tests prove the outputs are bitwise identical).
//!
//! ```bash
//! cargo bench --bench pipeline_epoch
//! ```

use alx::prelude::*;
use alx::util::Pcg64;

fn build_matrix(users: usize, items: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        for _ in 0..per_row {
            t.push((u, rng.next_zipf(items, 1.1) as u32, 1.0f32));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        dim: 64,
        epochs: 1,
        lambda: 1e-3,
        alpha: 1e-4,
        batch_rows: 64,
        batch_width: 8,
        compute_objective: false,
        threads,
        ..TrainConfig::default()
    }
}

/// Best-of-`reps` epoch wall clock at the given thread budget.
fn epoch_seconds(m: &Csr, threads: usize, reps: usize) -> f64 {
    let mut tr = Trainer::new(m, cfg(threads), Topology::new(8)).expect("trainer");
    tr.run_epoch().expect("warmup epoch"); // warm caches / page in tables
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        best = best.min(tr.run_epoch().expect("epoch").seconds);
    }
    best
}

fn main() {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let m = build_matrix(6000, 3000, 32, 7);
    println!(
        "pipeline_epoch: {} users x {} items, {} nnz, d=64, B=64 L=8, 8 shards, host threads={host}\n",
        m.rows,
        m.cols,
        m.nnz()
    );

    // threads=1: serial compute — one shard at a time, one segment worker
    // (feeder/scatter stage overlap stays, as on a real host pipeline).
    let serial = epoch_seconds(&m, 1, 3);
    println!("serial compute (threads=1) {serial:>8.3} s/epoch");
    let pipelined = epoch_seconds(&m, 0, 3);
    println!("pipelined   (threads=auto) {pipelined:>8.3} s/epoch");
    let speedup = serial / pipelined;
    println!("\nspeedup: {speedup:.2}x");
    if host >= 4 && speedup < 2.0 {
        println!("WARNING: expected >=2x over serial on a >=4-thread host");
    }
}
