//! The session lifecycle's core contract: `step()` / `checkpoint()` /
//! `resume()` is **bitwise deterministic** — a run interrupted at epoch k
//! and resumed from its checkpoint produces tables and remaining history
//! bitwise identical to the uninterrupted run, at every thread count and
//! in both storage precisions (tables round-trip losslessly through the
//! checkpoint format).

use alx::als::{EpochStats, PrecisionPolicy, TrainConfig};
use alx::config::AlxConfig;
use alx::coordinator::{EarlyStopOnPlateau, TrainSession};
use alx::data::InMemorySource;
use alx::sparse::Csr;
use alx::util::Pcg64;
use std::path::PathBuf;

/// Two-community implicit matrix (same generator family as the trainer's
/// unit tests).
fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize, precision: PrecisionPolicy) -> AlxConfig {
    AlxConfig {
        cores: 4,
        train: TrainConfig {
            dim: 12,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            threads,
            precision,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn source() -> InMemorySource {
    InMemorySource::new("community", community_matrix(60, 40, 3))
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_resume_{}_{}.ckpt", tag, std::process::id()))
}

/// The timing-free fingerprint of an epoch (seconds vary run to run).
fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

/// Train all `epochs` epochs in one session.
fn run_uninterrupted(
    epochs: usize,
    threads: usize,
    precision: PrecisionPolicy,
) -> (Vec<f32>, Vec<f32>, Vec<(usize, Option<u64>, u64)>) {
    let mut s = TrainSession::new(&source(), cfg(epochs, threads, precision)).unwrap();
    while s.remaining_epochs() > 0 {
        s.step().unwrap();
    }
    (
        s.trainer.w.to_dense().data,
        s.trainer.h.to_dense().data,
        s.history().iter().map(fingerprint).collect(),
    )
}

/// Train `stop_at` epochs, checkpoint, drop the session, resume from the
/// file in a brand-new session, and finish the run. Returns the final
/// tables and only the post-resume history.
fn run_interrupted(
    epochs: usize,
    stop_at: usize,
    threads: usize,
    precision: PrecisionPolicy,
    tag: &str,
) -> (Vec<f32>, Vec<f32>, Vec<(usize, Option<u64>, u64)>) {
    let path = tmp_path(tag);
    {
        let mut s = TrainSession::new(&source(), cfg(epochs, threads, precision)).unwrap();
        for _ in 0..stop_at {
            s.step().unwrap();
        }
        s.checkpoint(&path).unwrap();
    }
    let mut s =
        TrainSession::resume_with(&path, &source(), cfg(epochs, threads, precision), None)
            .unwrap();
    assert_eq!(s.trainer.current_epoch(), stop_at);
    while s.remaining_epochs() > 0 {
        s.step().unwrap();
    }
    let out = (
        s.trainer.w.to_dense().data,
        s.trainer.h.to_dense().data,
        s.history().iter().map(fingerprint).collect(),
    );
    let _ = std::fs::remove_file(&path);
    out
}

fn assert_resume_bitwise(threads: usize, precision: PrecisionPolicy, tag: &str) {
    const EPOCHS: usize = 6;
    const STOP_AT: usize = 3;
    let (w_full, h_full, hist_full) = run_uninterrupted(EPOCHS, threads, precision);
    let (w_res, h_res, hist_res) = run_interrupted(EPOCHS, STOP_AT, threads, precision, tag);
    assert_eq!(w_full, w_res, "W differs after resume ({tag})");
    assert_eq!(h_full, h_res, "H differs after resume ({tag})");
    // The resumed session's history must be exactly the tail of the
    // uninterrupted run: same epoch numbers, bitwise-equal objectives,
    // same comm accounting.
    assert_eq!(hist_res.len(), EPOCHS - STOP_AT);
    assert_eq!(&hist_full[STOP_AT..], &hist_res[..], "remaining history differs ({tag})");
}

#[test]
fn resume_is_bitwise_identical_serial_mixed() {
    assert_resume_bitwise(1, PrecisionPolicy::Mixed, "t1_mixed");
}

#[test]
fn resume_is_bitwise_identical_parallel_mixed() {
    assert_resume_bitwise(4, PrecisionPolicy::Mixed, "t4_mixed");
}

#[test]
fn resume_is_bitwise_identical_serial_f32() {
    assert_resume_bitwise(1, PrecisionPolicy::F32, "t1_f32");
}

#[test]
fn resume_is_bitwise_identical_parallel_f32() {
    assert_resume_bitwise(4, PrecisionPolicy::F32, "t4_f32");
}

#[test]
fn resume_across_thread_counts_matches() {
    // Checkpoint written by a serial run, resumed by a 4-thread run (and
    // vice versa): the pipelined engine's determinism contract extends
    // through the checkpoint boundary.
    let path = tmp_path("cross_threads");
    {
        let mut s = TrainSession::new(&source(), cfg(6, 1, PrecisionPolicy::F32)).unwrap();
        for _ in 0..3 {
            s.step().unwrap();
        }
        s.checkpoint(&path).unwrap();
    }
    let mut resumed =
        TrainSession::resume_with(&path, &source(), cfg(6, 4, PrecisionPolicy::F32), None)
            .unwrap();
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    let (w_full, h_full, _) = run_uninterrupted(6, 1, PrecisionPolicy::F32);
    assert_eq!(w_full, resumed.trainer.w.to_dense().data);
    assert_eq!(h_full, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn early_stop_state_survives_resume() {
    // An EarlyStopOnPlateau demanding absurd 90% per-epoch improvement
    // plateaus immediately: epoch 1 sets `best`, epochs 2..=1+patience
    // fail to improve, so the run stops at epoch 1 + patience. A run
    // interrupted before that point must stop at the SAME epoch after
    // resume — the checkpoint's objective log reconstructs the hook state.
    const PATIENCE: usize = 3;
    let stop_epoch = 1 + PATIENCE;
    let path = tmp_path("early_stop");

    let uninterrupted = {
        let mut s = TrainSession::new(&source(), cfg(50, 1, PrecisionPolicy::F32)).unwrap();
        s.add_hook(Box::new(EarlyStopOnPlateau::new(PATIENCE, 0.9)));
        s.run().unwrap();
        assert!(s.stopped(), "plateau must trigger");
        s.trainer.current_epoch()
    };
    assert_eq!(uninterrupted, stop_epoch);

    // Interrupt after epoch 2 — mid-plateau, so a hook that restarted
    // from scratch would stop 2 epochs late.
    {
        let mut s = TrainSession::new(&source(), cfg(50, 1, PrecisionPolicy::F32)).unwrap();
        s.add_hook(Box::new(EarlyStopOnPlateau::new(PATIENCE, 0.9)));
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&path).unwrap();
    }
    let mut resumed = TrainSession::resume_with(
        &path,
        &source(),
        cfg(50, 1, PrecisionPolicy::F32),
        None,
    )
    .unwrap();
    resumed.add_hook(Box::new(EarlyStopOnPlateau::new(PATIENCE, 0.9)));
    resumed.run().unwrap();
    assert!(resumed.stopped(), "resumed run must still plateau");
    assert_eq!(
        resumed.trainer.current_epoch(),
        stop_epoch,
        "resumed run stopped at a different epoch than the uninterrupted one"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn early_stop_checkpoint_written_at_stop_epoch_resumes_stopped() {
    // `--checkpoint-every 1` writes the checkpoint *before* the early-stop
    // hook fires in the same epoch, so a checkpoint can exist for the very
    // epoch the run stopped at. Resuming it must come up already stopped —
    // not train one extra epoch past the uninterrupted run.
    let path = tmp_path("early_stop_at_stop");
    let stop_epoch = {
        let mut s = TrainSession::new(&source(), cfg(50, 1, PrecisionPolicy::F32)).unwrap();
        s.add_hook(Box::new(EarlyStopOnPlateau::new(2, 0.9)));
        s.run().unwrap();
        assert!(s.stopped());
        s.checkpoint(&path).unwrap(); // state as of the stop epoch
        s.trainer.current_epoch()
    };
    let mut resumed = TrainSession::resume_with(
        &path,
        &source(),
        cfg(50, 1, PrecisionPolicy::F32),
        None,
    )
    .unwrap();
    resumed.add_hook(Box::new(EarlyStopOnPlateau::new(2, 0.9)));
    assert!(resumed.stopped(), "replaying a completed plateau must stop the session");
    resumed.run().unwrap();
    assert_eq!(
        resumed.trainer.current_epoch(),
        stop_epoch,
        "resumed-from-stop-epoch session trained extra epochs"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn config_driven_resume_matches_cli_path() {
    // What `alx train --resume <ckpt>` does: both sessions built purely
    // from the (webgraph-source) config.
    let make_cfg = || AlxConfig {
        scale: 0.0008,
        cores: 3,
        train: TrainConfig {
            dim: 8,
            epochs: 4,
            lambda: 0.03,
            alpha: 0.01,
            batch_rows: 32,
            batch_width: 8,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };
    let path = tmp_path("cfg_driven");

    let mut full = TrainSession::from_config(make_cfg()).unwrap();
    while full.remaining_epochs() > 0 {
        full.step().unwrap();
    }

    {
        let mut s = TrainSession::from_config(make_cfg()).unwrap();
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&path).unwrap();
    }
    let mut resumed = TrainSession::resume(&path, make_cfg()).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 2);
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    assert_eq!(full.trainer.w.to_dense().data, resumed.trainer.w.to_dense().data);
    assert_eq!(full.trainer.h.to_dense().data, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&path);
}
