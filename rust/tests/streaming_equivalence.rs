//! The streaming ingestion contract: a session built by streaming an
//! `ALXCSR02` file chunk-by-chunk (split and sharded as rows arrive,
//! bounded-memory cursor) trains **bitwise identically** to the in-memory
//! path on the same data — same split, same objective history, same
//! recalls, same final tables — while its peak ingestion working set is
//! bounded by the chunk size, not the matrix size.

use alx::als::{EpochStats, TrainConfig};
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::sparse::{write_chunked, Csr};
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize) -> AlxConfig {
    AlxConfig {
        cores: 4,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn write_csr02(m: &Csr, tag: &str, chunk_rows: usize) -> PathBuf {
    let path = std::env::temp_dir().join(format!(
        "alx_stream_eq_{}_{}_{}.csr02",
        tag,
        chunk_rows,
        std::process::id()
    ));
    let f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    write_chunked(m, f, chunk_rows).unwrap();
    path
}

/// Timing-free fingerprint of an epoch.
fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

type RunFingerprint =
    (Vec<(usize, Option<u64>, u64)>, Vec<f32>, Vec<f32>, Vec<(usize, u64)>);

fn run(mut s: TrainSession) -> RunFingerprint {
    let report = s.run().unwrap();
    let recalls: Vec<(usize, u64)> =
        report.recalls.iter().map(|r| (r.k, r.recall.to_bits())).collect();
    (
        report.history.iter().map(fingerprint).collect(),
        s.trainer.w.to_dense().data,
        s.trainer.h.to_dense().data,
        recalls,
    )
}

#[test]
fn streaming_run_is_bitwise_identical_to_in_memory() {
    let m = community_matrix(60, 40, 3);
    let in_memory = {
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, cfg(3)).unwrap()
    };
    let (hist_mem, w_mem, h_mem, rec_mem) = run(in_memory);

    for chunk_rows in [7usize, 16, 1000] {
        let path = write_csr02(&m, "bitwise", chunk_rows);
        let streaming = TrainSession::from_streaming(&path, cfg(3), None).unwrap();
        assert!(streaming.ingest.is_some(), "streaming session must report ingestion");
        let (hist, w, h, rec) = run(streaming);
        assert_eq!(hist, hist_mem, "objective history differs (chunk_rows={chunk_rows})");
        assert_eq!(w, w_mem, "W differs (chunk_rows={chunk_rows})");
        assert_eq!(h, h_mem, "H differs (chunk_rows={chunk_rows})");
        assert_eq!(rec, rec_mem, "recalls differ (chunk_rows={chunk_rows})");
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn streaming_session_reports_bounded_ingest() {
    let m = community_matrix(80, 40, 5);
    let path = write_csr02(&m, "bounded", 8);
    let s = TrainSession::from_streaming(&path, cfg(1), None).unwrap();
    let ing = s.ingest.as_ref().unwrap();
    assert_eq!(ing.chunks, 10);
    // The cursor's working set is one chunk, far below the matrix bytes.
    assert!(ing.peak_chunk_bytes > 0);
    assert!(
        ing.peak_chunk_bytes < m.memory_bytes() / 2,
        "peak chunk {} vs matrix {}",
        ing.peak_chunk_bytes,
        m.memory_bytes()
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streaming_respects_ingest_budget() {
    let m = community_matrix(80, 40, 7);
    // One giant chunk cannot fit a 1 MiB... use tiny budget via the
    // StreamingSource API directly (the config knob is MiB-granular).
    let path = write_csr02(&m, "budget", 1000);
    let src = alx::data::StreamingSource::new(&path, 64);
    let err = src.load_split(4, 0.9, 0.25, 1).unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");
    // Small chunks stream under the same budget... (8 rows ≈ 32 + nnz*8 B)
    let path2 = write_csr02(&m, "budget_ok", 2);
    let src2 = alx::data::StreamingSource::new(&path2, 1 << 10);
    assert!(src2.load_split(4, 0.9, 0.25, 1).is_ok());
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&path2);
}

#[test]
fn streaming_config_path_works_end_to_end() {
    let m = community_matrix(60, 40, 9);
    let path = write_csr02(&m, "config", 16);
    let mut c = cfg(2);
    c.data_source = "edge-list".to_string();
    c.data_path = path.display().to_string();
    c.data_streaming = true;
    let mut s = TrainSession::from_config(c).unwrap();
    assert_eq!(s.dataset.rows, 60);
    assert_eq!(s.dataset.nnz, m.nnz() as u64);
    let report = s.run().unwrap();
    assert_eq!(report.history.len(), 2);
    assert!(report.ingest.is_some());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streaming_checkpoint_resume_is_bitwise() {
    let m = community_matrix(60, 40, 11);
    let path = write_csr02(&m, "resume", 16);
    let ckpt = std::env::temp_dir().join(format!("alx_stream_eq_{}.ckpt", std::process::id()));

    let make = || TrainSession::from_streaming(&path, cfg(4), None).unwrap();
    let mut full = make();
    while full.remaining_epochs() > 0 {
        full.step().unwrap();
    }
    {
        let mut s = make();
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let mut c = cfg(4);
    c.data_path = path.display().to_string();
    c.data_streaming = true;
    let mut resumed = TrainSession::resume(&ckpt, c).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 2);
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    assert_eq!(full.trainer.w.to_dense().data, resumed.trainer.w.to_dense().data);
    assert_eq!(full.trainer.h.to_dense().data, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&ckpt);
}
