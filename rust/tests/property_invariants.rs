//! Property-based tests over the coordinator's core invariants (routing,
//! batching, sharding, collectives, splits). The environment is offline so
//! `proptest` is unavailable; this file uses the same methodology with an
//! in-repo harness: seeded random generators, many cases per property, and
//! the failing seed printed on assertion failure.

use alx::collectives::{sharded_gather, sharded_scatter, CommStats};
use alx::densebatch::DenseBatcher;
use alx::linalg::Mat;
use alx::sharding::{ShardedTable, Storage};
use alx::sparse::{split_strong_generalization, Csr};
use alx::util::Pcg64;

const CASES: u64 = 120;

/// Random CSR with heavy-tailed row lengths.
fn random_csr(rng: &mut Pcg64) -> Csr {
    let rows = 1 + rng.range(0, 40);
    let cols = 1 + rng.range(0, 60);
    let mut t = Vec::new();
    for r in 0..rows as u32 {
        let len = match rng.range(0, 10) {
            0..=5 => rng.range(0, 4),
            6..=8 => rng.range(0, 12),
            _ => rng.range(0, 40),
        }
        .min(cols);
        let mut seen = std::collections::HashSet::new();
        while seen.len() < len {
            seen.insert(rng.range(0, cols) as u32);
        }
        for c in seen {
            t.push((r, c, rng.next_f32() * 2.0 - 0.5));
        }
    }
    Csr::from_coo(rows, cols, &t)
}

/// PROPERTY: dense batching preserves every (row, item, value) triple of
/// non-empty rows exactly once, never splits a row across batches, and
/// never exceeds the static shape.
#[test]
fn prop_densebatch_is_a_partition() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed);
        let m = random_csr(&mut rng);
        let b = 1 + rng.range(0, 16);
        let w = 1 + rng.range(0, 12);
        let batcher = DenseBatcher::new(b, w);
        let rows: Vec<u32> = (0..m.rows as u32).collect();
        let capacity = b * w;

        let mut recovered: Vec<(u32, u32, f32)> = Vec::new();
        let mut rows_seen = std::collections::HashSet::new();
        for batch in batcher.batch_rows_of(&m, &rows) {
            assert_eq!(batch.items.len(), capacity, "seed {seed}: static shape violated");
            for &sr in &batch.segment_rows {
                assert!(rows_seen.insert(sr), "seed {seed}: row {sr} split across batches");
            }
            for dr in 0..batch.rows {
                let seg = batch.segments[dr] as usize;
                for slot in dr * w..(dr + 1) * w {
                    if batch.mask[slot] != 0.0 {
                        recovered.push((
                            batch.segment_rows[seg],
                            batch.items[slot],
                            batch.values[slot],
                        ));
                    }
                }
            }
        }
        let mut expected: Vec<(u32, u32, f32)> = Vec::new();
        for r in 0..m.rows {
            let take = m.row_len(r).min(capacity); // over-long rows truncate
            for k in 0..take {
                expected.push((r as u32, m.row_indices(r)[k], m.row_values(r)[k]));
            }
        }
        recovered.sort_by(|a, b| a.partial_cmp(b).unwrap());
        expected.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(recovered, expected, "seed {seed}: batching lost/duplicated slots");
    }
}

/// PROPERTY: the paper's collective-based sharded_gather reconstructs the
/// direct gather for any table/shard-count/id multiset, in both storages.
#[test]
fn prop_sharded_gather_reconstructs() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(1000 + seed);
        let rows = 1 + rng.range(0, 100);
        let dim = 1 + rng.range(0, 24);
        let shards = 1 + rng.range(0, 12);
        let storage = if seed % 2 == 0 { Storage::F32 } else { Storage::Bf16 };
        let table = ShardedTable::randn(rows, dim, shards, storage, &mut rng);
        let n_ids = rng.range(0, 50);
        let ids: Vec<u32> = (0..n_ids).map(|_| rng.range(0, rows) as u32).collect();
        let stats = CommStats::new();
        let got = sharded_gather(&table, &ids, &stats);
        let want = table.gather(&ids);
        assert!(
            got.max_abs_diff(&want) == 0.0,
            "seed {seed}: sharded gather diverged (shards={shards}, {storage:?})"
        );
    }
}

/// PROPERTY: scatter-then-gather round-trips through any sharding, up to
/// storage rounding (exact in f32, bf16-rounded otherwise).
#[test]
fn prop_scatter_gather_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(2000 + seed);
        let rows = 2 + rng.range(0, 80);
        let dim = 1 + rng.range(0, 16);
        let shards = 1 + rng.range(0, 9);
        let mut table = ShardedTable::zeros(rows, dim, shards, Storage::F32);
        // Distinct ids (scatter overwrite semantics are per-row).
        let mut ids: Vec<u32> = (0..rows as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(1 + rng.range(0, rows));
        let data = Mat::randn(ids.len(), dim, 1.0, &mut rng);
        let stats = CommStats::new();
        sharded_scatter(&mut table, &ids, &data, &stats);
        let got = sharded_gather(&table, &ids, &stats);
        assert!(got.max_abs_diff(&data) == 0.0, "seed {seed}: roundtrip failed");
    }
}

/// PROPERTY: shard ranges are a contiguous partition and `shard_of` is
/// consistent with them for any (rows, shards).
#[test]
fn prop_shard_routing_consistent() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(3000 + seed);
        let rows = 1 + rng.range(0, 500);
        let shards = 1 + rng.range(0, 40);
        let table = ShardedTable::zeros(rows, 4, shards, Storage::F32);
        let mut covered = 0;
        for s in 0..table.num_shards() {
            let r = table.range(s);
            assert_eq!(r.start, covered, "seed {seed}: gap in shard ranges");
            covered = r.end;
        }
        assert_eq!(covered, rows, "seed {seed}: shards do not cover all rows");
        for row in 0..rows {
            assert!(
                table.range(table.shard_of(row)).contains(row),
                "seed {seed}: routing broken for row {row}"
            );
        }
    }
}

/// PROPERTY: strong-generalization split — train rows and test rows are
/// disjoint, every test row's history+holdout equals its original links,
/// and no training data leaks from test rows.
#[test]
fn prop_split_leak_free() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(4000 + seed);
        let m = random_csr(&mut rng);
        let split = split_strong_generalization(&m, 0.8, 0.25, seed);
        for tr in &split.test {
            assert_eq!(
                split.train.row_len(tr.row as usize),
                0,
                "seed {seed}: test row {} leaked into train",
                tr.row
            );
            let mut all: Vec<u32> =
                tr.history.iter().map(|&(c, _)| c).chain(tr.holdout.iter().copied()).collect();
            all.sort_unstable();
            assert_eq!(
                all,
                m.row_indices(tr.row as usize),
                "seed {seed}: history+holdout != original row"
            );
            assert!(!tr.holdout.is_empty(), "seed {seed}: empty holdout");
            assert!(!tr.history.is_empty(), "seed {seed}: empty history");
        }
        // Conservation: train nnz + test links == original nnz (minus
        // skipped single-link test rows).
        let test_links: usize =
            split.test.iter().map(|t| t.history.len() + t.holdout.len()).sum();
        assert!(split.train.nnz() + test_links <= m.nnz(), "seed {seed}: links created");
    }
}

/// PROPERTY: CSR transpose is an involution and preserves every entry.
#[test]
fn prop_transpose_involution() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(5000 + seed);
        let m = random_csr(&mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt, "seed {seed}: transpose not involutive");
    }
}
