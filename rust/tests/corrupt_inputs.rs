//! Hostile-input hardening: corrupt, truncated and lying binary files
//! must surface as `Err` — never a panic, and never an allocation larger
//! than what the stream length actually supports. Covers all five
//! on-disk formats: `ALXCSR01`, `ALXCSR02`, the shard-major `ALXBANK01`
//! matrix bank, the `ALXTAB01` embedding-table bank and the `ALXCKPT2`
//! checkpoint.

use alx::als::checkpoint::{load_limited, save, CheckpointMeta, EngineMeta};
use alx::als::TrainConfig;
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::sharding::{ShardedTable, Storage, TableBank};
use alx::sparse::{write_chunked, ChunkedReader, Csr, CsrBank, ShardedCsr};
use alx::util::{durable, Pcg64};

fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for r in 0..rows as u32 {
        let len = rng.range(0, 8);
        let mut seen = std::collections::HashSet::new();
        while seen.len() < len {
            seen.insert(rng.range(0, cols) as u32);
        }
        for c in seen {
            t.push((r, c, (r as f32 + 1.0) * 0.5));
        }
    }
    Csr::from_coo(rows, cols, &t)
}

fn csr01_bytes(m: &Csr) -> Vec<u8> {
    let mut buf = Vec::new();
    m.write_to(&mut buf).unwrap();
    buf
}

fn csr02_bytes(m: &Csr, chunk_rows: usize) -> Vec<u8> {
    let mut buf = Vec::new();
    write_chunked(m, &mut buf, chunk_rows).unwrap();
    buf
}

fn read_csr02(buf: &[u8]) -> std::io::Result<Csr> {
    ChunkedReader::new(buf, buf.len() as u64, 0)?.read_all()
}

// ---------------------------------------------------------------- ALXCSR01

#[test]
fn csr01_truncation_at_every_byte_is_an_error() {
    let m = sample_matrix(13, 9, 1);
    let buf = csr01_bytes(&m);
    // Every prefix — which includes every section boundary (magic, header,
    // indptr, indices, values) — must fail cleanly.
    for cut in 0..buf.len() {
        let with_len = Csr::read_from_limited(&mut &buf[..cut], Some(cut as u64));
        assert!(with_len.is_err(), "bounded read accepted truncation at {cut}");
        let unbounded = Csr::read_from(&mut &buf[..cut]);
        assert!(unbounded.is_err(), "unbounded read accepted truncation at {cut}");
    }
    // The untruncated buffer still loads both ways.
    assert_eq!(Csr::read_from(&mut &buf[..]).unwrap(), m);
    assert_eq!(Csr::read_from_limited(&mut &buf[..], Some(buf.len() as u64)).unwrap(), m);
}

#[test]
fn csr01_oversized_nnz_header_fails_before_allocating() {
    // Header claims ~10^15 entries; the stream has 6 bytes of body. The
    // bounded path must reject on the length check; the unbounded path
    // must hit EOF after at most one staging block.
    let mut buf = Vec::new();
    buf.extend_from_slice(b"ALXCSR01");
    buf.extend_from_slice(&4u64.to_le_bytes()); // rows
    buf.extend_from_slice(&4u64.to_le_bytes()); // cols
    buf.extend_from_slice(&(1u64 << 50).to_le_bytes()); // nnz
    buf.extend_from_slice(&[0u8; 6]);
    let err = Csr::read_from_limited(&mut &buf[..], Some(buf.len() as u64)).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
    assert!(Csr::read_from(&mut &buf[..]).is_err());
}

#[test]
fn csr01_oversized_rows_header_fails_before_allocating() {
    let mut buf = Vec::new();
    buf.extend_from_slice(b"ALXCSR01");
    buf.extend_from_slice(&u64::MAX.to_le_bytes()); // rows: absurd
    buf.extend_from_slice(&4u64.to_le_bytes()); // cols
    buf.extend_from_slice(&0u64.to_le_bytes()); // nnz
    assert!(Csr::read_from_limited(&mut &buf[..], Some(buf.len() as u64)).is_err());
    assert!(Csr::read_from(&mut &buf[..]).is_err());
}

#[test]
fn csr01_non_monotonic_indptr_rejected() {
    // Handcrafted 2x2 matrix with indptr [0, 2, 1]: entry 2 drops below
    // its predecessor while the final value still "exists", so only the
    // monotonicity check can catch it. Body is sized so the length check
    // passes.
    let mut buf = Vec::new();
    buf.extend_from_slice(b"ALXCSR01");
    for v in [2u64, 2, 2] {
        buf.extend_from_slice(&v.to_le_bytes()); // rows, cols, nnz
    }
    for v in [0u64, 2, 1] {
        buf.extend_from_slice(&v.to_le_bytes()); // non-monotonic indptr
    }
    buf.extend_from_slice(&0u32.to_le_bytes()); // indices
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&1.0f32.to_le_bytes()); // values
    buf.extend_from_slice(&1.0f32.to_le_bytes());
    for stream_len in [None, Some(buf.len() as u64)] {
        let err = Csr::read_from_limited(&mut &buf[..], stream_len).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert!(err.to_string().contains("monotonic"), "{err}");
    }
}

#[test]
fn csr01_out_of_range_column_rejected() {
    let m = sample_matrix(13, 9, 3);
    assert!(m.nnz() > 0);
    let mut buf = csr01_bytes(&m);
    let idx0 = 32 + (m.rows + 1) * 8;
    buf[idx0..idx0 + 4].copy_from_slice(&1_000_000u32.to_le_bytes());
    let err = Csr::read_from(&mut &buf[..]).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
}

// ---------------------------------------------------------------- ALXCSR02

#[test]
fn csr02_chunk_boundary_fuzz_roundtrip() {
    // Round-trip across chunk sizes that hit every boundary alignment
    // (1-row chunks, sizes that divide rows, sizes that do not, one chunk).
    let m = sample_matrix(37, 17, 4);
    for chunk_rows in 1..=40 {
        let buf = csr02_bytes(&m, chunk_rows);
        let m2 = read_csr02(&buf).unwrap();
        assert_eq!(m, m2, "chunk_rows = {chunk_rows}");
    }
}

#[test]
fn csr02_truncation_at_every_byte_is_an_error() {
    let m = sample_matrix(21, 13, 5);
    let buf = csr02_bytes(&m, 6);
    for cut in 0..buf.len() {
        assert!(
            ChunkedReader::new(&buf[..cut], cut as u64, 0)
                .and_then(|r| r.read_all())
                .is_err(),
            "truncation at byte {cut}/{} accepted",
            buf.len()
        );
    }
}

#[test]
fn csr02_single_byte_corruption_never_panics() {
    // Flip one byte at every position. Header/chunk-structure flips must
    // error; flips inside the values payload may legally decode to other
    // floats, but nothing may panic and a successful decode must keep the
    // validated shape.
    let m = sample_matrix(17, 7, 6);
    let clean = csr02_bytes(&m, 5);
    for pos in 0..clean.len() {
        let mut buf = clean.clone();
        buf[pos] ^= 0x5a;
        match read_csr02(&buf) {
            Err(_) => {}
            Ok(m2) => {
                // The decode may legally succeed (e.g. a flipped value
                // byte), but the structural invariants must hold.
                assert_eq!(m2.indptr.len(), m2.rows + 1, "byte {pos}");
                assert_eq!(*m2.indptr.last().unwrap(), m2.nnz(), "byte {pos}");
                assert!(
                    m2.indices.iter().all(|&c| (c as usize) < m2.cols),
                    "byte {pos}: out-of-range column survived"
                );
            }
        }
    }
}

#[test]
fn csr02_lying_chunk_nnz_rejected() {
    let m = sample_matrix(12, 8, 7);
    let mut buf = csr02_bytes(&m, 12); // single chunk
    // chunk_nnz lives after file header (40) + chunk magic (4) + row_start
    // (8) + row_count (8).
    let off = 40 + 4 + 16;
    buf[off..off + 8].copy_from_slice(&(m.nnz() as u64 + 5).to_le_bytes());
    assert!(read_csr02(&buf).is_err());
}

#[test]
fn csr02_budget_violation_is_an_error_not_an_allocation() {
    let m = sample_matrix(48, 16, 8);
    let buf = csr02_bytes(&m, 48); // one big chunk
    let err = ChunkedReader::new(&buf[..], buf.len() as u64, 64)
        .and_then(|mut r| r.next_chunk().map(|_| ()))
        .unwrap_err();
    assert!(err.to_string().contains("budget"), "{err}");
}

// --------------------------------------------------------------- ALXBANK01

/// Write a valid bank for `m` and return its raw bytes (via a scratch
/// file — banks are opened by mmap, not from a stream).
fn bank_bytes(m: &Csr, shards: usize, tag: &str) -> Vec<u8> {
    let path = bank_scratch(tag);
    ShardedCsr::from_csr(m, shards).spill_to_bank(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    bytes
}

fn bank_scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("alx_corrupt_bank_{}_{}.alxbank", tag, std::process::id()))
}

/// `CsrBank::open` on a raw byte image (round-tripped through a file).
fn open_bank(bytes: &[u8], tag: &str) -> std::io::Result<CsrBank> {
    let path = bank_scratch(tag);
    std::fs::write(&path, bytes).unwrap();
    let out = CsrBank::open(&path);
    let _ = std::fs::remove_file(&path);
    out
}

#[test]
fn bank_roundtrips_clean() {
    let m = sample_matrix(33, 14, 20);
    let bytes = bank_bytes(&m, 5, "clean");
    let bank = open_bank(&bytes, "clean").unwrap();
    let reference = ShardedCsr::from_csr(&m, 5);
    for p in 0..5 {
        assert_eq!(&bank.load_shard(p), reference.piece(p).as_ref());
    }
}

#[test]
fn bank_truncation_at_every_byte_is_an_error() {
    let m = sample_matrix(21, 9, 21);
    let bytes = bank_bytes(&m, 4, "trunc");
    for cut in 0..bytes.len() {
        assert!(
            open_bank(&bytes[..cut], "trunc_cut").is_err(),
            "truncation at byte {cut}/{} accepted",
            bytes.len()
        );
    }
}

#[test]
fn bank_lying_header_fails_before_allocating() {
    let m = sample_matrix(16, 8, 22);
    let clean = bank_bytes(&m, 4, "lying");
    // A shard count in the billions must fail the directory-fits-the-file
    // check, not allocate a billion-entry directory.
    let mut buf = clean.clone();
    buf[40..48].copy_from_slice(&(1u64 << 40).to_le_bytes()); // num_shards
    assert!(open_bank(&buf, "lying_shards").is_err());
    // Oversized nnz: the directory totals no longer match.
    let mut buf = clean.clone();
    buf[32..40].copy_from_slice(&(1u64 << 50).to_le_bytes()); // nnz
    assert!(open_bank(&buf, "lying_nnz").is_err());
    // Oversized rows: the uniform partition no longer matches the
    // directory's per-shard row counts.
    let mut buf = clean.clone();
    buf[16..24].copy_from_slice(&(m.rows as u64 * 1000).to_le_bytes()); // rows
    assert!(open_bank(&buf, "lying_rows").is_err());
}

#[test]
fn bank_corrupt_shard_offsets_rejected() {
    let m = sample_matrix(16, 8, 23);
    let clean = bank_bytes(&m, 4, "offsets");
    // Directory entry 1 starts at byte 48 + 24; shift its offset.
    let off_pos = 48 + 24;
    let good = u64::from_le_bytes(clean[off_pos..off_pos + 8].try_into().unwrap());
    for bad in [0u64, good + 8, good.wrapping_sub(8), u64::MAX] {
        let mut buf = clean.clone();
        buf[off_pos..off_pos + 8].copy_from_slice(&bad.to_le_bytes());
        assert!(open_bank(&buf, "offsets_bad").is_err(), "offset {bad} accepted");
    }
}

#[test]
fn bank_single_byte_corruption_never_panics() {
    // Flip one byte at every position: structural corruption must error at
    // open; flips confined to the values payload may legally decode, but
    // the decoded shards must still satisfy every CSR invariant.
    let m = sample_matrix(15, 7, 24);
    let clean = bank_bytes(&m, 3, "flip");
    for pos in 0..clean.len() {
        let mut buf = clean.clone();
        buf[pos] ^= 0x5a;
        if let Ok(bank) = open_bank(&buf, "flip_one") {
            for p in 0..bank.num_shards() {
                let s = bank.load_shard(p);
                assert_eq!(s.indptr.len(), s.rows + 1, "byte {pos}");
                assert_eq!(*s.indptr.last().unwrap(), s.nnz(), "byte {pos}");
                assert!(
                    s.indices.iter().all(|&c| (c as usize) < s.cols),
                    "byte {pos}: out-of-range column survived"
                );
            }
        }
    }
}

// ---------------------------------------------------------------- ALXTAB01

/// Write a valid table bank and return its raw bytes (via a scratch file
/// — table banks are opened by mmap, not from a stream).
fn tab_bytes(rows: usize, dim: usize, shards: usize, storage: Storage, tag: &str) -> Vec<u8> {
    let mut rng = Pcg64::new(rows as u64 ^ 0x7ab5);
    let t = ShardedTable::randn(rows, dim, shards, storage, &mut rng);
    let path = tab_scratch(tag);
    t.spill_to_bank(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    bytes
}

fn tab_scratch(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("alx_corrupt_tab_{}_{}.alxtab", tag, std::process::id()))
}

/// `TableBank::open` on a raw byte image (round-tripped through a file).
fn open_tab(bytes: &[u8], tag: &str) -> std::io::Result<TableBank> {
    let path = tab_scratch(tag);
    std::fs::write(&path, bytes).unwrap();
    let out = TableBank::open(&path);
    let _ = std::fs::remove_file(&path);
    out
}

#[test]
fn tab_roundtrips_clean() {
    for storage in [Storage::F32, Storage::Bf16] {
        let bytes = tab_bytes(21, 4, 3, storage, "clean");
        let bank = open_tab(&bytes, "clean_open").unwrap();
        assert_eq!(bank.rows, 21);
        assert_eq!(bank.dim, 4);
        assert_eq!(bank.num_shards(), 3);
        assert_eq!(bank.storage(), storage);
        for p in 0..3 {
            let (start, end) = bank.shard_range(p);
            assert_eq!(bank.load_shard(p).elems(), (end - start) * 4);
        }
    }
}

#[test]
fn tab_truncation_at_every_byte_is_an_error() {
    let bytes = tab_bytes(13, 3, 4, Storage::Bf16, "trunc");
    for cut in 0..bytes.len() {
        assert!(
            open_tab(&bytes[..cut], "trunc_cut").is_err(),
            "truncation at byte {cut}/{} accepted",
            bytes.len()
        );
    }
}

#[test]
fn tab_lying_header_fails_before_allocating() {
    let clean = tab_bytes(16, 4, 4, Storage::F32, "lying");
    // Header layout: magic 16 | rows 16..24 | dim 24..32 | shards 32..40
    // | elem 40..48.
    // A shard count in the billions must fail the directory-fits-the-file
    // check, not drive a huge allocation or read.
    let mut buf = clean.clone();
    buf[32..40].copy_from_slice(&(1u64 << 40).to_le_bytes());
    assert!(open_tab(&buf, "lying_shards").is_err());
    // Oversized rows: the partition no longer matches the directory.
    let mut buf = clean.clone();
    buf[16..24].copy_from_slice(&(16u64 * 1000).to_le_bytes());
    assert!(open_tab(&buf, "lying_rows").is_err());
    // Oversized dim: segments run past the end of the file.
    let mut buf = clean.clone();
    buf[24..32].copy_from_slice(&4096u64.to_le_bytes());
    assert!(open_tab(&buf, "lying_dim").is_err());
    // An element size that is neither bf16 nor f32.
    let mut buf = clean.clone();
    buf[40..48].copy_from_slice(&8u64.to_le_bytes());
    assert!(open_tab(&buf, "lying_elem").is_err());
    // Zero dim.
    let mut buf = clean.clone();
    buf[24..32].copy_from_slice(&0u64.to_le_bytes());
    assert!(open_tab(&buf, "zero_dim").is_err());
}

#[test]
fn tab_corrupt_directory_offsets_rejected() {
    let clean = tab_bytes(16, 4, 4, Storage::F32, "offsets");
    // Directory entry 1 starts at byte 48 + 16; shift its offset.
    let off_pos = 48 + 16;
    let good = u64::from_le_bytes(clean[off_pos..off_pos + 8].try_into().unwrap());
    for bad in [0u64, good + 8, good.wrapping_sub(8), u64::MAX] {
        let mut buf = clean.clone();
        buf[off_pos..off_pos + 8].copy_from_slice(&bad.to_le_bytes());
        assert!(open_tab(&buf, "offsets_bad").is_err(), "offset {bad} accepted");
    }
}

// ---------------------------------------------------------------- ALXCKPT2

/// A valid checkpoint image: two tables, a 2-entry objective log (one
/// recorded objective, one skipped epoch) and one recall record.
fn ckpt_bytes(storage: Storage) -> Vec<u8> {
    let mut rng = Pcg64::new(0xc47);
    let users = ShardedTable::randn(14, 3, 2, storage, &mut rng);
    let items = ShardedTable::randn(11, 3, 2, storage, &mut rng);
    let meta = CheckpointMeta {
        epoch: 4,
        dim: 3,
        users: 14,
        items: 11,
        storage_bf16: storage == Storage::Bf16,
    };
    let mut buf = Vec::new();
    save(
        &mut buf,
        &meta,
        &users,
        &items,
        &[(1, Some(-12.5)), (2, None)],
        &[(2, 20, 0.5)],
        EngineMeta::default(),
    )
    .unwrap();
    buf
}

#[test]
fn ckpt_truncation_at_every_byte_is_an_error() {
    for storage in [Storage::F32, Storage::Bf16] {
        let clean = ckpt_bytes(storage);
        assert!(load_limited(&mut &clean[..], 2, Some(clean.len() as u64)).is_ok());
        let mut legacy_boundary_ok = 0;
        for cut in 0..clean.len() {
            match load_limited(&mut &clean[..cut], 2, Some(cut as u64)) {
                Err(_) => {}
                Ok(ck) => {
                    // The two legal truncation points: exactly at the start
                    // of a trailing section ("RCLG" recall log / "ENGM"
                    // engine identity), both optional for legacy-file
                    // compatibility. Everything before the cut must have
                    // parsed intact, and a cut before the recall section
                    // must also drop the engine record.
                    assert!(ck.engine.is_none(), "cut {cut}");
                    if !ck.recall_log.is_empty() {
                        assert_eq!(cut, clean.len() - 9, "cut {cut}");
                    }
                    assert_eq!(ck.meta.epoch, 4, "cut {cut}");
                    assert_eq!(ck.objective_log.len(), 2, "cut {cut}");
                    legacy_boundary_ok += 1;
                }
            }
        }
        assert!(
            legacy_boundary_ok <= 2,
            "{legacy_boundary_ok} truncation points accepted ({storage:?})"
        );
    }
}

#[test]
fn ckpt_single_byte_corruption_never_panics() {
    // Flip one byte at every position. Structural damage must error;
    // flips confined to table elements legally decode to other numbers,
    // but nothing may panic and the result must stay self-consistent.
    let clean = ckpt_bytes(Storage::Bf16);
    for pos in 0..clean.len() {
        let mut buf = clean.clone();
        buf[pos] ^= 0x5a;
        if let Ok(ck) = load_limited(&mut &buf[..], 2, Some(buf.len() as u64)) {
            assert_eq!(ck.users.rows as u64, ck.meta.users, "byte {pos}");
            assert_eq!(ck.items.rows as u64, ck.meta.items, "byte {pos}");
            assert_eq!(
                ck.users.to_dense().data.len(),
                ck.meta.users as usize * ck.meta.dim as usize,
                "byte {pos}: users table shape drifted"
            );
            assert_eq!(
                ck.items.to_dense().data.len(),
                ck.meta.items as usize * ck.meta.dim as usize,
                "byte {pos}: items table shape drifted"
            );
        }
    }
}

#[test]
fn ckpt_lying_header_fails_before_allocating() {
    // A header claiming ~10^15-row tables over a 61-byte stream must be
    // rejected by the length check, not drive a petabyte allocation.
    let mut buf = Vec::new();
    buf.extend_from_slice(b"ALXCKPT2");
    buf.extend_from_slice(&0u64.to_le_bytes()); // epoch
    buf.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
    buf.extend_from_slice(&(1u64 << 50).to_le_bytes()); // users
    buf.extend_from_slice(&(1u64 << 50).to_le_bytes()); // items
    buf.push(0); // storage f32
    buf.extend_from_slice(&0u64.to_le_bytes()); // objective log len
    let err = load_limited(&mut &buf[..], 2, Some(buf.len() as u64)).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("table data"), "{err}");
}

#[test]
fn failed_checkpoint_save_preserves_previous_good_one() {
    // A save that cannot even stage its tmp file (here: the staging path
    // is occupied by a directory) must leave the previously published
    // checkpoint byte-for-byte intact — corrupting the only good
    // checkpoint while failing to write its replacement is the one
    // unrecoverable outcome.
    let path = std::env::temp_dir()
        .join(format!("alx_corrupt_ckpt_keep_{}.ckpt", std::process::id()));
    let source = InMemorySource::new("corrupt-keep", sample_matrix(30, 20, 40));
    let cfg = AlxConfig {
        cores: 2,
        train: TrainConfig {
            dim: 6,
            epochs: 4,
            batch_rows: 16,
            batch_width: 4,
            threads: 1,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    };
    let mut s = TrainSession::new(&source, cfg).unwrap();
    s.step().unwrap();
    s.checkpoint(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    let tmp = durable::tmp_path(&path);
    std::fs::create_dir_all(&tmp).unwrap();
    s.step().unwrap();
    let r = s.checkpoint(&path);
    assert!(r.is_err(), "checkpoint save must fail when staging is impossible");
    assert_eq!(std::fs::read(&path).unwrap(), good, "previous good checkpoint clobbered");

    // Once the obstruction clears, the next save publishes new state.
    std::fs::remove_dir_all(&tmp).unwrap();
    s.checkpoint(&path).unwrap();
    assert_ne!(std::fs::read(&path).unwrap(), good, "second save published stale state");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tab_single_byte_corruption_never_panics() {
    // Flip one byte at every position: structural corruption must error
    // at open; flips inside the element payload legally decode to other
    // numbers (any bit pattern is a valid element), but nothing may
    // panic and the decoded shapes must stay exact.
    let clean = tab_bytes(15, 3, 3, Storage::Bf16, "flip");
    for pos in 0..clean.len() {
        let mut buf = clean.clone();
        buf[pos] ^= 0x5a;
        if let Ok(bank) = open_tab(&buf, "flip_one") {
            for p in 0..bank.num_shards() {
                let (start, end) = bank.shard_range(p);
                assert_eq!(
                    bank.load_shard(p).elems(),
                    (end - start) * bank.dim,
                    "byte {pos}: shard {p} shape drifted"
                );
            }
        }
    }
}
