//! End-to-end integration: generate → split → distributed train → eval,
//! exercising the full native pipeline the way `examples/webgraph_e2e.rs`
//! does, plus failure-injection and precision-collapse integration checks.

use alx::als::{PrecisionPolicy, TrainConfig, Trainer};
use alx::config::AlxConfig;
use alx::coordinator::Coordinator;
use alx::eval::EvalConfig;
use alx::sparse::split_strong_generalization;
use alx::topo::Topology;
use alx::webgraph::{generate, Variant, VariantSpec};

fn base_cfg() -> AlxConfig {
    AlxConfig {
        variant: Variant::InDense,
        scale: 0.0012, // ~600 nodes
        cores: 4,
        data_seed: 17,
        train: TrainConfig {
            dim: 32,
            epochs: 6,
            lambda: 0.05,
            alpha: 0.005,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: true,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

#[test]
fn full_pipeline_reaches_good_recall() {
    let mut coord = Coordinator::prepare(base_cfg()).unwrap();
    let report = coord.run().unwrap();
    let r20 = report.recalls.iter().find(|r| r.k == 20).unwrap().recall;
    let r50 = report.recalls.iter().find(|r| r.k == 50).unwrap().recall;
    // In-dense is the paper's easiest variant (0.965/0.974); our synthetic
    // twin at tiny scale should still clear a high bar.
    assert!(r20 > 0.6, "recall@20 = {r20}");
    assert!(r50 > 0.6, "recall@50 = {r50}");
    // ALS objective decreases.
    let objs: Vec<f64> = report.history.iter().map(|h| h.objective.unwrap()).collect();
    assert!(objs.last().unwrap() < objs.first().unwrap());
}

#[test]
fn sparse_variant_is_harder_than_dense() {
    // Table 2's qualitative ordering: dense >> sparse recall.
    let dense = {
        let mut coord = Coordinator::prepare(base_cfg()).unwrap();
        coord.run().unwrap().recalls[0].recall
    };
    let sparse = {
        let mut cfg = base_cfg();
        cfg.variant = Variant::Sparse; // full-crawl sparse: noisy
        cfg.scale = 0.0000018; // similar node count
        let mut coord = Coordinator::prepare(cfg).unwrap();
        coord.run().unwrap().recalls[0].recall
    };
    assert!(
        dense > sparse + 0.1,
        "dense ({dense}) should clearly beat sparse ({sparse})"
    );
}

#[test]
fn naive_bf16_underperforms_mixed_at_low_lambda() {
    // Figure 4 as an integration property: at low λ the naive-bf16 run
    // must end up clearly worse than mixed (collapse or degradation),
    // while mixed stays close to f32.
    let spec = VariantSpec::preset(Variant::InDense).scaled(0.0012);
    let graph = generate(&spec, 23);
    let split = split_strong_generalization(&graph.adjacency, 0.9, 0.25, 5);
    let mut finals = std::collections::HashMap::new();
    for precision in [PrecisionPolicy::F32, PrecisionPolicy::Mixed, PrecisionPolicy::NaiveBf16] {
        let cfg = TrainConfig {
            dim: 32,
            epochs: 6,
            lambda: 1e-4, // low regularization — the collapse regime
            alpha: 1e-3,  // (α·G also regularizes; keep it low too)
            precision,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: false,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&split.train, cfg, Topology::new(2)).unwrap();
        tr.fit().unwrap();
        let recalls = alx::eval::evaluate(&tr, &split.test, &EvalConfig::default());
        finals.insert(precision.name(), recalls[0].recall);
    }
    let f32r = finals["f32"];
    let mixed = finals["mixed"];
    let naive = finals["naive-bf16"];
    assert!(
        naive < mixed - 0.1,
        "naive-bf16 ({naive}) should collapse below mixed ({mixed})"
    );
    assert!(
        (mixed - f32r).abs() < 0.15,
        "mixed ({mixed}) should track f32 ({f32r})"
    );
}

#[test]
fn empty_training_matrix_is_handled() {
    let m = alx::sparse::Csr::from_coo(10, 10, &[]);
    let cfg = TrainConfig {
        dim: 4,
        epochs: 1,
        batch_rows: 8,
        batch_width: 4,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&m, cfg, Topology::new(2)).unwrap();
    // No batches → pure regularizer world; must not panic.
    let stats = tr.run_epoch().unwrap();
    assert!(stats.objective.unwrap() >= 0.0);
}

#[test]
fn single_core_topology_works() {
    let mut cfg = base_cfg();
    cfg.cores = 1;
    cfg.train.epochs = 2;
    let mut coord = Coordinator::prepare(cfg).unwrap();
    let report = coord.run().unwrap();
    assert_eq!(report.history.len(), 2);
    // Single core → no cross-core traffic needed, but the collectives are
    // still issued (degenerate ring).
    assert!(report.comm_bytes_per_epoch > 0);
}

#[test]
fn many_cores_more_than_rows() {
    // Degenerate sharding: more cores than rows must still work.
    let m = alx::sparse::Csr::from_coo(
        6,
        6,
        &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 5, 1.0), (5, 0, 1.0)],
    );
    let cfg = TrainConfig {
        dim: 4,
        epochs: 2,
        batch_rows: 8,
        batch_width: 4,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&m, cfg, Topology::new(16)).unwrap();
    tr.fit().unwrap();
}
