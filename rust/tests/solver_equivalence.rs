//! The iALS++ engine contract: the subspace solver is a drop-in
//! [`SolveEngine`] with the same determinism guarantees as the direct
//! engine — bitwise identical across thread counts, across
//! resident/spilled storage, and across a checkpoint/resume — while both
//! engines clear the quickstart recall bar, and the (optionally SIMD)
//! blocked gramian kernel is bitwise identical to its scalar reference.

use alx::als::{EngineKind, EpochStats, TrainConfig};
use alx::config::AlxConfig;
use alx::coordinator::{grid_search, GridSpec, TrainSession};
use alx::data::InMemorySource;
use alx::linalg::{syrk_rankk_upper, syrk_rankk_upper_scalar};
use alx::prelude::*;
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize) -> AlxConfig {
    AlxConfig {
        cores: 8,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            engine: EngineKind::IalsPp,
            block_dim: 4,
            batch_rows: 16,
            batch_width: 4,
            threads,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_solver_eq_{}_{}", tag, std::process::id()))
}

/// Timing-free fingerprint of an epoch.
fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

type RunFingerprint = (Vec<(usize, Option<u64>, u64)>, Vec<f32>, Vec<f32>);

fn run(mut s: TrainSession) -> RunFingerprint {
    let report = s.run().unwrap();
    (
        report.history.iter().map(fingerprint).collect(),
        s.trainer.w.to_dense().data,
        s.trainer.h.to_dense().data,
    )
}

#[test]
fn ialspp_is_bitwise_identical_across_thread_counts() {
    let m = community_matrix(80, 48, 3);
    let serial = {
        let source = InMemorySource::new("community", m.clone());
        run(TrainSession::new(&source, cfg(3, 1)).unwrap())
    };
    for threads in [2usize, 4] {
        let source = InMemorySource::new("community", m.clone());
        let fp = run(TrainSession::new(&source, cfg(3, threads)).unwrap());
        assert_eq!(fp.0, serial.0, "objective history differs (threads={threads})");
        assert_eq!(fp.1, serial.1, "W differs (threads={threads})");
        assert_eq!(fp.2, serial.2, "H differs (threads={threads})");
    }
}

#[test]
fn ialspp_spilled_run_is_bitwise_identical_to_resident() {
    // Matrix shards in ALXBANK01 banks *and* W/H in ALXTAB01 banks
    // (`--spill --spill-model`), demand-paged: same bits as resident.
    let m = community_matrix(80, 48, 5);
    let resident = {
        let source = InMemorySource::new("community", m.clone());
        run(TrainSession::new(&source, cfg(3, 4)).unwrap())
    };
    let dir = tmp("spill");
    let spilled = {
        let mut c = cfg(3, 4);
        c.data_spill = true;
        c.model_spill = true;
        c.spill_dir = dir.display().to_string();
        let source = InMemorySource::new("community", m.clone());
        run(TrainSession::new(&source, c).unwrap())
    };
    assert_eq!(spilled.0, resident.0, "objective history differs");
    assert_eq!(spilled.1, resident.1, "W differs");
    assert_eq!(spilled.2, resident.2, "H differs");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ialspp_checkpoint_resume_is_bitwise() {
    let m = community_matrix(80, 48, 7);
    let ckpt = tmp("resume.ckpt");
    let straight = {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(4, 4)).unwrap();
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        s
    };

    // Interrupted after epoch 2, resumed in a fresh session at a
    // different thread count.
    {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(4, 4)).unwrap();
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let source = InMemorySource::new("community", m.clone());
    let mut resumed = TrainSession::resume_with(&ckpt, &source, cfg(4, 1), None).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 2);
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    assert_eq!(straight.trainer.w.to_dense().data, resumed.trainer.w.to_dense().data);
    assert_eq!(straight.trainer.h.to_dense().data, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn resume_rejects_checkpoint_from_the_other_engine() {
    let m = community_matrix(80, 48, 9);
    let ckpt = tmp("mismatch.ckpt");
    {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(4, 2)).unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let mut qr_cfg = cfg(4, 2);
    qr_cfg.train.engine = EngineKind::Qr;
    let source = InMemorySource::new("community", m.clone());
    let err = TrainSession::resume_with(&ckpt, &source, qr_cfg, None)
        .err()
        .expect("qr config must reject an ialspp checkpoint");
    assert!(err.to_string().contains("engine mismatch"), "{err}");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn both_engines_clear_the_quickstart_grid_bar() {
    // The tiny quickstart grid (2 λ cells) must reach the e2e recall bar
    // under either engine; the subspace solves may not cost recall.
    let mut best = Vec::new();
    for engine in EngineKind::ALL {
        let base = AlxConfig {
            variant: Variant::InDense,
            scale: 0.0012,
            cores: 4,
            data_seed: 17,
            train: TrainConfig {
                dim: 32,
                epochs: 5,
                alpha: 0.005,
                engine,
                block_dim: 8,
                batch_rows: 64,
                batch_width: 8,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        };
        let spec = GridSpec { lambdas: vec![5e-2, 1e-3], alphas: vec![5e-3], select_k: 20 };
        let points = grid_search(&base, &spec).unwrap();
        assert!(
            points[0].recall_at_20 > 0.6,
            "{} best grid cell recall@20 = {}",
            engine.name(),
            points[0].recall_at_20
        );
        best.push(points[0].recall_at_20);
    }
    // The subspace engine lands within a hair of the direct engine.
    assert!((best[0] - best[1]).abs() < 0.05, "qr={} ialspp={}", best[0], best[1]);
}

#[test]
fn blocked_kernel_dispatch_is_bitwise_identical_to_scalar() {
    // With `--features simd` this pits the AVX2 path against the scalar
    // reference; without it, dispatch == scalar and the test is the
    // trivial identity. CI runs both featurings.
    let mut rng = Pcg64::new(11);
    for d in [1usize, 7, 16, 33, 128] {
        for k in [1usize, 3, 16] {
            let rows: Vec<f32> = (0..k * d)
                .map(|i| {
                    // Exercise the hi == 0.0 skip path too.
                    if i % 11 == 0 {
                        0.0
                    } else {
                        rng.next_f32() - 0.5
                    }
                })
                .collect();
            let mut g_dispatch: Vec<f32> =
                (0..d * d).map(|_| rng.next_f32()).collect();
            let mut g_scalar = g_dispatch.clone();
            syrk_rankk_upper(&mut g_dispatch, d, &rows);
            syrk_rankk_upper_scalar(&mut g_scalar, d, &rows);
            assert_eq!(g_dispatch, g_scalar, "d={d} k={k}");
        }
    }
}
