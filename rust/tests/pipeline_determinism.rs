//! The pipelined multi-threaded trainer's core contract: every stage uses
//! a fixed work assignment, so the trained tables and epoch history are
//! **bitwise identical** to the serial path (`threads = 1`) for every
//! thread budget and feeder depth.

use alx::als::{PrecisionPolicy, TrainConfig, Trainer};
use alx::sparse::Csr;
use alx::topo::Topology;
use alx::util::Pcg64;

/// Two-community implicit matrix (same generator family as the unit
/// tests): every row nonempty, realistic overlap between shards.
fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(threads: usize, feed_depth: usize, precision: PrecisionPolicy) -> TrainConfig {
    TrainConfig {
        dim: 12,
        epochs: 3,
        lambda: 0.05,
        alpha: 0.01,
        batch_rows: 16,
        batch_width: 4,
        precision,
        threads,
        feed_depth,
        ..TrainConfig::default()
    }
}

/// Full training run → (W, H, per-epoch objectives, per-epoch comm bytes).
fn run(
    m: &Csr,
    cores: usize,
    threads: usize,
    feed_depth: usize,
    precision: PrecisionPolicy,
) -> (Vec<f32>, Vec<f32>, Vec<f64>, Vec<u64>) {
    let mut tr = Trainer::new(m, cfg(threads, feed_depth, precision), Topology::new(cores))
        .expect("trainer");
    let hist = tr.fit().expect("fit");
    (
        tr.w.to_dense().data,
        tr.h.to_dense().data,
        hist.iter().map(|h| h.objective.unwrap()).collect(),
        hist.iter().map(|h| h.comm_bytes).collect(),
    )
}

#[test]
fn multithreaded_is_bitwise_identical_to_serial() {
    let m = community_matrix(60, 40, 3);
    let serial = run(&m, 4, 1, 4, PrecisionPolicy::F32);
    for threads in [2usize, 4, 7] {
        let par = run(&m, 4, threads, 4, PrecisionPolicy::F32);
        assert_eq!(serial.0, par.0, "W differs at threads={threads}");
        assert_eq!(serial.1, par.1, "H differs at threads={threads}");
        assert_eq!(serial.2, par.2, "objective history differs at threads={threads}");
        assert_eq!(serial.3, par.3, "comm accounting differs at threads={threads}");
    }
}

#[test]
fn mixed_precision_is_bitwise_deterministic_too() {
    // bf16 tables, f32 accumulators — the paper's default policy must obey
    // the same contract (the fused gather widens exactly like a
    // materialized gather).
    let m = community_matrix(50, 36, 11);
    let serial = run(&m, 4, 1, 4, PrecisionPolicy::Mixed);
    let par = run(&m, 4, 4, 4, PrecisionPolicy::Mixed);
    assert_eq!(serial.0, par.0);
    assert_eq!(serial.1, par.1);
    assert_eq!(serial.2, par.2);
}

#[test]
fn feeder_depth_does_not_change_results() {
    // The BatchFeeder's backpressure depth changes stage overlap, never
    // batch content or order (the in-trainer feeder ordering contract).
    let m = community_matrix(60, 40, 5);
    let shallow = run(&m, 4, 4, 1, PrecisionPolicy::F32);
    let deep = run(&m, 4, 4, 8, PrecisionPolicy::F32);
    assert_eq!(shallow.0, deep.0);
    assert_eq!(shallow.1, deep.1);
    assert_eq!(shallow.2, deep.2);
}

#[test]
fn ordering_stable_across_feeder_chunk_boundaries() {
    // Shards larger than the feeder's row chunk (512): the producer emits
    // multiple chunks per shard, and the pipelined result must still match
    // the serial path bitwise.
    let m = community_matrix(1100, 64, 17); // 2 shards × 550 rows > 512
    let mut cfg0 = cfg(1, 4, PrecisionPolicy::F32);
    cfg0.epochs = 1;
    let mut cfg4 = cfg0.clone();
    cfg4.threads = 4;
    let mut serial = Trainer::new(&m, cfg0, Topology::new(2)).expect("trainer");
    let mut par = Trainer::new(&m, cfg4, Topology::new(2)).expect("trainer");
    serial.fit().expect("fit");
    par.fit().expect("fit");
    assert_eq!(serial.w.to_dense().data, par.w.to_dense().data);
    assert_eq!(serial.h.to_dense().data, par.h.to_dense().data);
}

#[test]
fn pipelined_pass_covers_every_shard_row() {
    // Every nonempty row must be solved exactly once per pass: after one
    // epoch, no user row may still sit at its random init.
    let m = community_matrix(50, 30, 9);
    let mut tr = Trainer::new(&m, cfg(0, 4, PrecisionPolicy::F32), Topology::new(4))
        .expect("trainer");
    let before = tr.w.to_dense();
    tr.run_epoch().expect("epoch");
    let after = tr.w.to_dense();
    for r in 0..m.rows {
        let moved = (0..before.cols).any(|c| before[(r, c)] != after[(r, c)]);
        assert!(moved, "row {r} was never solved by the pipelined pass");
    }
}
