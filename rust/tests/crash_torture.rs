//! Crash torture: kill a child `alx train` at seeded failpoints mid-epoch
//! and mid-checkpoint, resume it, and assert the finished run is bitwise
//! identical to an uninterrupted reference — for resident training and
//! for the fully out-of-core `--stream --spill --spill-model` path.
//! Published artifacts left behind by a crash must pass `alx verify`.
//!
//! The whole suite needs fault injection compiled in; run it with
//! `cargo test --features failpoints --test crash_torture`. Without the
//! feature only a stub asserting the hooks are no-ops remains.

#[cfg(not(feature = "failpoints"))]
mod stub {
    #[test]
    fn crash_torture_requires_failpoints_feature() {
        // Compiled-out build: the hooks are inert no-ops and there is
        // nothing to torture. The CI torture job builds with the feature.
        assert!(!alx::util::fault::ENABLED);
        assert!(alx::util::fault::failpoint("ckpt.write").is_ok());
    }
}

#[cfg(feature = "failpoints")]
mod torture {
    use alx::als::TrainConfig;
    use alx::config::AlxConfig;
    use alx::coordinator::TrainSession;
    use alx::data::InMemorySource;
    use alx::sparse::{Csr, ShardedCsr};
    use alx::util::{durable, fault, Pcg64};
    use std::path::{Path, PathBuf};
    use std::process::{Command, Output};
    use std::sync::Mutex;

    /// The in-process tests below share the global failpoint registry;
    /// serialize them so one test's injected faults never fire inside
    /// another. (The subprocess tests configure children via the
    /// `ALX_FAILPOINTS` env var and never touch this process's registry.)
    static FP_LOCK: Mutex<()> = Mutex::new(());

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("alx_torture_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn alx_bin(dir: &Path) -> Command {
        let mut c = Command::new(env!("CARGO_BIN_EXE_alx"));
        c.current_dir(dir);
        c.env_remove("ALX_FAILPOINTS");
        c
    }

    fn run_ok(mut c: Command) -> Output {
        let out = c.output().unwrap();
        assert!(
            out.status.success(),
            "command failed\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        out
    }

    /// Run a child train and assert the injected abort actually killed it.
    fn run_killed(mut c: Command, failpoints: &str) -> Output {
        c.env("ALX_FAILPOINTS", failpoints);
        let out = c.output().unwrap();
        assert!(
            !out.status.success(),
            "child survived ALX_FAILPOINTS='{failpoints}'\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        out
    }

    /// Small deterministic resident run: 3 epochs, checkpoint every epoch
    /// plus the final write (4 `ckpt.write` hits total).
    fn resident_train_args(ckpt: &str) -> Vec<String> {
        [
            "train", "--scale", "0.0012", "--dim", "8", "--epochs", "3", "--cores", "2",
            "--threads", "1", "--checkpoint-every", "1", "--checkpoint", ckpt,
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    }

    /// Fully out-of-core run: streamed ingestion, spilled matrix banks,
    /// spilled model banks, 2 epochs (3 `ckpt.write` hits total).
    fn spill_train_args(ckpt: &str) -> Vec<String> {
        [
            "train", "--stream", "--data", "g.alxcsr02", "--spill", "--spill-dir", "spill",
            "--spill-model", "--model-spill-dir", "spill", "--resident-shards", "1",
            "--resident-table-shards", "1", "--cores", "4", "--threads", "1", "--dim", "8",
            "--epochs", "2", "--checkpoint-every", "1", "--checkpoint", ckpt,
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    }

    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                let p = e.path();
                if p.is_dir() {
                    walk(&p, out);
                } else {
                    out.push(p);
                }
            }
        }
    }

    /// `alx verify` every published bank artifact under `dir` (skipping
    /// in-flight `*.tmp.*` staging files, which a kill may leave behind).
    fn verify_leftover_banks(dir: &Path) -> usize {
        let mut files = Vec::new();
        walk(dir, &mut files);
        let mut checked = 0;
        for p in files {
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            if name.contains(".tmp.") {
                continue;
            }
            if name.ends_with(".alxbank") || name.ends_with(".alxtab") {
                run_ok({
                    let mut c = alx_bin(dir);
                    c.arg("verify").arg(&p);
                    c
                });
                checked += 1;
            }
        }
        checked
    }

    /// Kill a resident run during its Nth checkpoint write (N seeded, and
    /// always ≥ 2 so a previous good checkpoint exists), resume from what
    /// survived, and demand a bitwise-identical final checkpoint.
    #[test]
    fn resident_kill_mid_checkpoint_resumes_bitwise() {
        let dir = scratch("resident_ckpt");
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(resident_train_args("ref.ckpt"));
            c
        });
        let reference = std::fs::read(dir.join("ref.ckpt")).unwrap();

        let mut rng = Pcg64::new(0xC0A7);
        for round in 0..2 {
            let ckpt = format!("crash_{round}.ckpt");
            let hit = rng.range(2, 5); // kill during ckpt write 2..=4 of 4
            run_killed(
                {
                    let mut c = alx_bin(&dir);
                    c.args(resident_train_args(&ckpt));
                    c
                },
                &format!("ckpt.write=hit:{hit}:abort"),
            );
            // The abort fired before this write created its tmp file, so
            // the published checkpoint is the previous complete one.
            run_ok({
                let mut c = alx_bin(&dir);
                c.arg("verify").arg(&ckpt);
                c
            });
            run_ok({
                let mut c = alx_bin(&dir);
                c.args(resident_train_args(&ckpt));
                c.arg("--resume").arg(&ckpt);
                c
            });
            let resumed = std::fs::read(dir.join(&ckpt)).unwrap();
            assert_eq!(
                resumed, reference,
                "resumed checkpoint differs from uninterrupted run (kill at ckpt.write hit {hit})"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Kill between the checkpoint's fsync and its rename: the staged tmp
    /// file is orphaned, the published checkpoint stays the previous good
    /// one, and resume still converges bitwise.
    #[test]
    fn resident_kill_at_publish_keeps_previous_checkpoint() {
        let dir = scratch("resident_publish");
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(resident_train_args("ref.ckpt"));
            c
        });
        let reference = std::fs::read(dir.join("ref.ckpt")).unwrap();

        run_killed(
            {
                let mut c = alx_bin(&dir);
                c.args(resident_train_args("crash.ckpt"));
                c
            },
            "ckpt.publish=hit:2:abort",
        );
        // Published checkpoint = epoch 1's write; the epoch-2 bytes died
        // staged in a tmp file that must never be picked up as published.
        run_ok({
            let mut c = alx_bin(&dir);
            c.arg("verify").arg("crash.ckpt");
            c
        });
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(resident_train_args("crash.ckpt"));
            c.arg("--resume").arg("crash.ckpt");
            c
        });
        assert_eq!(std::fs::read(dir.join("crash.ckpt")).unwrap(), reference);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Kill the out-of-core path mid-epoch (during a table-shard
    /// write-back, before any checkpoint exists): the crash must leave
    /// only verifiable published banks plus ignorable tmp files, and a
    /// from-scratch rerun must match the uninterrupted reference bitwise.
    #[test]
    fn spill_kill_mid_epoch_leaves_verifiable_artifacts() {
        let dir = scratch("spill_midepoch");
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(["generate", "--scale", "0.0012", "--out", "g.alxcsr02", "--chunk-rows", "64"]);
            c
        });
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(spill_train_args("ref.ckpt"));
            c
        });
        let reference = std::fs::read(dir.join("ref.ckpt")).unwrap();

        let mut rng = Pcg64::new(0x5EED);
        let hit = rng.range(3, 9); // within epoch 1: W+H write-backs alone exceed this
        run_killed(
            {
                let mut c = alx_bin(&dir);
                c.args(spill_train_args("crash.ckpt"));
                c
            },
            &format!("tab.store_shard=hit:{hit}:abort"),
        );
        assert!(
            !dir.join("crash.ckpt").exists(),
            "no checkpoint should have been published before the mid-epoch kill"
        );
        // Everything the crashed run *published* must still verify clean.
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(["verify", "g.alxcsr02"]);
            c
        });
        let banks = verify_leftover_banks(&dir);
        assert!(banks >= 1, "expected published spill banks to survive the crash");
        // No checkpoint to resume from: recovery is a from-scratch rerun,
        // which must be untroubled by the crash debris and end bitwise
        // identical to the reference.
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(spill_train_args("crash.ckpt"));
            c
        });
        assert_eq!(std::fs::read(dir.join("crash.ckpt")).unwrap(), reference);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Kill the out-of-core path mid-checkpoint and resume from the
    /// surviving checkpoint (re-ingesting the stream into fresh banks).
    #[test]
    fn spill_kill_mid_checkpoint_resumes_bitwise() {
        let dir = scratch("spill_ckpt");
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(["generate", "--scale", "0.0012", "--out", "g.alxcsr02", "--chunk-rows", "64"]);
            c
        });
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(spill_train_args("ref.ckpt"));
            c
        });
        let reference = std::fs::read(dir.join("ref.ckpt")).unwrap();

        run_killed(
            {
                let mut c = alx_bin(&dir);
                c.args(spill_train_args("crash.ckpt"));
                c
            },
            "ckpt.write=hit:2:abort",
        );
        run_ok({
            let mut c = alx_bin(&dir);
            c.arg("verify").arg("crash.ckpt");
            c
        });
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(spill_train_args("crash.ckpt"));
            c.arg("--resume").arg("crash.ckpt");
            c
        });
        assert_eq!(std::fs::read(dir.join("crash.ckpt")).unwrap(), reference);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `alx verify` is the corruption oracle the torture runs lean on:
    /// it must pass intact artifacts and exit non-zero on truncation.
    #[test]
    fn verify_cli_detects_truncation() {
        let dir = scratch("verify_cli");
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(["generate", "--scale", "0.0012", "--out", "g.alxcsr02", "--chunk-rows", "64"]);
            c
        });
        run_ok({
            let mut c = alx_bin(&dir);
            c.args(["verify", "g.alxcsr02"]);
            c
        });
        let whole = std::fs::read(dir.join("g.alxcsr02")).unwrap();
        std::fs::write(dir.join("cut.alxcsr02"), &whole[..whole.len() - 7]).unwrap();
        let out = alx_bin(&dir).args(["verify", "cut.alxcsr02"]).output().unwrap();
        assert!(!out.status.success(), "verify passed a truncated file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ------------------------------------------------------------------
    // In-process injection: behaviors that don't need a child process.
    // ------------------------------------------------------------------

    fn tiny_matrix(users: usize, items: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for u in 0..users as u32 {
            for _ in 0..6 {
                t.push((u, rng.range(0, items) as u32, 1.0));
            }
        }
        Csr::from_coo(users, items, &t)
    }

    fn tiny_cfg(epochs: usize) -> AlxConfig {
        AlxConfig {
            cores: 4,
            train: TrainConfig {
                dim: 8,
                epochs,
                lambda: 0.05,
                alpha: 0.01,
                batch_rows: 16,
                batch_width: 4,
                threads: 1,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    /// ENOSPC while spilling a bank: clean classified error naming the
    /// artifact, nothing half-published at the destination, no staging
    /// litter.
    #[test]
    fn enospc_spill_publishes_nothing() {
        let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        fault::reset();
        fault::configure("bank.write_shard=once:enospc").unwrap();
        let sharded = ShardedCsr::from_csr(&tiny_matrix(48, 30, 9), 3);
        let path =
            std::env::temp_dir().join(format!("alx_torture_enospc_{}.alxbank", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let e = sharded.spill_to_bank(&path).unwrap_err();
        fault::reset();
        assert!(e.to_string().contains("disk full"), "unclassified ENOSPC: {e}");
        assert!(e.to_string().contains("alxbank"), "error must name the artifact: {e}");
        assert!(!path.exists(), "half-published bank left at destination");
        assert!(!durable::tmp_path(&path).exists(), "staging file left behind");
    }

    /// ENOSPC during a checkpoint write must leave the previous good
    /// checkpoint byte-for-byte intact and clean up its staging file.
    #[test]
    fn enospc_checkpoint_keeps_previous_good_one() {
        let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        fault::reset();
        let path = std::env::temp_dir()
            .join(format!("alx_torture_ckpt_enospc_{}.ckpt", std::process::id()));
        let source = InMemorySource::new("tiny", tiny_matrix(48, 30, 9));
        let mut s = TrainSession::new(&source, tiny_cfg(4)).unwrap();
        s.step().unwrap();
        s.checkpoint(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        s.step().unwrap();
        fault::configure("ckpt.write=once:enospc").unwrap();
        let r = s.checkpoint(&path);
        fault::reset();
        assert!(r.is_err(), "injected disk-full checkpoint write must error");
        assert_eq!(std::fs::read(&path).unwrap(), good, "previous checkpoint clobbered");
        assert!(!durable::tmp_path(&path).exists(), "staging file left behind");
        let _ = std::fs::remove_file(&path);
    }

    /// Every background prefetch dying (panic in the prefetch thread) must
    /// degrade to on-demand loads: the epoch completes, the failures are
    /// counted, and the result is bitwise identical to a healthy run.
    #[test]
    fn dead_prefetchers_degrade_to_on_demand() {
        let _g = FP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        fault::reset();
        let base = scratch("prefetch_degrade");
        let spill_cfg = |sub: &str| AlxConfig {
            data_spill: true,
            spill_dir: base.join(sub).display().to_string(),
            resident_shards: 1,
            model_spill: true,
            model_spill_dir: base.join(sub).display().to_string(),
            resident_table_shards: 1,
            ..tiny_cfg(2)
        };

        let source = InMemorySource::new("tiny", tiny_matrix(48, 30, 9));
        let (w_clean, h_clean) = {
            let mut s = TrainSession::new(&source, spill_cfg("clean")).unwrap();
            s.run().unwrap();
            (s.trainer.w.to_dense().data, s.trainer.h.to_dense().data)
        };

        fault::configure("prefetch.matrix=every:1:panic;prefetch.table=every:1:panic").unwrap();
        let (w_faulty, h_faulty, report) = {
            let mut s = TrainSession::new(&source, spill_cfg("faulty")).unwrap();
            let report = s.run().unwrap(); // must not hang or fail
            (s.trainer.w.to_dense().data, s.trainer.h.to_dense().data, report)
        };
        fault::reset();

        assert_eq!(w_clean, w_faulty, "dead prefetchers changed W");
        assert_eq!(h_clean, h_faulty, "dead prefetchers changed H");
        let sp = report.spill.expect("spill stats missing");
        if sp.prefetches > 0 {
            assert!(sp.prefetch_failures > 0, "dead prefetches were not counted");
        }
        let _ = std::fs::remove_dir_all(&base);
    }
}
