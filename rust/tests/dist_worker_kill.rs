//! Process-level failure drill for worker-side solves: a real `alx worker`
//! process armed with a `dist.solve` failpoint aborts mid-SOLVE_PASS, and
//! the coordinator must fail the epoch cleanly — naming the dead process,
//! leaving the previously published checkpoint byte-identical to a local
//! run's and `alx verify`-clean, and resumable.
//!
//! The in-process twin (thread workers, stop-flag kill) lives in
//! `dist_equivalence.rs`; this file covers the real subprocess fleet and
//! the deterministic fault-injection path. Needs the failpoints feature:
//! `cargo test --features failpoints --test dist_worker_kill`.

#[cfg(not(feature = "failpoints"))]
mod stub {
    #[test]
    fn dist_worker_kill_requires_failpoints_feature() {
        // Compiled-out build: the hooks are inert no-ops and there is
        // nothing to kill. The CI torture job builds with the feature.
        assert!(!alx::util::fault::ENABLED);
        assert!(alx::util::fault::failpoint("dist.solve").is_ok());
    }
}

#[cfg(feature = "failpoints")]
mod drill {
    use alx::als::TrainConfig;
    use alx::config::AlxConfig;
    use alx::coordinator::TrainSession;
    use alx::data::InMemorySource;
    use alx::dist::{DistCompute, DistConfig, DistMode};
    use alx::sparse::Csr;
    use std::io::BufRead;
    use std::path::PathBuf;
    use std::process::{Child, Command, Stdio};

    /// A regular bipartite matrix whose batch counts are exact by
    /// construction: 32 users × 16 items, user `u` rates items
    /// `(u + j) % 16` for `j in 0..4`. Every user has 4 nonzeros (one
    /// dense row at width 4) and every item has 8 (two dense rows), so
    /// with 4 shards and `batch_rows = 16` each shard is exactly one
    /// dense batch in both passes.
    fn regular_matrix() -> Csr {
        let mut t = Vec::new();
        for u in 0..32u32 {
            for j in 0..4u32 {
                t.push((u, (u + j) % 16, 1.0 + (u + j) as f32 * 0.05));
            }
        }
        Csr::from_coo(32, 16, &t)
    }

    fn cfg() -> AlxConfig {
        AlxConfig {
            cores: 4,
            train: TrainConfig {
                dim: 8,
                epochs: 3,
                lambda: 0.05,
                alpha: 0.01,
                batch_rows: 16,
                batch_width: 4,
                threads: 2,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("alx_dwk_{}_{}", tag, std::process::id()))
    }

    /// Spawn a real `alx worker` on an ephemeral port, read its
    /// `ALX_WORKER_LISTENING host:port` announcement off piped stdout,
    /// and keep draining the pipe so the child's log writes never block.
    fn spawn_worker(failpoints: Option<&str>) -> (Child, String) {
        let mut c = Command::new(env!("CARGO_BIN_EXE_alx"));
        c.arg("worker").arg("--port").arg("0");
        if let Some(spec) = failpoints {
            c.arg("--failpoints").arg(spec);
        }
        c.env_remove("ALX_FAILPOINTS");
        c.stdout(Stdio::piped());
        c.stderr(Stdio::null());
        let mut child = c.spawn().unwrap();
        let stdout = child.stdout.take().expect("stdout was piped");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        let addr = loop {
            line.clear();
            let k = reader.read_line(&mut line).unwrap();
            assert!(k > 0, "worker exited before announcing its address");
            if let Some(rest) = line.trim().strip_prefix(alx::dist::WORKER_READY_PREFIX) {
                break rest.trim().to_string();
            }
        };
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(k) if k > 0) {
                sink.clear();
            }
        });
        (child, addr)
    }

    fn shutdown_worker(addr: &str) {
        if let Ok(mut s) = std::net::TcpStream::connect(addr) {
            let _ = alx::util::net::write_frame_capped(
                &mut s,
                &alx::dist::protocol::enc_shutdown(),
                alx::dist::protocol::MAX_FRAME,
            );
            let _ = alx::util::net::read_frame_capped(&mut s, alx::dist::protocol::MAX_FRAME);
        }
    }

    #[test]
    fn worker_abort_mid_solve_pass_is_clean_and_resumable() {
        let m = regular_matrix();

        // Local reference: one epoch, checkpointed. The worker-compute
        // checkpoint below must match it byte for byte.
        let ref_ckpt = tmp("ref.ckpt");
        let reference = {
            let source = InMemorySource::new("regular", m.clone());
            let mut s = TrainSession::new(&source, cfg()).unwrap();
            s.step().unwrap();
            s.checkpoint(&ref_ckpt).unwrap();
            std::fs::read(&ref_ckpt).unwrap()
        };

        // Worker 0 owns shards 0 and 2 (owner = shard % fleet), so it
        // serves exactly 4 SOLVE_BATCH requests per epoch (W shards 0,2
        // + H shards 0,2, one batch each — see `regular_matrix`). Hit 5
        // is therefore the first solve of epoch 2: the process aborts
        // mid-pass, after the epoch-1 checkpoint is safely on disk.
        let (mut victim, addr0) = spawn_worker(Some("dist.solve=hit:5:abort"));
        let (mut peer, addr1) = spawn_worker(None);
        let addrs = vec![addr0, addr1.clone()];

        let ckpt = tmp("kill.ckpt");
        let mut s = {
            let mut c = cfg();
            c.dist = DistConfig {
                mode: DistMode::Tcp,
                topology: "parameter-server".to_string(),
                workers: addrs.clone(),
                heartbeat_ms: 25,
                compute: DistCompute::Worker,
            };
            let source = InMemorySource::new("regular", m.clone());
            TrainSession::new(&source, c).unwrap()
        };
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
        let saved = std::fs::read(&ckpt).unwrap();
        assert_eq!(saved, reference, "worker-solve checkpoint must match the local bytes");

        // Epoch 2 must fail cleanly — an Err naming the dead process
        // (directly, or via the surviving worker's failed peer gather),
        // not a hang or a panic.
        let err = s.step().expect_err("epoch must abort once the worker dies");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("worker") || msg.contains("peer"),
            "error should name the dead process: {msg}"
        );
        drop(s);
        let status = victim.wait().unwrap();
        assert!(!status.success(), "the armed worker must die by abort, not exit cleanly");

        // The published checkpoint is untouched by the failed epoch,
        // structurally valid, and resumable.
        assert_eq!(std::fs::read(&ckpt).unwrap(), saved);
        alx::verify::verify_file(&ckpt).expect("pre-kill checkpoint must pass alx verify");
        let source = InMemorySource::new("regular", m.clone());
        let mut resumed = TrainSession::resume_with(&ckpt, &source, cfg(), None).unwrap();
        assert_eq!(resumed.trainer.current_epoch(), 1);
        resumed.step().unwrap();

        shutdown_worker(&addr1);
        let _ = peer.wait();
        let _ = std::fs::remove_file(&ref_ckpt);
        let _ = std::fs::remove_file(&ckpt);
    }
}
