//! The spilled-model contract: a session whose embedding tables live in
//! read-write-mapped `ALXTAB01` banks (demand-paged through the LRU
//! residency manager, scatters checked out and written back per shard
//! pass) trains **bitwise identically** to the fully resident model —
//! same objective history, same final tables, same recalls — at every
//! thread count and storage precision, including across a
//! checkpoint/resume, while a run over the residency budget reports
//! nonzero table-shard faults and prefetch hits.

use alx::als::{EpochStats, PrecisionPolicy, TrainConfig};
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::prelude::*;
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize, spill_model: bool, precision: PrecisionPolicy) -> AlxConfig {
    AlxConfig {
        cores: 8,
        model_spill: spill_model,
        resident_table_shards: 2,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            threads,
            precision,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_model_spill_{}_{}", tag, std::process::id()))
}

/// Timing-free fingerprint of an epoch.
fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

type RunFingerprint =
    (Vec<(usize, Option<u64>, u64)>, Vec<f32>, Vec<f32>, Vec<(usize, u64)>);

fn run(mut s: TrainSession) -> (RunFingerprint, RunReport) {
    let report = s.run().unwrap();
    let recalls: Vec<(usize, u64)> =
        report.recalls.iter().map(|r| (r.k, r.recall.to_bits())).collect();
    (
        (
            report.history.iter().map(fingerprint).collect(),
            s.trainer.w.to_dense().data,
            s.trainer.h.to_dense().data,
            recalls,
        ),
        report,
    )
}

#[test]
fn spilled_model_is_bitwise_identical_to_resident() {
    let m = community_matrix(80, 48, 3);
    for threads in [1usize, 4] {
        for precision in [PrecisionPolicy::F32, PrecisionPolicy::Mixed] {
            let tag = format!("bitwise_t{threads}_{}", precision.name());
            let resident = {
                let source = InMemorySource::new("community", m.clone());
                TrainSession::new(&source, cfg(3, threads, false, precision)).unwrap()
            };
            let (fp_resident, rep_resident) = run(resident);
            assert!(
                rep_resident.table_spill.is_none(),
                "resident run must not report model spill"
            );

            let spilled = {
                let mut c = cfg(3, threads, true, precision);
                c.model_spill_dir = tmp(&tag).display().to_string();
                let source = InMemorySource::new("community", m.clone());
                TrainSession::new(&source, c).unwrap()
            };
            let (fp_spilled, rep_spilled) = run(spilled);
            assert_eq!(fp_spilled.0, fp_resident.0, "objective history differs ({tag})");
            assert_eq!(fp_spilled.1, fp_resident.1, "W differs ({tag})");
            assert_eq!(fp_spilled.2, fp_resident.2, "H differs ({tag})");
            assert_eq!(fp_spilled.3, fp_resident.3, "recalls differ ({tag})");
            let ts = rep_spilled.table_spill.expect("spilled model must report accounting");
            assert!(ts.bank_bytes > 0);
            let _ = std::fs::remove_dir_all(tmp(&tag));
        }
    }
}

#[test]
fn model_spill_over_budget_faults_and_prefetches() {
    // 8 table shards per side, residency cap 2: every pass faults fixed
    // shards back in, and the shard workers stage upcoming target shards
    // through the background prefetcher.
    let m = community_matrix(120, 64, 5);
    let dir = tmp("budget");
    let mut c = cfg(3, 4, true, PrecisionPolicy::F32);
    c.model_spill_dir = dir.display().to_string();
    let source = InMemorySource::new("community", m.clone());
    let (_, report) = run(TrainSession::new(&source, c).unwrap());
    let ts = report.table_spill.expect("table spill accounting");
    assert!(ts.shard_faults > 0, "over-budget run must fault: {ts:?}");
    assert!(ts.prefetch_hits > 0, "residency cache must land hits: {ts:?}");
    assert!(ts.prefetches > 0, "shard workers must stage prefetches: {ts:?}");
    // The two banks hold W and H at storage precision: (rows + cols)
    // rows of dim 8 at ≥ 2 bytes per element is a safe lower bound.
    let table_bytes = (m.rows as u64 + m.cols as u64) * 8 * 2;
    assert!(ts.bank_bytes >= table_bytes, "{ts:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spilled_model_checkpoint_resume_is_bitwise() {
    let m = community_matrix(80, 48, 7);
    let dir_a = tmp("resume_full");
    let dir_b = tmp("resume_cut");
    let ckpt = tmp("resume.ckpt");
    let make = |dir: &PathBuf, threads: usize| {
        let mut c = cfg(4, threads, true, PrecisionPolicy::Mixed);
        c.model_spill_dir = dir.display().to_string();
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };

    let mut full = make(&dir_a, 4);
    while full.remaining_epochs() > 0 {
        full.step().unwrap();
    }

    // Interrupted at epoch 2, resumed in a fresh session whose banks
    // start from a different random init (the resume re-attaches and
    // overwrites them shard by shard) and a different thread count.
    {
        let mut s = make(&dir_b, 4);
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let source = InMemorySource::new("community", m.clone());
    let mut c = cfg(4, 1, true, PrecisionPolicy::Mixed);
    c.model_spill_dir = dir_b.display().to_string();
    let mut resumed = TrainSession::resume_with(&ckpt, &source, c, None).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 2);
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    assert_eq!(full.trainer.w.to_dense().data, resumed.trainer.w.to_dense().data);
    assert_eq!(full.trainer.h.to_dense().data, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn spilled_eval_streams_without_dense_materialization() {
    // The recall path must stream H shard-by-shard (MIPS index build,
    // candidate scoring, exact top-k) — materializing a dense copy of a
    // spilled table would defeat the whole out-of-core model story. The
    // sharding module counts every `to_dense()`; eval must add zero.
    let m = community_matrix(80, 48, 11);
    let dir = tmp("eval_stream");
    let mut c = cfg(2, 4, true, PrecisionPolicy::F32);
    c.model_spill_dir = dir.display().to_string();
    let source = InMemorySource::new("community", m.clone());
    let mut s = TrainSession::new(&source, c).unwrap();
    while s.remaining_epochs() > 0 {
        s.step().unwrap();
    }
    assert!(s.trainer.h.is_spilled());
    let before = alx::sharding::dense_materializations();
    let exact = s.evaluate().unwrap();
    let approx = s.evaluate_with(&EvalConfig { approximate: true, ..EvalConfig::default() });
    assert!(!exact.is_empty() && !approx.is_empty());
    let after = alx::sharding::dense_materializations();
    assert_eq!(
        after, before,
        "evaluate must stream shards, never to_dense() a table (exact and MIPS paths)"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fully_out_of_core_matrix_and_model_is_bitwise() {
    // The complete composition: ALXCSR02 chunks stream through the split
    // into spilled ALXBANK01 matrix banks, the model spills into
    // ALXTAB01 table banks — with --stream --spill --spill-model neither
    // the matrix nor the model ever exists in RAM, and training is still
    // bitwise identical to the fully resident session on the same data.
    let m = community_matrix(80, 48, 9);
    let csr02 = tmp("stream.csr02");
    let dir = tmp("stream_banks");
    {
        let f = std::io::BufWriter::new(std::fs::File::create(&csr02).unwrap());
        alx::sparse::write_chunked(&m, f, 16).unwrap();
    }
    let resident = {
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, cfg(2, 4, false, PrecisionPolicy::Mixed)).unwrap()
    };
    let (fp_resident, _) = run(resident);

    let mut c = cfg(2, 4, true, PrecisionPolicy::Mixed);
    c.data_spill = true;
    c.resident_shards = 2;
    c.spill_dir = dir.display().to_string();
    let spilled = TrainSession::from_streaming(&csr02, c, None).unwrap();
    let (fp_spilled, report) = run(spilled);
    assert_eq!(fp_spilled.0, fp_resident.0, "objective history differs");
    assert_eq!(fp_spilled.1, fp_resident.1, "W differs");
    assert_eq!(fp_spilled.2, fp_resident.2, "H differs");
    assert_eq!(fp_spilled.3, fp_resident.3, "recalls differ");
    assert!(report.spill.is_some(), "matrix spill accounting missing");
    assert!(report.table_spill.is_some(), "model spill accounting missing");
    let _ = std::fs::remove_file(&csr02);
    let _ = std::fs::remove_dir_all(&dir);
}
