//! Integration: the XLA/PJRT engine (AOT L2 graph + L1 Pallas kernel) must
//! agree with the native rust engine on identical inputs, per solver —
//! the cross-layer correctness contract of the whole architecture.
//!
//! Requires `make artifacts`; tests skip (pass vacuously, with a stderr
//! note) when the artifact directory is absent so `cargo test` stays
//! usable on a fresh checkout.

use alx::als::{NativeEngine, SolveEngine, TrainConfig, Trainer};
use alx::densebatch::DenseBatcher;
use alx::linalg::{Mat, SolveOptions, SolverKind};
use alx::runtime::XlaEngine;
use alx::sparse::Csr;
use alx::topo::Topology;
use alx::util::Pcg64;

const ARTIFACTS: &str = "artifacts";
const B: usize = 64;
const L: usize = 8;

fn artifacts_available() -> bool {
    let ok = std::path::Path::new(ARTIFACTS).join("manifest.tsv").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` to enable XLA engine tests");
    }
    ok
}

/// Random sparse problem + gathered slot embeddings for one batch.
fn random_batch(
    d: usize,
    rows: usize,
    seed: u64,
) -> (alx::densebatch::DenseBatch, Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let n_items = 50;
    let mut triplets = Vec::new();
    for r in 0..rows as u32 {
        let len = 1 + rng.range(0, 12);
        let mut cols = std::collections::HashSet::new();
        while cols.len() < len {
            cols.insert(rng.range(0, n_items) as u32);
        }
        for c in cols {
            triplets.push((r, c, rng.next_f32() + 0.25));
        }
    }
    let m = Csr::from_coo(rows, n_items, &triplets);
    let items = Mat::randn(n_items, d, 0.6, &mut rng);
    let gram = items.gramian();
    let batcher = DenseBatcher::new(B, L);
    let batch = batcher.batch_rows_of(&m, &(0..rows as u32).collect::<Vec<_>>())[0].clone();
    let mut h = Mat::zeros(B * L, d);
    for (slot, &it) in batch.items.iter().enumerate() {
        h.row_mut(slot).copy_from_slice(items.row(it as usize));
    }
    (batch, h, gram)
}

#[test]
fn xla_matches_native_all_solvers() {
    if !artifacts_available() {
        return;
    }
    for solver in SolverKind::ALL {
        for d in [16usize, 32] {
            let (batch, h, gram) = random_batch(d, 20, 42 + d as u64);
            let native = NativeEngine::new(solver, SolveOptions::default());
            let xla =
                XlaEngine::new(ARTIFACTS, solver.name(), d, B, L).expect("open artifact");
            let wn = native.solve_batch(&batch, &h, &gram, 0.1, 0.01).unwrap();
            let wx = xla.solve_batch(&batch, &h, &gram, 0.1, 0.01).unwrap();
            assert_eq!(wn.rows, wx.rows);
            let diff = wn.max_abs_diff(&wx);
            let scale = wn.data.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-6);
            assert!(
                diff / scale < 5e-3,
                "{} d={d}: native vs xla rel diff {}",
                solver.name(),
                diff / scale
            );
        }
    }
}

#[test]
fn xla_engine_rejects_wrong_shapes() {
    if !artifacts_available() {
        return;
    }
    let (batch, h, gram) = random_batch(16, 10, 7);
    // Engine compiled for d=32 must reject d=16 inputs.
    let xla = XlaEngine::new(ARTIFACTS, "cg", 32, B, L).unwrap();
    assert!(xla.solve_batch(&batch, &h, &gram, 0.1, 0.01).is_err());
}

#[test]
fn xla_engine_missing_artifact_errors() {
    if !artifacts_available() {
        return;
    }
    assert!(XlaEngine::new(ARTIFACTS, "cg", 17, B, L).is_err()); // d=17 never compiled
}

#[test]
fn training_with_xla_engine_learns() {
    if !artifacts_available() {
        return;
    }
    // Small community matrix; train with the XLA engine end to end.
    let mut rng = Pcg64::new(11);
    let (users, items) = (48, 40);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..8 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    let m = Csr::from_coo(users, items, &t);
    let cfg = TrainConfig {
        dim: 16,
        epochs: 3,
        lambda: 0.05,
        alpha: 0.01,
        batch_rows: B,
        batch_width: L,
        ..TrainConfig::default()
    };
    let engine = Box::new(XlaEngine::new(ARTIFACTS, "cg", 16, B, L).unwrap());
    let mut trainer = Trainer::with_engine(&m, cfg.clone(), Topology::new(2), engine).unwrap();
    let hist = trainer.fit().unwrap();
    let objs: Vec<f64> = hist.iter().map(|h| h.objective.unwrap()).collect();
    assert!(
        objs.last().unwrap() < objs.first().unwrap(),
        "xla-engine training should reduce the objective: {objs:?}"
    );

    // And the native engine lands at a comparable objective.
    let mut native = Trainer::new(&m, cfg, Topology::new(2)).unwrap();
    let hist_n = native.fit().unwrap();
    let on = hist_n.last().unwrap().objective.unwrap();
    let ox = objs.last().unwrap();
    assert!((on - ox).abs() / on < 0.05, "native {on} vs xla {ox}");
}
