//! The spilled-training contract: a session whose train/transpose shards
//! live in mmap-backed `ALXBANK01` banks (demand-paged through the LRU
//! residency manager, with background prefetch) trains **bitwise
//! identically** to the fully resident path — same objective history,
//! same final tables, same recalls — at every thread count, including
//! across a checkpoint/resume, while a run over the residency budget
//! reports nonzero shard faults and prefetch hits.

use alx::als::{EpochStats, TrainConfig};
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::prelude::*;
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize, spill: bool) -> AlxConfig {
    AlxConfig {
        cores: 8,
        data_spill: spill,
        resident_shards: 2,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            threads,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_spill_eq_{}_{}", tag, std::process::id()))
}

/// Timing-free fingerprint of an epoch.
fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

type RunFingerprint =
    (Vec<(usize, Option<u64>, u64)>, Vec<f32>, Vec<f32>, Vec<(usize, u64)>);

fn run(mut s: TrainSession) -> (RunFingerprint, RunReport) {
    let report = s.run().unwrap();
    let recalls: Vec<(usize, u64)> =
        report.recalls.iter().map(|r| (r.k, r.recall.to_bits())).collect();
    (
        (
            report.history.iter().map(fingerprint).collect(),
            s.trainer.w.to_dense().data,
            s.trainer.h.to_dense().data,
            recalls,
        ),
        report,
    )
}

#[test]
fn spilled_run_is_bitwise_identical_to_resident() {
    let m = community_matrix(80, 48, 3);
    for threads in [1usize, 4] {
        let resident = {
            let source = InMemorySource::new("community", m.clone());
            TrainSession::new(&source, cfg(3, threads, false)).unwrap()
        };
        let (fp_resident, rep_resident) = run(resident);
        assert!(rep_resident.spill.is_none(), "resident run must not report spill");

        let spilled = {
            let mut c = cfg(3, threads, true);
            c.spill_dir = tmp(&format!("bitwise_t{threads}")).display().to_string();
            let source = InMemorySource::new("community", m.clone());
            TrainSession::new(&source, c).unwrap()
        };
        let (fp_spilled, rep_spilled) = run(spilled);
        assert_eq!(fp_spilled.0, fp_resident.0, "objective history differs (threads={threads})");
        assert_eq!(fp_spilled.1, fp_resident.1, "W differs (threads={threads})");
        assert_eq!(fp_spilled.2, fp_resident.2, "H differs (threads={threads})");
        assert_eq!(fp_spilled.3, fp_resident.3, "recalls differ (threads={threads})");
        let sp = rep_spilled.spill.expect("spilled run must report spill accounting");
        assert!(sp.bank_bytes > 0);
        let _ = std::fs::remove_dir_all(tmp(&format!("bitwise_t{threads}")));
    }
}

#[test]
fn spill_over_resident_budget_faults_and_prefetches() {
    // 8 shards per bank, residency cap 2: a 3-epoch run must fault shards
    // back in every pass and serve others from the prefetch cache.
    let m = community_matrix(120, 64, 5);
    let dir = tmp("budget");
    let mut c = cfg(3, 4, true);
    c.spill_dir = dir.display().to_string();
    let source = InMemorySource::new("community", m.clone());
    let (_, report) = run(TrainSession::new(&source, c).unwrap());
    let sp = report.spill.expect("spill accounting");
    assert!(sp.shard_faults > 0, "over-budget run must fault: {sp:?}");
    assert!(sp.prefetch_hits > 0, "prefetch must land hits: {sp:?}");
    assert!(sp.prefetches > 0, "workers must issue prefetches: {sp:?}");
    // The two banks together hold the train matrix twice over (matrix +
    // transpose), so their bytes are on the order of the matrix itself.
    assert!(sp.bank_bytes >= m.memory_bytes() / 2, "{sp:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spilled_checkpoint_resume_is_bitwise() {
    let m = community_matrix(80, 48, 7);
    let dir_a = tmp("resume_full");
    let dir_b = tmp("resume_cut");
    let ckpt = tmp("resume.ckpt");
    let make = |dir: &PathBuf, threads: usize| {
        let mut c = cfg(4, threads, true);
        c.spill_dir = dir.display().to_string();
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };

    let mut full = make(&dir_a, 4);
    while full.remaining_epochs() > 0 {
        full.step().unwrap();
    }

    // Interrupted at epoch 2, resumed in a fresh session (threads 1, so
    // the equivalence also crosses thread counts and spill dirs).
    {
        let mut s = make(&dir_b, 4);
        s.step().unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let source = InMemorySource::new("community", m.clone());
    let mut c = cfg(4, 1, true);
    c.spill_dir = dir_b.display().to_string();
    let mut resumed = TrainSession::resume_with(&ckpt, &source, c, None).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 2);
    while resumed.remaining_epochs() > 0 {
        resumed.step().unwrap();
    }
    assert_eq!(full.trainer.w.to_dense().data, resumed.trainer.w.to_dense().data);
    assert_eq!(full.trainer.h.to_dense().data, resumed.trainer.h.to_dense().data);
    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn streaming_plus_spill_trains_without_the_matrix_ever_resident() {
    // The full out-of-core composition: ALXCSR02 chunks stream through
    // the split into a spilling builder (banks written as shards
    // complete), then train demand-paged — bitwise identical to the
    // resident in-memory session on the same data.
    let m = community_matrix(80, 48, 9);
    let csr02 = tmp("stream.csr02");
    let dir = tmp("stream_banks");
    {
        let f = std::io::BufWriter::new(std::fs::File::create(&csr02).unwrap());
        alx::sparse::write_chunked(&m, f, 16).unwrap();
    }
    let resident = {
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, cfg(2, 4, false)).unwrap()
    };
    let (fp_resident, _) = run(resident);

    let mut c = cfg(2, 4, true);
    c.spill_dir = dir.display().to_string();
    let spilled = TrainSession::from_streaming(&csr02, c, None).unwrap();
    assert!(spilled.ingest.is_some(), "streaming session must report ingestion");
    let (fp_spilled, report) = run(spilled);
    assert_eq!(fp_spilled.0, fp_resident.0, "objective history differs");
    assert_eq!(fp_spilled.1, fp_resident.1, "W differs");
    assert_eq!(fp_spilled.2, fp_resident.2, "H differs");
    assert_eq!(fp_spilled.3, fp_resident.3, "recalls differ");
    assert!(report.spill.is_some());
    let _ = std::fs::remove_file(&csr02);
    let _ = std::fs::remove_dir_all(&dir);
}
