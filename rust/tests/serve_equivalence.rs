//! The serving contract: every Top-K answer the server produces — cache
//! miss, cache hit, or coalesced into a concurrent batch — is **bitwise
//! identical** to single-threaded exact `eval::mips` scoring over the
//! dense item table, for f32 and bf16 models, on resident and
//! bank-backed (spilled) table storage. Plus the liveness half of the
//! story: shutdown mid-traffic leaves no wedged workers and no poisoned
//! table locks (the same `Arc<ServeModel>` serves again immediately),
//! expired deadlines degrade to errors, and injected faults at the
//! accept/read/index stages never take the server down.

use alx::eval::MipsIndex;
use alx::linalg::Mat;
use alx::serving::{serve, Client, Response, ServeConfig, ServeModel, TopKRequest};
use alx::sharding::{ShardedTable, Storage};
use alx::util::Pcg64;
use std::path::PathBuf;
use std::sync::Arc;

const DIM: usize = 8;
const USERS: usize = 24;
const ITEMS: usize = 64;
const CLUSTERS: usize = 8;
const SEED: u64 = 4242;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_serve_eq_{}_{}", tag, std::process::id()))
}

/// Random model tables; `spill_dir` routes them through `ALXTAB01` banks
/// and reopens them demand-paged (1–2 resident shards, so serving pages).
fn tables(storage: Storage, spill_dir: Option<&PathBuf>) -> (ShardedTable, ShardedTable) {
    let mut rng = Pcg64::new(11);
    let users = ShardedTable::randn(USERS, DIM, 3, storage, &mut rng);
    let items = ShardedTable::randn(ITEMS, DIM, 5, storage, &mut rng);
    match spill_dir {
        None => (users, items),
        Some(dir) => {
            std::fs::create_dir_all(dir).unwrap();
            let wb = dir.join("w.alxtab");
            let hb = dir.join("h.alxtab");
            users.spill_to_bank(&wb).unwrap();
            items.spill_to_bank(&hb).unwrap();
            (ShardedTable::open_bank(&wb, 1).unwrap(), ShardedTable::open_bank(&hb, 2).unwrap())
        }
    }
}

/// The reference: single-threaded exact `eval::mips` scoring over dense
/// matrices (densifying is fine in a test — it is exactly what serving
/// must never need to do).
fn expect_topk(
    idx: &MipsIndex,
    users_dense: &Mat,
    items_dense: &Mat,
    user: usize,
    k: usize,
    probes: usize,
    exclude: &[u32],
) -> Vec<(u32, f32)> {
    let mut ex = exclude.to_vec();
    ex.sort_unstable();
    idx.search_scored(items_dense, users_dense.row(user), k, probes, &ex)
        .into_iter()
        .map(|(s, id)| (id, s))
        .collect()
}

fn assert_bitwise(got: &[(u32, f32)], want: &[(u32, f32)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.0, w.0, "{ctx}: item at rank {i}");
        assert_eq!(g.1.to_bits(), w.1.to_bits(), "{ctx}: score bits at rank {i}");
    }
}

#[test]
fn server_responses_bitwise_match_exact_scoring() {
    for storage in [Storage::F32, Storage::Bf16] {
        for spilled in [false, true] {
            let tag = format!("{storage:?}_{}", if spilled { "spilled" } else { "resident" });
            let dir = tmp(&tag);
            let (users, items) = tables(storage, spilled.then_some(&dir));
            assert_eq!(items.is_spilled(), spilled);
            let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, SEED));
            let users_dense = model.users.to_dense();
            let items_dense = model.items.to_dense();
            let idx = MipsIndex::build(&items_dense, CLUSTERS, SEED);
            assert_eq!(
                idx.centroids.data, model.index.centroids.data,
                "{tag}: streamed index build must equal the dense build"
            );

            let cfg = ServeConfig {
                threads: 2,
                batch_window_us: 2_000,
                batch_max: 16,
                cache_entries: 8,
                ..ServeConfig::default()
            };
            let mut handle = serve(Arc::clone(&model), &cfg).unwrap();
            let addr = handle.addr();

            // Concurrent clients with overlapping users: requests coalesce
            // into mixed batches, and repeated identities land cache hits.
            let mut joins = Vec::new();
            for t in 0..3u64 {
                let addr = addr.clone();
                joins.push(std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let mut out = Vec::new();
                    for i in 0..8u64 {
                        let user = (t * 3 + i) % USERS as u64;
                        let exclude = vec![(user as u32 * 7) % ITEMS as u32];
                        let req = TopKRequest { user, k: 6, probes: 3, deadline_us: 0, exclude };
                        match c.topk(&req).unwrap() {
                            Response::TopK(items) => out.push((req, items)),
                            other => panic!("unexpected reply: {other:?}"),
                        }
                    }
                    out
                }));
            }
            for j in joins {
                for (req, got) in j.join().unwrap() {
                    let want = expect_topk(
                        &idx,
                        &users_dense,
                        &items_dense,
                        req.user as usize,
                        6,
                        3,
                        &req.exclude,
                    );
                    assert_bitwise(&got, &want, &format!("{tag} user {}", req.user));
                }
            }

            // Explicit miss-then-hit on one connection: both must equal
            // the reference (a hit replays stored bits, never recomputes).
            let mut c = Client::connect(&addr).unwrap();
            let req = TopKRequest { user: 5, k: 6, probes: 3, deadline_us: 0, exclude: vec![9, 1] };
            let hits_before = handle.stats().cache_hits;
            let Response::TopK(first) = c.topk(&req).unwrap() else { panic!("miss failed") };
            let Response::TopK(second) = c.topk(&req).unwrap() else { panic!("hit failed") };
            let want = expect_topk(&idx, &users_dense, &items_dense, 5, 6, 3, &req.exclude);
            assert_bitwise(&first, &want, &format!("{tag} cache miss"));
            assert_bitwise(&second, &want, &format!("{tag} cache hit"));
            assert!(
                handle.stats().cache_hits > hits_before,
                "{tag}: repeated request must hit the cache"
            );

            handle.stop();
            let stats = handle.stats();
            assert!(stats.requests >= 26, "{tag}: {stats:?}");
            assert!(stats.batches >= 1, "{tag}: {stats:?}");
            if spilled {
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

#[test]
fn shutdown_mid_traffic_then_restart_serves_again() {
    // Spilled backend on purpose: a shutdown that poisoned the paged
    // table's locks or wedged a worker would surface when the same
    // Arc<ServeModel> is served a second time.
    let dir = tmp("restart");
    let (users, items) = tables(Storage::F32, Some(&dir));
    let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, SEED));
    let users_dense = model.users.to_dense();
    let items_dense = model.items.to_dense();
    let idx = MipsIndex::build(&items_dense, CLUSTERS, SEED);

    let cfg = ServeConfig { threads: 2, batch_window_us: 500, ..ServeConfig::default() };
    let mut h1 = serve(Arc::clone(&model), &cfg).unwrap();
    let addr = h1.addr();

    let mut joins = Vec::new();
    for t in 0..4u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut ok = Vec::new();
            let Ok(mut c) = Client::connect(&addr) else { return ok };
            for i in 0..50u64 {
                let user = (t * 7 + i) % USERS as u64;
                let req = TopKRequest { user, k: 4, probes: 2, deadline_us: 0, exclude: vec![] };
                match c.topk(&req) {
                    Ok(Response::TopK(items)) => ok.push((user, items)),
                    // Shutdown raced us: rejected or disconnected. Stop.
                    Ok(_) | Err(_) => break,
                }
            }
            ok
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(10));
    if let Ok(mut c) = Client::connect(&addr) {
        let _ = c.shutdown();
    }
    for j in joins {
        for (user, got) in j.join().unwrap() {
            let want = expect_topk(&idx, &users_dense, &items_dense, user as usize, 4, 2, &[]);
            assert_bitwise(&got, &want, &format!("pre-shutdown user {user}"));
        }
    }
    h1.wait(); // joins accept, workers, and every connection thread

    // Same model object, fresh server: everything still works.
    let mut h2 = serve(Arc::clone(&model), &cfg).unwrap();
    let mut c = Client::connect(&h2.addr()).unwrap();
    let req = TopKRequest { user: 3, k: 4, probes: 2, deadline_us: 0, exclude: vec![] };
    let Response::TopK(got) = c.topk(&req).unwrap() else { panic!("restart query failed") };
    let want = expect_topk(&idx, &users_dense, &items_dense, 3, 4, 2, &[]);
    assert_bitwise(&got, &want, "post-restart");
    h2.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn expired_deadline_gets_error_not_stale_result() {
    let (users, items) = tables(Storage::F32, None);
    let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, SEED));
    // A long batch window guarantees the 1µs deadline is already blown
    // by the time a worker drains the batch.
    let cfg = ServeConfig { threads: 1, batch_window_us: 50_000, ..ServeConfig::default() };
    let mut handle = serve(model, &cfg).unwrap();
    let mut c = Client::connect(&handle.addr()).unwrap();
    let req = TopKRequest { user: 0, k: 4, probes: 2, deadline_us: 1, exclude: vec![] };
    match c.topk(&req).unwrap() {
        Response::Err(msg) => assert!(msg.contains("deadline"), "got: {msg}"),
        other => panic!("expected a deadline error, got {other:?}"),
    }
    handle.stop();
    assert_eq!(handle.stats().deadline_expired, 1);
}

#[test]
fn out_of_range_user_and_malformed_frame_answer_err_and_server_survives() {
    let (users, items) = tables(Storage::F32, None);
    let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, SEED));
    let mut handle = serve(model, &ServeConfig::default()).unwrap();
    let addr = handle.addr();

    let mut c = Client::connect(&addr).unwrap();
    let req =
        TopKRequest { user: USERS as u64 + 5, k: 4, probes: 2, deadline_us: 0, exclude: vec![] };
    match c.topk(&req).unwrap() {
        Response::Err(msg) => assert!(msg.contains("out of range"), "got: {msg}"),
        other => panic!("expected out-of-range error, got {other:?}"),
    }

    // Garbage opcode: ERR back, that connection closed, server up.
    let mut bad = Client::connect(&addr).unwrap();
    match bad.send_raw(&[0xFF, 0xAA]).unwrap() {
        Some(Response::Err(_)) => {}
        other => panic!("expected ERR for malformed frame, got {other:?}"),
    }
    let mut again = Client::connect(&addr).unwrap();
    assert_eq!(again.ping().unwrap(), Response::Ok);
    handle.stop();
    assert_eq!(handle.stats().malformed, 1);
}

#[cfg(feature = "failpoints")]
mod failpoints {
    use super::*;
    use alx::util::fault;

    /// All three serve failpoints in one test — the fault registry is
    /// process-global, so the stages must run serialized.
    #[test]
    fn injected_faults_degrade_to_errors_never_wedges() {
        let (users, items) = tables(Storage::F32, None);
        let model = Arc::new(ServeModel::from_tables(users, items, CLUSTERS, SEED));
        let cfg = ServeConfig { threads: 2, ..ServeConfig::default() };

        // serve.read: the poisoned connection gets ERR and is dropped;
        // the next connection is untouched.
        fault::configure("serve.read=once").unwrap();
        let mut h = serve(Arc::clone(&model), &cfg).unwrap();
        let addr = h.addr();
        let mut c = Client::connect(&addr).unwrap();
        match c.ping() {
            Ok(Response::Err(_)) | Err(_) => {}
            other => panic!("expected injected read error, got {other:?}"),
        }
        let mut c2 = Client::connect(&addr).unwrap();
        assert_eq!(c2.ping().unwrap(), Response::Ok);

        // serve.index: one scoring batch errors out; the next succeeds on
        // the same connection (worker loop survives).
        fault::configure("serve.index=once").unwrap();
        let req = TopKRequest { user: 1, k: 3, probes: 2, deadline_us: 0, exclude: vec![] };
        match c2.topk(&req).unwrap() {
            Response::Err(_) => {}
            other => panic!("expected injected index error, got {other:?}"),
        }
        match c2.topk(&req).unwrap() {
            Response::TopK(items) => assert_eq!(items.len(), 3),
            other => panic!("expected recovery after injected error, got {other:?}"),
        }
        h.stop();

        // serve.accept: an accept hiccup is logged and the loop keeps
        // accepting.
        fault::configure("serve.accept=once").unwrap();
        let mut h2 = serve(model, &cfg).unwrap();
        let mut c3 = Client::connect(&h2.addr()).unwrap();
        assert_eq!(c3.ping().unwrap(), Response::Ok);
        h2.stop();
        fault::reset();
    }
}
