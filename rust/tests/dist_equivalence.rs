//! The transport conformance contract (PR 8): a multi-process `tcp` run —
//! worker processes owning the table shards, collectives over the wire —
//! is **bitwise identical** to the single-process `local` run it emulates:
//! same objective history, same final W/H bits, same recalls, same
//! checkpoint bytes, and *exactly* the same `CommStats` byte accounting,
//! for both topologies (parameter-server and all-reduce) at every thread
//! count. A killed worker mid-run fails the epoch cleanly, with the
//! previously written checkpoint intact.
//!
//! Workers run as in-process threads here (same code path as `alx worker`
//! minus process spawning); the CI dist smoke covers the real
//! multi-process `alx launch` flow.

use alx::als::{EpochStats, TrainConfig};
use alx::collectives::CommSnapshot;
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::dist::{DistConfig, DistMode, Worker};
use alx::prelude::*;
use alx::topo::{ideal_epoch_comm, Workload};
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize, cores: usize) -> AlxConfig {
    AlxConfig {
        cores,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            threads,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_dist_eq_{}_{}", tag, std::process::id()))
}

/// In-process worker fleet: each worker is the `alx worker` serve loop on
/// an ephemeral port, running on its own thread.
struct Fleet {
    addrs: Vec<String>,
    stops: Vec<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_fleet(n: usize) -> Fleet {
    let mut addrs = Vec::new();
    let mut stops = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let w = Worker::bind("127.0.0.1:0").unwrap();
        addrs.push(w.local_addr().unwrap().to_string());
        stops.push(w.stop_handle());
        handles.push(std::thread::spawn(move || w.serve().unwrap()));
    }
    Fleet { addrs, stops, handles }
}

impl Fleet {
    fn join(self) {
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

fn dist_cfg(topology: &str, addrs: &[String]) -> DistConfig {
    DistConfig {
        mode: DistMode::Tcp,
        topology: topology.to_string(),
        workers: addrs.to_vec(),
        heartbeat_ms: 0,
    }
}

fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

struct RunResult {
    history: Vec<(usize, Option<u64>, u64)>,
    w: Vec<f32>,
    h: Vec<f32>,
    recalls: Vec<(usize, u64)>,
    comm: CommSnapshot,
    checkpoint: Vec<u8>,
}

/// Run a session to completion, checkpoint it, and collect every
/// observable the conformance contract compares.
fn run(mut s: TrainSession, ckpt_tag: &str) -> RunResult {
    let report = s.run().unwrap();
    let ckpt = tmp(ckpt_tag);
    s.checkpoint(&ckpt).unwrap();
    let bytes = std::fs::read(&ckpt).unwrap();
    let _ = std::fs::remove_file(&ckpt);
    // In tcp mode this politely stops the fleet; locally it is a no-op.
    s.trainer.collectives().shutdown().unwrap();
    RunResult {
        history: report.history.iter().map(fingerprint).collect(),
        w: s.trainer.w.to_dense().data,
        h: s.trainer.h.to_dense().data,
        recalls: report.recalls.iter().map(|r| (r.k, r.recall.to_bits())).collect(),
        comm: report.comm,
        checkpoint: bytes,
    }
}

#[test]
fn tcp_runs_are_bitwise_identical_to_local() {
    let m = community_matrix(80, 48, 3);
    for threads in [1usize, 4] {
        let local = {
            let source = InMemorySource::new("community", m.clone());
            TrainSession::new(&source, cfg(2, threads, 4)).unwrap()
        };
        let local = run(local, &format!("local_t{threads}"));
        assert!(local.comm.total_bytes() > 0, "local run must price collectives");

        for topology in ["parameter-server", "all-reduce"] {
            let fleet = spawn_fleet(4);
            let tcp = {
                let mut c = cfg(2, threads, 4);
                c.dist = dist_cfg(topology, &fleet.addrs);
                let source = InMemorySource::new("community", m.clone());
                TrainSession::new(&source, c).unwrap()
            };
            let tcp = run(tcp, &format!("tcp_{topology}_t{threads}"));
            fleet.join();
            let tag = format!("{topology}, threads={threads}");
            assert_eq!(tcp.history, local.history, "objective history differs ({tag})");
            assert_eq!(tcp.w, local.w, "W differs ({tag})");
            assert_eq!(tcp.h, local.h, "H differs ({tag})");
            assert_eq!(tcp.recalls, local.recalls, "recalls differ ({tag})");
            // The conformance oracle: byte-for-byte identical accounting.
            assert_eq!(tcp.comm, local.comm, "CommStats differ ({tag})");
            assert_eq!(tcp.checkpoint, local.checkpoint, "checkpoint bytes differ ({tag})");
        }
    }
}

#[test]
fn heartbeats_do_not_perturb_the_run() {
    // Same equivalence with the failure detector armed: ping traffic rides
    // a separate connection and must not show up anywhere in the oracle.
    let m = community_matrix(60, 40, 5);
    let local = {
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, cfg(2, 2, 4)).unwrap()
    };
    let local = run(local, "hb_local");

    let fleet = spawn_fleet(2);
    let tcp = {
        let mut c = cfg(2, 2, 4);
        c.dist = dist_cfg("parameter-server", &fleet.addrs);
        c.dist.heartbeat_ms = 20;
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };
    let tcp = run(tcp, "hb_tcp");
    fleet.join();
    assert_eq!(tcp.history, local.history);
    assert_eq!(tcp.w, local.w);
    assert_eq!(tcp.comm, local.comm);
}

#[test]
fn predicted_comm_bytes_bound_measured_at_4_and_8_shards() {
    // The topo cost model's ideal volume vs the trainer's measured
    // CommStats: they differ only by the dense-batcher's padding factor
    // and the eval holdout, at every shard count — and the tcp
    // transports measure *exactly* what local measures, so this
    // cross-check covers both topologies via the equality tests above.
    let m = community_matrix(80, 48, 7);
    for cores in [4usize, 8] {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(1, 2, cores)).unwrap();
        let before = s.trainer.comm.snapshot();
        let stats = s.step().unwrap();
        let epoch = s.trainer.comm.snapshot().since(&before);
        assert_eq!(stats.comm_bytes, epoch.total_bytes());

        let w = Workload {
            nnz: m.nnz() as u64,
            rows_plus_cols: (m.rows + m.cols) as u64,
            dim: s.cfg.train.dim,
            elem_bytes: s.trainer.w.storage().elem_bytes(),
            batch_rows: s.cfg.train.batch_rows,
            batch_width: s.cfg.train.batch_width,
        };
        let predicted = ideal_epoch_comm(&w, s.trainer.w.num_shards());
        // The model assumes zero batch padding over the *full* matrix;
        // the measured run pads each row's slots up to the batch width
        // but also trains without the held-out split rows. Both effects
        // are small constants, so measured must land inside a tight
        // ratio window of ideal — per collective and in total.
        let check = |what: &str, measured: u64, ideal: u64| {
            assert!(
                measured >= ideal / 2 && measured <= ideal * 4,
                "cores={cores}: measured {what} {measured} outside [{}..{}] around ideal {ideal}",
                ideal / 2,
                ideal * 4
            );
        };
        check("all-gather", epoch.all_gather_bytes, predicted.all_gather_bytes);
        check("all-reduce", epoch.all_reduce_bytes, predicted.all_reduce_bytes);
        check("total", epoch.total_bytes(), predicted.total_bytes());
    }
}

#[test]
fn killed_worker_aborts_cleanly_with_checkpoint_intact() {
    let m = community_matrix(60, 40, 9);
    let ckpt = tmp("kill.ckpt");

    let fleet = spawn_fleet(2);
    let mut s = {
        let mut c = cfg(3, 2, 4);
        c.dist = dist_cfg("parameter-server", &fleet.addrs);
        c.dist.heartbeat_ms = 25;
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };
    s.step().unwrap();
    s.checkpoint(&ckpt).unwrap();

    // Kill worker 1: its serve loop and connection handlers exit, closing
    // every socket. Join so the death is complete before the next step.
    let Fleet { stops, mut handles, .. } = fleet;
    stops[1].store(true, std::sync::atomic::Ordering::SeqCst);
    handles.remove(1).join().unwrap();

    // The next epoch must fail cleanly — an Err, not a hang or a panic.
    let err = s.step().expect_err("epoch must abort once a worker is dead");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker"), "error should name the worker: {msg}");
    drop(s);
    stops[0].store(true, std::sync::atomic::Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }

    // The pre-kill checkpoint is intact: a local session resumes from it
    // at the checkpointed epoch and trains on unharmed.
    let source = InMemorySource::new("community", m.clone());
    let mut resumed = TrainSession::resume_with(&ckpt, &source, cfg(3, 2, 4), None).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 1);
    resumed.step().unwrap();
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn tcp_resume_is_bitwise_identical_to_local_resume() {
    // Checkpoint restore re-pushes the restored bits to the worker fleet
    // (push_tables), so a resumed tcp run continues bitwise with local.
    let m = community_matrix(80, 48, 11);
    let ckpt = tmp("resume.ckpt");
    {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(3, 2, 4)).unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let finish = |c: AlxConfig| {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::resume_with(&ckpt, &source, c, None).unwrap();
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        s.trainer.collectives().shutdown().unwrap();
        (s.trainer.w.to_dense().data, s.trainer.h.to_dense().data)
    };
    let local = finish(cfg(3, 2, 4));

    let fleet = spawn_fleet(4);
    let mut c = cfg(3, 2, 4);
    c.dist = dist_cfg("all-reduce", &fleet.addrs);
    let tcp = finish(c);
    fleet.join();
    assert_eq!(tcp.0, local.0, "resumed W differs");
    assert_eq!(tcp.1, local.1, "resumed H differs");
    let _ = std::fs::remove_file(&ckpt);
}
