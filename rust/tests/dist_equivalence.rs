//! The transport conformance contract (PR 8, extended by the worker-side
//! solve offload): a multi-process `tcp` run — worker processes owning
//! the table shards, collectives over the wire — is **bitwise identical**
//! to the single-process `local` run it emulates: same objective history,
//! same final W/H bits, same recalls, same checkpoint bytes, and
//! *exactly* the same `CommStats` byte accounting, for both topologies
//! (parameter-server and all-reduce) at every thread count — and in both
//! compute placements (`coordinator` solves locally, `worker` pushes the
//! solves to the shard owners). A killed worker mid-run fails the epoch
//! cleanly, with the previously written checkpoint intact.
//!
//! Workers run as in-process threads here (same code path as `alx worker`
//! minus process spawning); the CI dist smoke covers the real
//! multi-process `alx launch` flow.

use alx::als::{EngineKind, EpochStats, TrainConfig};
use alx::collectives::{CommSnapshot, WireSnapshot};
use alx::config::AlxConfig;
use alx::coordinator::TrainSession;
use alx::data::InMemorySource;
use alx::dist::{DistCompute, DistConfig, DistMode, Worker};
use alx::prelude::*;
use alx::topo::{ideal_epoch_comm, ideal_worker_compute_wire, Workload};
use alx::util::Pcg64;
use std::path::PathBuf;

fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut t = Vec::new();
    for u in 0..users as u32 {
        let comm = (u as usize) % 2;
        for _ in 0..6 {
            let item = if rng.next_f64() < 0.9 {
                comm * (items / 2) + rng.range(0, items / 2)
            } else {
                rng.range(0, items)
            };
            t.push((u, item as u32, 1.0));
        }
    }
    Csr::from_coo(users, items, &t)
}

fn cfg(epochs: usize, threads: usize, cores: usize) -> AlxConfig {
    AlxConfig {
        cores,
        train: TrainConfig {
            dim: 8,
            epochs,
            lambda: 0.05,
            alpha: 0.01,
            batch_rows: 16,
            batch_width: 4,
            threads,
            ..TrainConfig::default()
        },
        ..AlxConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("alx_dist_eq_{}_{}", tag, std::process::id()))
}

/// In-process worker fleet: each worker is the `alx worker` serve loop on
/// an ephemeral port, running on its own thread.
struct Fleet {
    addrs: Vec<String>,
    stops: Vec<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_fleet(n: usize) -> Fleet {
    let mut addrs = Vec::new();
    let mut stops = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let w = Worker::bind("127.0.0.1:0").unwrap();
        addrs.push(w.local_addr().unwrap().to_string());
        stops.push(w.stop_handle());
        handles.push(std::thread::spawn(move || w.serve().unwrap()));
    }
    Fleet { addrs, stops, handles }
}

impl Fleet {
    fn join(self) {
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

fn dist_cfg(topology: &str, addrs: &[String]) -> DistConfig {
    DistConfig {
        mode: DistMode::Tcp,
        topology: topology.to_string(),
        workers: addrs.to_vec(),
        heartbeat_ms: 0,
        compute: DistCompute::Coordinator,
    }
}

/// [`dist_cfg`] in owner-computes mode: the workers run the solves.
fn worker_dist_cfg(topology: &str, addrs: &[String]) -> DistConfig {
    DistConfig { compute: DistCompute::Worker, ..dist_cfg(topology, addrs) }
}

fn fingerprint(h: &EpochStats) -> (usize, Option<u64>, u64) {
    (h.epoch, h.objective.map(f64::to_bits), h.comm_bytes)
}

struct RunResult {
    history: Vec<(usize, Option<u64>, u64)>,
    w: Vec<f32>,
    h: Vec<f32>,
    recalls: Vec<(usize, u64)>,
    comm: CommSnapshot,
    /// Transport-measured frame bytes (`None` on the Local backend).
    wire: Option<WireSnapshot>,
    checkpoint: Vec<u8>,
}

/// Run a session to completion, checkpoint it, and collect every
/// observable the conformance contract compares.
fn run(mut s: TrainSession, ckpt_tag: &str) -> RunResult {
    let report = s.run().unwrap();
    let ckpt = tmp(ckpt_tag);
    s.checkpoint(&ckpt).unwrap();
    let bytes = std::fs::read(&ckpt).unwrap();
    let _ = std::fs::remove_file(&ckpt);
    let wire = s.trainer.collectives().wire_snapshot();
    // In tcp mode this politely stops the fleet; locally it is a no-op.
    s.trainer.collectives().shutdown().unwrap();
    RunResult {
        history: report.history.iter().map(fingerprint).collect(),
        w: s.trainer.w.to_dense().data,
        h: s.trainer.h.to_dense().data,
        recalls: report.recalls.iter().map(|r| (r.k, r.recall.to_bits())).collect(),
        comm: report.comm,
        wire,
        checkpoint: bytes,
    }
}

#[test]
fn tcp_runs_are_bitwise_identical_to_local() {
    let m = community_matrix(80, 48, 3);
    for threads in [1usize, 4] {
        let local = {
            let source = InMemorySource::new("community", m.clone());
            TrainSession::new(&source, cfg(2, threads, 4)).unwrap()
        };
        let local = run(local, &format!("local_t{threads}"));
        assert!(local.comm.total_bytes() > 0, "local run must price collectives");

        for topology in ["parameter-server", "all-reduce"] {
            let fleet = spawn_fleet(4);
            let tcp = {
                let mut c = cfg(2, threads, 4);
                c.dist = dist_cfg(topology, &fleet.addrs);
                let source = InMemorySource::new("community", m.clone());
                TrainSession::new(&source, c).unwrap()
            };
            let tcp = run(tcp, &format!("tcp_{topology}_t{threads}"));
            fleet.join();
            let tag = format!("{topology}, threads={threads}");
            assert_eq!(tcp.history, local.history, "objective history differs ({tag})");
            assert_eq!(tcp.w, local.w, "W differs ({tag})");
            assert_eq!(tcp.h, local.h, "H differs ({tag})");
            assert_eq!(tcp.recalls, local.recalls, "recalls differ ({tag})");
            // The conformance oracle: byte-for-byte identical accounting.
            assert_eq!(tcp.comm, local.comm, "CommStats differ ({tag})");
            assert_eq!(tcp.checkpoint, local.checkpoint, "checkpoint bytes differ ({tag})");
            // Gather-request dedup: a full dense batch draws more slot ids
            // than the item table has rows, so repeats are guaranteed and
            // the wire must carry strictly fewer ids than the collective
            // requested — without moving any of the bit-exact results
            // above or the priced CommStats.
            let wire = tcp.wire.expect("tcp transport measures wire traffic");
            assert!(wire.total_bytes() > 0, "no wire traffic measured ({tag})");
            assert!(
                wire.gather_ids_sent < wire.gather_ids_pre_dedup,
                "gather dedup must shrink the id stream ({tag}): {wire:?}"
            );
        }
        assert!(local.wire.is_none(), "the Local backend has no wire to measure");
    }
}

#[test]
fn worker_compute_runs_are_bitwise_identical_to_local() {
    // The tentpole contract: `compute = "worker"` moves every solve to
    // the shard owners (peer-mesh gathers, worker-side engine, in-place
    // write-back) and must still reproduce the local run bit for bit —
    // same objective history, tables, recalls, CommStats and checkpoint
    // bytes — across worker counts, thread counts, both engines and both
    // topologies.
    let m = community_matrix(80, 48, 13);
    for engine in [EngineKind::Qr, EngineKind::IalsPp] {
        for threads in [1usize, 4] {
            let mk_cfg = || {
                let mut c = cfg(2, threads, 4);
                c.train.engine = engine;
                c.train.block_dim = 4;
                c
            };
            let local = {
                let source = InMemorySource::new("community", m.clone());
                TrainSession::new(&source, mk_cfg()).unwrap()
            };
            let local = run(local, &format!("wc_local_{engine:?}_t{threads}"));
            for workers in [2usize, 4] {
                for topology in ["parameter-server", "all-reduce"] {
                    let fleet = spawn_fleet(workers);
                    let tcp = {
                        let mut c = mk_cfg();
                        c.dist = worker_dist_cfg(topology, &fleet.addrs);
                        let source = InMemorySource::new("community", m.clone());
                        TrainSession::new(&source, c).unwrap()
                    };
                    let tag = format!("wc_{engine:?}_{topology}_t{threads}_w{workers}");
                    let tcp = run(tcp, &tag);
                    fleet.join();
                    assert_eq!(tcp.history, local.history, "objective history differs ({tag})");
                    assert_eq!(tcp.w, local.w, "W differs ({tag})");
                    assert_eq!(tcp.h, local.h, "H differs ({tag})");
                    assert_eq!(tcp.recalls, local.recalls, "recalls differ ({tag})");
                    assert_eq!(tcp.comm, local.comm, "CommStats differ ({tag})");
                    assert_eq!(
                        tcp.checkpoint, local.checkpoint,
                        "checkpoint bytes differ ({tag})"
                    );
                    // Peer-mesh gathers dedup repeated fixed-side ids the
                    // same way the coordinator's gathers do.
                    let wire = tcp.wire.expect("worker-compute runs measure wire traffic");
                    assert!(wire.total_bytes() > 0, "no wire traffic measured ({tag})");
                    assert!(
                        wire.gather_ids_sent < wire.gather_ids_pre_dedup,
                        "peer-gather dedup must shrink the id stream ({tag}): {wire:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn worker_compute_resume_is_bitwise_identical_to_local_resume() {
    // Mid-training resume under worker-side solves: restore re-pushes the
    // checkpointed bits to the fleet, and the remaining epochs solve on
    // the workers — still bitwise the local continuation.
    let m = community_matrix(80, 48, 15);
    let ckpt = tmp("wc_resume.ckpt");
    {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(3, 2, 4)).unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let finish = |c: AlxConfig| {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::resume_with(&ckpt, &source, c, None).unwrap();
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        s.trainer.collectives().shutdown().unwrap();
        (s.trainer.w.to_dense().data, s.trainer.h.to_dense().data)
    };
    let local = finish(cfg(3, 2, 4));

    let fleet = spawn_fleet(4);
    let mut c = cfg(3, 2, 4);
    c.dist = worker_dist_cfg("parameter-server", &fleet.addrs);
    let wc = finish(c);
    fleet.join();
    assert_eq!(wc.0, local.0, "resumed W differs");
    assert_eq!(wc.1, local.1, "resumed H differs");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn worker_compute_wire_bytes_bounded_by_ideal() {
    // The topo model's worker-compute wire volume vs the transport's
    // measured frame bytes: the ideal assumes zero batch padding and
    // prices the peer mesh at one fetch per slot (dedup and locally
    // hosted rows shrink the real number), while framing/opcode/ack
    // overheads inflate it — so measured lands inside a generous ratio
    // window rather than on the nose.
    let m = community_matrix(80, 48, 17);
    let fleet = spawn_fleet(4);
    let mut c = cfg(1, 2, 4);
    c.dist = worker_dist_cfg("parameter-server", &fleet.addrs);
    let source = InMemorySource::new("community", m.clone());
    let mut s = TrainSession::new(&source, c).unwrap();
    s.step().unwrap();
    let wire = s.trainer.collectives().wire_snapshot().expect("tcp measures wire traffic");
    s.trainer.collectives().shutdown().unwrap();
    drop(s);
    fleet.join();

    let w = Workload {
        nnz: m.nnz() as u64,
        rows_plus_cols: (m.rows + m.cols) as u64,
        dim: 8,
        elem_bytes: 2,
        batch_rows: 16,
        batch_width: 4,
    };
    let ideal = ideal_worker_compute_wire(&w, 4, 4);
    let measured = wire.total_bytes();
    assert!(
        measured >= ideal / 4 && measured <= ideal * 4,
        "measured wire bytes {measured} outside [{}..{}] around ideal {ideal}",
        ideal / 4,
        ideal * 4
    );
}

#[test]
fn heartbeats_do_not_perturb_the_run() {
    // Same equivalence with the failure detector armed: ping traffic rides
    // a separate connection and must not show up anywhere in the oracle.
    let m = community_matrix(60, 40, 5);
    let local = {
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, cfg(2, 2, 4)).unwrap()
    };
    let local = run(local, "hb_local");

    let fleet = spawn_fleet(2);
    let tcp = {
        let mut c = cfg(2, 2, 4);
        c.dist = dist_cfg("parameter-server", &fleet.addrs);
        c.dist.heartbeat_ms = 20;
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };
    let tcp = run(tcp, "hb_tcp");
    fleet.join();
    assert_eq!(tcp.history, local.history);
    assert_eq!(tcp.w, local.w);
    assert_eq!(tcp.comm, local.comm);
}

#[test]
fn predicted_comm_bytes_bound_measured_at_4_and_8_shards() {
    // The topo cost model's ideal volume vs the trainer's measured
    // CommStats: they differ only by the dense-batcher's padding factor
    // and the eval holdout, at every shard count — and the tcp
    // transports measure *exactly* what local measures, so this
    // cross-check covers both topologies via the equality tests above.
    let m = community_matrix(80, 48, 7);
    for cores in [4usize, 8] {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(1, 2, cores)).unwrap();
        let before = s.trainer.comm.snapshot();
        let stats = s.step().unwrap();
        let epoch = s.trainer.comm.snapshot().since(&before);
        assert_eq!(stats.comm_bytes, epoch.total_bytes());

        let w = Workload {
            nnz: m.nnz() as u64,
            rows_plus_cols: (m.rows + m.cols) as u64,
            dim: s.cfg.train.dim,
            elem_bytes: s.trainer.w.storage().elem_bytes(),
            batch_rows: s.cfg.train.batch_rows,
            batch_width: s.cfg.train.batch_width,
        };
        let predicted = ideal_epoch_comm(&w, s.trainer.w.num_shards());
        // The model assumes zero batch padding over the *full* matrix;
        // the measured run pads each row's slots up to the batch width
        // but also trains without the held-out split rows. Both effects
        // are small constants, so measured must land inside a tight
        // ratio window of ideal — per collective and in total.
        let check = |what: &str, measured: u64, ideal: u64| {
            assert!(
                measured >= ideal / 2 && measured <= ideal * 4,
                "cores={cores}: measured {what} {measured} outside [{}..{}] around ideal {ideal}",
                ideal / 2,
                ideal * 4
            );
        };
        check("all-gather", epoch.all_gather_bytes, predicted.all_gather_bytes);
        check("all-reduce", epoch.all_reduce_bytes, predicted.all_reduce_bytes);
        check("total", epoch.total_bytes(), predicted.total_bytes());
    }
}

fn killed_worker_drill(compute: DistCompute, tag: &str) {
    let m = community_matrix(60, 40, 9);
    let ckpt = tmp(&format!("kill_{tag}.ckpt"));

    let fleet = spawn_fleet(2);
    let mut s = {
        let mut c = cfg(3, 2, 4);
        c.dist = dist_cfg("parameter-server", &fleet.addrs);
        c.dist.compute = compute;
        c.dist.heartbeat_ms = 25;
        let source = InMemorySource::new("community", m.clone());
        TrainSession::new(&source, c).unwrap()
    };
    s.step().unwrap();
    s.checkpoint(&ckpt).unwrap();

    // Kill worker 1: its serve loop and connection handlers exit, closing
    // every socket. Join so the death is complete before the next step.
    let Fleet { stops, mut handles, .. } = fleet;
    stops[1].store(true, std::sync::atomic::Ordering::SeqCst);
    handles.remove(1).join().unwrap();

    // The next epoch must fail cleanly — an Err, not a hang or a panic.
    let err = s.step().expect_err("epoch must abort once a worker is dead");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker"), "error should name the worker: {msg}");
    drop(s);
    stops[0].store(true, std::sync::atomic::Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }

    // The pre-kill checkpoint is intact: a local session resumes from it
    // at the checkpointed epoch and trains on unharmed.
    let source = InMemorySource::new("community", m.clone());
    let mut resumed = TrainSession::resume_with(&ckpt, &source, cfg(3, 2, 4), None).unwrap();
    assert_eq!(resumed.trainer.current_epoch(), 1);
    resumed.step().unwrap();
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn killed_worker_aborts_cleanly_with_checkpoint_intact() {
    killed_worker_drill(DistCompute::Coordinator, "coord");
}

#[test]
fn killed_worker_aborts_cleanly_under_worker_compute() {
    // Same drill with the solves on the workers: the death can surface
    // through a failed SOLVE_BATCH, a failed peer gather inside the
    // surviving worker, or the heartbeat — all of them abort the epoch
    // cleanly with the checkpoint intact.
    killed_worker_drill(DistCompute::Worker, "wc");
}

#[test]
fn tcp_resume_is_bitwise_identical_to_local_resume() {
    // Checkpoint restore re-pushes the restored bits to the worker fleet
    // (push_tables), so a resumed tcp run continues bitwise with local.
    let m = community_matrix(80, 48, 11);
    let ckpt = tmp("resume.ckpt");
    {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::new(&source, cfg(3, 2, 4)).unwrap();
        s.step().unwrap();
        s.checkpoint(&ckpt).unwrap();
    }
    let finish = |c: AlxConfig| {
        let source = InMemorySource::new("community", m.clone());
        let mut s = TrainSession::resume_with(&ckpt, &source, c, None).unwrap();
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        s.trainer.collectives().shutdown().unwrap();
        (s.trainer.w.to_dense().data, s.trainer.h.to_dense().data)
    };
    let local = finish(cfg(3, 2, 4));

    let fleet = spawn_fleet(4);
    let mut c = cfg(3, 2, 4);
    c.dist = dist_cfg("all-reduce", &fleet.addrs);
    let tcp = finish(c);
    fleet.join();
    assert_eq!(tcp.0, local.0, "resumed W differs");
    assert_eq!(tcp.1, local.1, "resumed H differs");
    let _ = std::fs::remove_file(&ckpt);
}
