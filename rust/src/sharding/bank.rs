//! `ALXTAB01` — the shard-major on-disk embedding-table bank behind
//! spilled models.
//!
//! `ALXBANK01` took the training *matrix* out of host RAM (PR 4); at
//! WebGraph scale the term that actually dominates is the *model* —
//! `rows × dim × precision` for each of W and H. A table bank stores one
//! embedding table shard-major: a fixed-shape segment of raw bf16/f32
//! elements per shard, with a validated directory of per-shard offsets,
//! so a single shard's rows can be faulted in (and written back) without
//! touching the rest of the file.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "ALXTAB01" + 8 zero bytes            16 bytes
//! rows u64 | dim u64 | num_shards u64 | elem_bytes u64 (2 = bf16, 4 = f32)
//! directory, num_shards entries:
//!   seg_offset u64 | seg_rows u64
//! per shard segment (back to back, in shard order):
//!   seg_rows × dim elements (u16 bf16 bits, or f32 bits)
//! ```
//!
//! Shard `p` holds global rows `[p·per, min((p+1)·per, rows))` with
//! `per = ceil(rows / num_shards)` — the exact uniform partition of
//! [`super::ShardedTable`], so table-bank shard `p` is the scatter target
//! of matrix shard pass `p`.
//!
//! [`TableBank::open`] memory-maps the file **read-write** (shards are
//! written back in place after each pass through
//! [`TableBank::store_shard`]) and validates the entire structure up
//! front — header against the exact file length, every directory entry
//! against the canonical layout — so a corrupt or lying file fails with
//! `InvalidData` before any shard-sized allocation and decodes are
//! infallible afterwards. Segment payloads are raw numeric bits; any bit
//! pattern is a valid element, so there is no content validation to do.

use super::{ShardData, Storage};
use crate::sparse::bank::per_for;
use crate::util::mmap::MmapMut;
use crate::util::{durable, fault};
use std::io::{Result, Write};
use std::path::Path;

/// File magic of the table-bank format (padded to 16 bytes on disk).
pub const ALXTAB01_MAGIC: &[u8; 8] = b"ALXTAB01";
const MAGIC_BYTES: usize = 16;
/// Magic + rows/dim/num_shards/elem_bytes.
const HEADER_BYTES: usize = MAGIC_BYTES + 4 * 8;
const DIR_ENTRY_BYTES: usize = 2 * 8;

fn bad(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

fn shard_range(rows: usize, per: usize, p: usize) -> (usize, usize) {
    ((p * per).min(rows), ((p + 1) * per).min(rows))
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

/// Writes an `ALXTAB01` file. Every segment's shape is fixed by the
/// header (uniform partition × dim × element size), so the header and the
/// full directory are emitted up front and shards are appended in order —
/// no backpatching, and a streaming writer never holds more than the
/// shard currently being encoded.
pub struct TableBankWriter<W: Write> {
    w: W,
    rows: usize,
    dim: usize,
    num_shards: usize,
    per: usize,
    storage: Storage,
    next_shard: usize,
}

impl<W: Write> TableBankWriter<W> {
    /// Start a bank for a `rows × dim` table in `num_shards` uniform
    /// row-range shards of `storage`-precision elements. Writes the full
    /// header and directory immediately.
    pub fn create(
        mut w: W,
        rows: usize,
        dim: usize,
        num_shards: usize,
        storage: Storage,
    ) -> Result<Self> {
        if num_shards == 0 {
            return Err(bad("table bank needs at least one shard"));
        }
        if rows as u64 > u32::MAX as u64 {
            return Err(bad("table rows exceed the u32 id space"));
        }
        if dim == 0 || dim as u64 > u32::MAX as u64 {
            return Err(bad(format!("table dim {dim} out of range")));
        }
        let per = per_for(rows, num_shards);
        let elem = storage.elem_bytes();
        let mut header = vec![0u8; HEADER_BYTES + num_shards * DIR_ENTRY_BYTES];
        header[..ALXTAB01_MAGIC.len()].copy_from_slice(ALXTAB01_MAGIC);
        header[MAGIC_BYTES..MAGIC_BYTES + 8].copy_from_slice(&(rows as u64).to_le_bytes());
        header[MAGIC_BYTES + 8..MAGIC_BYTES + 16].copy_from_slice(&(dim as u64).to_le_bytes());
        header[MAGIC_BYTES + 16..MAGIC_BYTES + 24]
            .copy_from_slice(&(num_shards as u64).to_le_bytes());
        header[MAGIC_BYTES + 24..MAGIC_BYTES + 32].copy_from_slice(&elem.to_le_bytes());
        let mut offset = header.len() as u64;
        for p in 0..num_shards {
            let (start, end) = shard_range(rows, per, p);
            let e = HEADER_BYTES + p * DIR_ENTRY_BYTES;
            header[e..e + 8].copy_from_slice(&offset.to_le_bytes());
            header[e + 8..e + 16].copy_from_slice(&((end - start) as u64).to_le_bytes());
            offset += (end - start) as u64 * dim as u64 * elem;
        }
        w.write_all(&header)?;
        Ok(TableBankWriter { w, rows, dim, num_shards, per, storage, next_shard: 0 })
    }

    /// Append the next shard's payload. Its element type must match the
    /// bank's storage and its length the uniform partition's row count.
    pub fn write_shard(&mut self, data: &ShardData) -> Result<()> {
        if self.next_shard >= self.num_shards {
            return Err(bad(format!(
                "table bank already holds the declared {} shards",
                self.num_shards
            )));
        }
        if data.storage() != self.storage {
            return Err(bad(format!(
                "shard {} is {:?}, the bank stores {:?}",
                self.next_shard,
                data.storage(),
                self.storage
            )));
        }
        let (start, end) = shard_range(self.rows, self.per, self.next_shard);
        let want = (end - start) * self.dim;
        if data.elems() != want {
            return Err(bad(format!(
                "shard {} has {} elements, the uniform partition wants {want}",
                self.next_shard,
                data.elems()
            )));
        }
        // Failpoint `tab.write_shard`: one hit per shard segment, byte
        // counter advanced by the segment's on-disk size.
        fault::failpoint_bytes(
            "tab.write_shard",
            want as u64 * self.storage.elem_bytes(),
        )?;
        let mut buf = Vec::with_capacity(want * self.storage.elem_bytes() as usize);
        match data {
            ShardData::Bf16(v) => {
                for &x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            ShardData::F32(v) => {
                for &x in v {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        self.w.write_all(&buf)?;
        self.next_shard += 1;
        Ok(())
    }

    /// Verify every shard arrived, flush, and return the inner writer.
    pub fn finish(mut self) -> Result<W> {
        if self.next_shard != self.num_shards {
            return Err(bad(format!(
                "table bank got {} of the declared {} shards",
                self.next_shard, self.num_shards
            )));
        }
        fault::failpoint("tab.finish")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// A validated, read-write memory-mapped `ALXTAB01` file. Shards decode
/// into owned [`ShardData`] on demand ([`TableBank::load_shard`]) and are
/// written back in place ([`TableBank::store_shard`]); writes go through
/// the shared mapping, so later loads — from this handle or a fresh open
/// — see them.
#[derive(Debug)]
pub struct TableBank {
    map: MmapMut,
    pub rows: usize,
    pub dim: usize,
    storage: Storage,
    per: usize,
    num_shards: usize,
}

impl TableBank {
    /// Open and fully validate a table bank. Every structural invariant
    /// is checked here (exact file size, canonical directory), so later
    /// decodes cannot fail.
    pub fn open(path: impl AsRef<Path>) -> Result<TableBank> {
        fault::failpoint("tab.open")?;
        let path = path.as_ref();
        let f = durable::retry("table bank open", || {
            std::fs::OpenOptions::new().read(true).write(true).open(path)
        })
        .map_err(|e| durable::annotate(e, &format!("table bank {}", path.display())))?;
        let map = MmapMut::map_mut(&f)?;
        Self::from_map(map)
    }

    fn from_map(map: MmapMut) -> Result<TableBank> {
        let b = map.bytes();
        if b.len() < HEADER_BYTES {
            return Err(bad("file too short for an ALXTAB01 header"));
        }
        if &b[..ALXTAB01_MAGIC.len()] != ALXTAB01_MAGIC
            || b[ALXTAB01_MAGIC.len()..MAGIC_BYTES].iter().any(|&x| x != 0)
        {
            return Err(bad("bad magic (expected ALXTAB01)"));
        }
        let rows64 = u64_at(b, MAGIC_BYTES);
        let dim64 = u64_at(b, MAGIC_BYTES + 8);
        let shards64 = u64_at(b, MAGIC_BYTES + 16);
        let elem64 = u64_at(b, MAGIC_BYTES + 24);
        if rows64 > u32::MAX as u64 {
            return Err(bad(format!("rows {rows64} exceeds the u32 id space")));
        }
        if dim64 == 0 || dim64 > u32::MAX as u64 {
            return Err(bad(format!("dim {dim64} out of range")));
        }
        let storage = match elem64 {
            2 => Storage::Bf16,
            4 => Storage::F32,
            other => return Err(bad(format!("element size {other} is neither bf16 nor f32"))),
        };
        if shards64 == 0 {
            return Err(bad("table bank declares zero shards"));
        }
        // The directory must fit in the file before anything is sized
        // from it, so a lying shard count cannot force an over-read.
        let dir_end = HEADER_BYTES as u128 + shards64 as u128 * DIR_ENTRY_BYTES as u128;
        if dir_end > b.len() as u128 {
            return Err(bad(format!(
                "directory for {shards64} shards does not fit the {}-byte file",
                b.len()
            )));
        }
        let rows = rows64 as usize;
        let dim = dim64 as usize;
        let num_shards = shards64 as usize;
        let per = per_for(rows, num_shards);

        // Directory: offsets must follow the canonical back-to-back
        // layout and per-shard rows the uniform partition. u128
        // arithmetic so lying fields fail the bounds, not wrap.
        let mut expect_off = dir_end;
        for p in 0..num_shards {
            let e = HEADER_BYTES + p * DIR_ENTRY_BYTES;
            let off = u64_at(b, e);
            let seg_rows = u64_at(b, e + 8);
            let (start, end) = shard_range(rows, per, p);
            if seg_rows != (end - start) as u64 {
                return Err(bad(format!(
                    "shard {p} directory claims {seg_rows} rows, the uniform \
                     partition of {rows} rows over {num_shards} shards wants {}",
                    end - start
                )));
            }
            if off as u128 != expect_off {
                return Err(bad(format!(
                    "shard {p} offset {off} breaks the canonical layout (expected {expect_off})"
                )));
            }
            expect_off += seg_rows as u128 * dim as u128 * elem64 as u128;
            if expect_off > b.len() as u128 {
                return Err(bad(format!(
                    "shard {p} segment runs past the end of the {}-byte file",
                    b.len()
                )));
            }
        }
        if expect_off != b.len() as u128 {
            return Err(bad(format!(
                "table bank should be {expect_off} bytes, file is {}",
                b.len()
            )));
        }
        Ok(TableBank { map, rows, dim, storage, per, num_shards })
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    pub fn storage(&self) -> Storage {
        self.storage
    }

    /// Bytes of the on-disk bank file.
    pub fn file_bytes(&self) -> u64 {
        self.map.len() as u64
    }

    /// Global row range `[start, end)` of shard `p`.
    pub fn shard_range(&self, p: usize) -> (usize, usize) {
        shard_range(self.rows, self.per, p)
    }

    /// Byte offset of shard `p`'s segment.
    fn seg_offset(&self, p: usize) -> usize {
        let (start, _) = self.shard_range(p);
        HEADER_BYTES
            + self.num_shards * DIR_ENTRY_BYTES
            + start * self.dim * self.storage.elem_bytes() as usize
    }

    /// Element count of shard `p`'s segment.
    fn seg_elems(&self, p: usize) -> usize {
        let (start, end) = self.shard_range(p);
        (end - start) * self.dim
    }

    /// Decode shard `p` into owned [`ShardData`]. Infallible after the
    /// validation [`TableBank::open`] performed — this is the "table
    /// shard fault" cost of the demand-paged model path.
    pub fn load_shard(&self, p: usize) -> ShardData {
        let n = self.seg_elems(p);
        let off = self.seg_offset(p);
        let b = self.map.bytes();
        match self.storage {
            Storage::Bf16 => ShardData::Bf16(
                b[off..off + n * 2]
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            Storage::F32 => ShardData::F32(
                b[off..off + n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
        }
    }

    /// Write shard `p`'s payload back in place (the write-back half of a
    /// shard checkout). The data must match the bank's storage and the
    /// shard's element count exactly.
    pub fn store_shard(&mut self, p: usize, data: &ShardData) -> Result<()> {
        let n = self.seg_elems(p);
        if data.storage() != self.storage || data.elems() != n {
            return Err(bad(format!(
                "shard {p} write-back shape mismatch: got {} {:?} elements, bank wants {n} {:?}",
                data.elems(),
                data.storage(),
                self.storage
            )));
        }
        // Failpoint `tab.store_shard`: one hit per write-back, byte counter
        // advanced by the segment's size.
        fault::failpoint_bytes("tab.store_shard", n as u64 * self.storage.elem_bytes())?;
        let off = self.seg_offset(p);
        let elem = self.storage.elem_bytes() as usize;
        let dst = &mut self.map.bytes_mut()[off..off + n * elem];
        match data {
            ShardData::Bf16(v) => {
                for (c, x) in dst.chunks_exact_mut(2).zip(v) {
                    c.copy_from_slice(&x.to_le_bytes());
                }
            }
            ShardData::F32(v) => {
                for (c, x) in dst.chunks_exact_mut(4).zip(v) {
                    c.copy_from_slice(&x.to_le_bytes());
                }
            }
        }
        self.map.flush_range(off, n * elem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::ShardedTable;
    use crate::util::Pcg64;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_tab_{}_{}.alxtab", tag, std::process::id()))
    }

    fn write_bank(
        rows: usize,
        dim: usize,
        shards: usize,
        storage: Storage,
        tag: &str,
    ) -> (ShardedTable, std::path::PathBuf) {
        let mut rng = Pcg64::new(rows as u64 ^ 0x7ab);
        let t = ShardedTable::randn(rows, dim, shards, storage, &mut rng);
        let path = tmp(tag);
        t.spill_to_bank(&path).unwrap();
        (t, path)
    }

    #[test]
    fn bank_roundtrips_every_shard_both_storages() {
        for storage in [Storage::F32, Storage::Bf16] {
            for shards in [1usize, 2, 3, 7, 41] {
                let tag = format!("rt{shards}{}", storage.elem_bytes());
                let (t, path) = write_bank(41, 5, shards, storage, &tag);
                let bank = TableBank::open(&path).unwrap();
                assert_eq!(bank.rows, 41);
                assert_eq!(bank.dim, 5);
                assert_eq!(bank.num_shards(), shards);
                assert_eq!(bank.storage(), storage);
                for p in 0..shards {
                    let r = t.range(p);
                    assert_eq!(bank.shard_range(p), (r.start, r.end));
                    let loaded = bank.load_shard(p);
                    t.with_shard_data(p, |data| {
                        assert_eq!(&loaded, data, "shard {p}/{shards} {storage:?}")
                    });
                }
                let _ = std::fs::remove_file(&path);
            }
        }
    }

    #[test]
    fn store_shard_writes_through_to_fresh_opens() {
        let (_, path) = write_bank(20, 3, 4, Storage::F32, "wt");
        {
            let mut bank = TableBank::open(&path).unwrap();
            let mut data = bank.load_shard(1);
            if let ShardData::F32(v) = &mut data {
                for (i, x) in v.iter_mut().enumerate() {
                    *x = i as f32 * 0.5;
                }
            }
            bank.store_shard(1, &data).unwrap();
            // The same handle sees the write.
            assert_eq!(bank.load_shard(1), data);
        }
        // And so does a fresh open of the file.
        let bank = TableBank::open(&path).unwrap();
        if let ShardData::F32(v) = bank.load_shard(1) {
            assert_eq!(v[2], 1.0);
        } else {
            panic!("expected f32 shard");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn writer_rejects_wrong_shapes() {
        let buf = std::io::Cursor::new(Vec::new());
        let mut w = TableBankWriter::create(buf, 10, 4, 2, Storage::F32).unwrap();
        // Wrong length for the partition (shard 0 wants 5 rows × 4).
        assert!(w.write_shard(&ShardData::F32(vec![0.0; 8])).is_err());
        // Wrong element type.
        assert!(w.write_shard(&ShardData::Bf16(vec![0; 20])).is_err());
        // Correct shards, then one too many.
        w.write_shard(&ShardData::F32(vec![0.0; 20])).unwrap();
        w.write_shard(&ShardData::F32(vec![0.0; 20])).unwrap();
        assert!(w.write_shard(&ShardData::F32(vec![0.0; 20])).is_err());
        // Short banks fail at finish.
        let buf = std::io::Cursor::new(Vec::new());
        let mut w = TableBankWriter::create(buf, 10, 4, 2, Storage::F32).unwrap();
        w.write_shard(&ShardData::F32(vec![0.0; 20])).unwrap();
        assert!(w.finish().is_err());
    }

    #[test]
    fn open_rejects_bad_magic_and_short_files() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTATAB!XXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXX").unwrap();
        assert!(TableBank::open(&path).is_err());
        std::fs::write(&path, b"ALXTAB01").unwrap();
        assert!(TableBank::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
