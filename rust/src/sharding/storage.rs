//! Pluggable shard storage behind [`super::ShardedTable`] — the
//! table-side twin of [`crate::sparse::CsrStorage`].
//!
//! The ALS epoch touches the embedding tables one uniform shard at a
//! time on the write side (shard pass μ scatters only into table shard
//! μ, paper Fig. 2) and row-at-a-time on the read side (gathers,
//! gramians, the objective), so where a table's shards *live* is a
//! storage policy, not a trainer concern. A [`TableStorage`] backend
//! hands out decoded shards:
//!
//! * [`ResidentShards`] — every shard a host-RAM `Vec`, borrowed
//!   directly. The default; exactly the pre-spill behaviour, with zero
//!   indirection on the fused-gather hot path.
//! * [`PagedTable`] — shards live in a read-write-mapped `ALXTAB01` bank
//!   and materialize on demand through a residency manager: an LRU of at
//!   most `resident_table_shards` decoded shards plus deduplicated
//!   background prefetch of the shard a pass is about to check out.
//!   Mutation is checkout/checkin: a shard pass checks its shard out
//!   once, scatters into the owned copy, and the check-in writes the
//!   exact element bits back through the mapping — which is what keeps
//!   spilled-model training bitwise identical to resident.
//!
//! Steady-state memory of a paged table is bounded by the residency cap
//! plus the shards currently checked out by active passes (at most the
//! shard-worker count), never by `rows × dim`.

use super::bank::TableBank;
use super::ShardData;
use crate::sparse::SpillStats;
use crate::util::fault;
use crate::util::threads::{lock_or_recover, stall_timeout_ms};
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Where the row-range shards of a [`super::ShardedTable`] live.
///
/// Contract: a backend is either *resident* (the `resident`/`resident_mut`
/// accessors return `Some`, and the table mutates shards in place) or
/// *paged* (they return `None`, and mutation goes through
/// [`TableStorage::checkout`]/[`TableStorage::checkin`]). The decoded
/// bytes of a shard are identical whichever backend serves them.
pub trait TableStorage: Send + Sync + std::fmt::Debug {
    fn num_shards(&self) -> usize;

    /// Direct borrow of shard `s` for resident backends (`None` → read
    /// through [`TableStorage::shard`] handles).
    fn resident(&self, s: usize) -> Option<&ShardData>;

    /// Direct mutable borrow of every shard for resident backends
    /// (`None` → mutate through checkout/checkin).
    fn resident_mut(&mut self) -> Option<&mut [ShardData]>;

    /// Materialized handle to shard `s` (may fault it in from disk).
    fn shard(&self, s: usize) -> Arc<ShardData>;

    /// Hint that shard `s` will be requested soon (no-op by default).
    fn prefetch(&self, _s: usize) {}

    /// Check shard `s` out for mutation: its current contents, owned.
    /// Resident backends never see this call (the table mutates their
    /// shards in place through `resident_mut`).
    fn checkout(&self, s: usize) -> ShardData;

    /// Check a mutated shard back in (write-through for paged backends).
    fn checkin(&self, s: usize, data: ShardData);

    /// [`TableStorage::checkin`] for unwinding contexts: must not panic.
    /// Returns `false` (after logging) when the write-back failed instead
    /// of propagating — a view dropped during a panic must neither abort
    /// the process with a double panic nor silently lose the shard.
    fn checkin_nopanic(&self, s: usize, data: ShardData) -> bool {
        self.checkin(s, data);
        true
    }

    /// Residency/fault accounting (all zero for resident backends).
    fn spill_stats(&self) -> SpillStats {
        SpillStats::default()
    }

    /// Bytes currently resident in host memory.
    fn resident_bytes(&self) -> u64;

    fn clone_box(&self) -> Box<dyn TableStorage>;
}

/// The default backend: every shard resident in host RAM.
#[derive(Clone, Debug, Default)]
pub struct ResidentShards {
    shards: Vec<ShardData>,
}

impl ResidentShards {
    pub fn new(shards: Vec<ShardData>) -> ResidentShards {
        ResidentShards { shards }
    }
}

impl TableStorage for ResidentShards {
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn resident(&self, s: usize) -> Option<&ShardData> {
        Some(&self.shards[s])
    }

    fn resident_mut(&mut self) -> Option<&mut [ShardData]> {
        Some(&mut self.shards)
    }

    fn shard(&self, s: usize) -> Arc<ShardData> {
        // Cold path only — every reader prefers the `resident` borrow.
        Arc::new(self.shards[s].clone())
    }

    fn checkout(&self, _s: usize) -> ShardData {
        unreachable!("resident table shards mutate in place")
    }

    fn checkin(&self, _s: usize, _data: ShardData) {
        unreachable!("resident table shards mutate in place")
    }

    fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|d| d.memory_bytes()).sum()
    }

    fn clone_box(&self) -> Box<dyn TableStorage> {
        Box::new(self.clone())
    }
}

/// LRU residency state of a [`PagedTable`]: front = most recently used.
struct TableResidency {
    resident: VecDeque<(usize, Arc<ShardData>)>,
    loading: HashSet<usize>,
}

struct PagedShared {
    /// The mapped bank. Behind a mutex because check-ins write through
    /// the mapping; decodes and write-backs are short memcpy-speed
    /// critical sections and never nest with the residency lock.
    bank: Mutex<TableBank>,
    cap: usize,
    num_shards: usize,
    file_bytes: u64,
    state: Mutex<TableResidency>,
    loaded: Condvar,
    faults: AtomicU64,
    hits: AtomicU64,
    prefetches: AtomicU64,
    prefetch_failures: AtomicU64,
}

impl PagedShared {
    /// Insert a freshly decoded shard at the MRU position unless one is
    /// already resident, and evict past the cap. Evicted handles still in
    /// use elsewhere stay alive until their last `Arc` drops — eviction
    /// never invalidates a reader.
    fn insert_fresh(&self, p: usize, data: Arc<ShardData>) {
        let mut g = lock_or_recover(&self.state);
        g.loading.remove(&p);
        if !g.resident.iter().any(|(q, _)| *q == p) {
            g.resident.push_front((p, data));
            while g.resident.len() > self.cap {
                g.resident.pop_back();
            }
        }
        drop(g);
        self.loaded.notify_all();
    }

    /// Insert a checked-in shard, *replacing* any stale resident copy —
    /// after a write-back the cache must serve the new contents.
    fn insert_replace(&self, p: usize, data: Arc<ShardData>) {
        let mut g = lock_or_recover(&self.state);
        if let Some(pos) = g.resident.iter().position(|(q, _)| *q == p) {
            g.resident.remove(pos);
        }
        g.resident.push_front((p, data));
        while g.resident.len() > self.cap {
            g.resident.pop_back();
        }
        drop(g);
        self.loaded.notify_all();
    }

    /// Decode shard `p` from the mapped bank.
    fn load(&self, p: usize) -> Arc<ShardData> {
        let bank = lock_or_recover(&self.bank);
        Arc::new(bank.load_shard(p))
    }
}

/// Clears a shard's in-flight `loading` mark when dropped, so a panic
/// mid-decode wakes the condvar waiters instead of wedging them forever
/// (they retry and surface the failure on their own thread). The
/// successful path's insert already removed the mark; the second removal
/// is a no-op.
struct TableLoadingGuard<'a> {
    shared: &'a PagedShared,
    p: usize,
}

impl Drop for TableLoadingGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock_or_recover(&self.shared.state);
        g.loading.remove(&self.p);
        drop(g);
        self.shared.loaded.notify_all();
    }
}

/// Demand-paged table storage over a read-write-mapped `ALXTAB01` bank.
#[derive(Clone)]
pub struct PagedTable {
    shared: Arc<PagedShared>,
}

impl PagedTable {
    /// Wrap an opened bank with a residency cap of `resident_table_shards`
    /// decoded shards (clamped to at least 1).
    pub fn new(bank: TableBank, resident_table_shards: usize) -> PagedTable {
        let num_shards = bank.num_shards();
        let file_bytes = bank.file_bytes();
        PagedTable {
            shared: Arc::new(PagedShared {
                bank: Mutex::new(bank),
                cap: resident_table_shards.max(1),
                num_shards,
                file_bytes,
                state: Mutex::new(TableResidency {
                    resident: VecDeque::new(),
                    loading: HashSet::new(),
                }),
                loaded: Condvar::new(),
                faults: AtomicU64::new(0),
                hits: AtomicU64::new(0),
                prefetches: AtomicU64::new(0),
                prefetch_failures: AtomicU64::new(0),
            }),
        }
    }

    /// Max decoded shards resident at once.
    pub fn resident_cap(&self) -> usize {
        self.shared.cap
    }

    /// Write a shard's bits back through the mapped bank.
    fn write_back(&self, s: usize, data: &ShardData) -> std::io::Result<()> {
        let mut bank = lock_or_recover(&self.shared.bank);
        bank.store_shard(s, data)
    }
}

impl std::fmt::Debug for PagedTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedTable")
            .field("shards", &self.shared.num_shards)
            .field("cap", &self.shared.cap)
            .finish()
    }
}

impl TableStorage for PagedTable {
    fn num_shards(&self) -> usize {
        self.shared.num_shards
    }

    fn resident(&self, _s: usize) -> Option<&ShardData> {
        None
    }

    fn resident_mut(&mut self) -> Option<&mut [ShardData]> {
        None
    }

    fn shard(&self, p: usize) -> Arc<ShardData> {
        let s = &*self.shared;
        let mut g = lock_or_recover(&s.state);
        loop {
            if let Some(pos) = g.resident.iter().position(|(q, _)| *q == p) {
                let entry = g.resident.remove(pos).unwrap();
                let data = Arc::clone(&entry.1);
                g.resident.push_front(entry);
                s.hits.fetch_add(1, Ordering::Relaxed);
                return data;
            }
            if g.loading.contains(&p) {
                // A prefetch (or another reader) is already decoding it.
                // Bounded wait: if the loader stalls or dies without
                // clearing its mark, steal the load and fault on demand
                // instead of hanging the epoch.
                let (ng, timeout) = s
                    .loaded
                    .wait_timeout(g, Duration::from_millis(stall_timeout_ms()))
                    .unwrap_or_else(|e| e.into_inner());
                g = ng;
                if timeout.timed_out() && g.loading.contains(&p) {
                    crate::log_warn!(
                        "background load of table shard {p} stalled past {}ms; \
                         loading on demand",
                        stall_timeout_ms()
                    );
                    g.loading.remove(&p);
                }
                continue;
            }
            // Fault: decode synchronously on this thread.
            g.loading.insert(p);
            drop(g);
            let guard = TableLoadingGuard { shared: s, p };
            let data = s.load(p);
            s.faults.fetch_add(1, Ordering::Relaxed);
            s.insert_fresh(p, Arc::clone(&data));
            drop(guard);
            return data;
        }
    }

    fn prefetch(&self, p: usize) {
        let s = &*self.shared;
        {
            let mut g = lock_or_recover(&s.state);
            if g.loading.contains(&p) || g.resident.iter().any(|(q, _)| *q == p) {
                return;
            }
            g.loading.insert(p);
        }
        s.prefetches.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || {
            // Panic isolation: a dying prefetch thread clears its loading
            // mark (the guard) and is counted, and the reader degrades to
            // an on-demand fault — never a hung epoch or lost shard.
            let guard = TableLoadingGuard { shared: &shared, p };
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                fault::failpoint("prefetch.table")?;
                let data = shared.load(p);
                shared.insert_fresh(p, data);
                Ok::<(), std::io::Error>(())
            }));
            match r {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    shared.prefetch_failures.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!(
                        "prefetch of table shard {p} failed ({e}); it will load on demand"
                    );
                }
                Err(_) => {
                    shared.prefetch_failures.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!(
                        "prefetch thread for table shard {p} panicked; it will load on demand"
                    );
                }
            }
            drop(guard);
        });
    }

    fn checkout(&self, s: usize) -> ShardData {
        // A checkout is a read (fault or hit) plus an owned copy the
        // caller mutates; the matching checkin writes it back.
        let handle = self.shard(s);
        (*handle).clone()
    }

    fn checkin(&self, s: usize, data: ShardData) {
        // Shapes are fixed by construction; a write-back can only fail on
        // the non-unix owned-buffer fallback's file IO (or an injected
        // fault), and silently dropping updates would corrupt training.
        self.write_back(s, &data).expect("table bank write-back failed");
        self.shared.insert_replace(s, Arc::new(data));
    }

    fn checkin_nopanic(&self, s: usize, data: ShardData) -> bool {
        match self.write_back(s, &data) {
            Ok(()) => {
                self.shared.insert_replace(s, Arc::new(data));
                true
            }
            Err(e) => {
                // The cache keeps serving what is actually on disk; the
                // loss is loud, not silent, and the caller is already
                // unwinding from its own failure.
                crate::log_error!("table shard {s} write-back failed during unwind: {e}");
                false
            }
        }
    }

    fn spill_stats(&self) -> SpillStats {
        let s = &*self.shared;
        SpillStats {
            shard_faults: s.faults.load(Ordering::Relaxed),
            prefetch_hits: s.hits.load(Ordering::Relaxed),
            prefetches: s.prefetches.load(Ordering::Relaxed),
            prefetch_failures: s.prefetch_failures.load(Ordering::Relaxed),
            bank_bytes: s.file_bytes,
        }
    }

    fn resident_bytes(&self) -> u64 {
        let g = lock_or_recover(&self.shared.state);
        g.resident.iter().map(|(_, d)| d.memory_bytes()).sum()
    }

    fn clone_box(&self) -> Box<dyn TableStorage> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ShardedTable, Storage};
    use super::*;
    use crate::util::Pcg64;

    fn tab_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_tabstore_{}_{}.alxtab", tag, std::process::id()))
    }

    fn paged(rows: usize, shards: usize, cap: usize, tag: &str) -> (ShardedTable, PagedTable) {
        let mut rng = Pcg64::new(7);
        let t = ShardedTable::randn(rows, 4, shards, Storage::F32, &mut rng);
        let path = tab_path(tag);
        t.spill_to_bank(&path).unwrap();
        let store = PagedTable::new(TableBank::open(&path).unwrap(), cap);
        let _ = std::fs::remove_file(&path); // unix keeps the mapping alive
        (t, store)
    }

    #[test]
    fn paged_serves_identical_shards() {
        let (t, store) = paged(40, 5, 2, "ident");
        for p in 0..5 {
            let got = store.shard(p);
            t.with_shard_data(p, |want| assert_eq!(&*got, want, "shard {p}"));
        }
    }

    #[test]
    fn lru_evicts_past_the_cap_and_counts_faults() {
        let (_, store) = paged(60, 6, 2, "lru");
        for p in 0..6 {
            let _ = store.shard(p);
        }
        let s = store.spill_stats();
        assert_eq!(s.shard_faults, 6);
        assert_eq!(s.prefetch_hits, 0);
        assert!(s.bank_bytes > 0);
        // Re-touching the MRU shard hits; an evicted one faults again.
        let _ = store.shard(5);
        assert_eq!(store.spill_stats().prefetch_hits, 1);
        let _ = store.shard(0);
        assert_eq!(store.spill_stats().shard_faults, 7);
        assert!(store.resident_bytes() > 0);
    }

    #[test]
    fn prefetch_stages_a_shard_for_a_hit() {
        let (t, store) = paged(30, 3, 2, "prefetch");
        store.prefetch(1);
        let got = store.shard(1);
        t.with_shard_data(1, |want| assert_eq!(&*got, want));
        let s = store.spill_stats();
        assert_eq!(s.prefetches, 1);
        assert_eq!(s.shard_faults + s.prefetch_hits, 1);
        // Idempotent while resident or loading.
        store.prefetch(1);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(store.spill_stats().prefetches <= 2);
    }

    #[test]
    fn checkout_checkin_roundtrips_mutation() {
        let (_, store) = paged(24, 4, 1, "rw");
        let mut data = store.checkout(2);
        if let ShardData::F32(v) = &mut data {
            for x in v.iter_mut() {
                *x = 9.25;
            }
        }
        store.checkin(2, data);
        // Served from cache...
        if let ShardData::F32(v) = &*store.shard(2) {
            assert!(v.iter().all(|&x| x == 9.25));
        } else {
            panic!("expected f32 shard");
        }
        // ...and from the bank after eviction.
        let _ = store.shard(0);
        let _ = store.shard(1);
        if let ShardData::F32(v) = &*store.shard(2) {
            assert!(v.iter().all(|&x| x == 9.25));
        } else {
            panic!("expected f32 shard");
        }
    }

    #[test]
    fn concurrent_readers_agree() {
        let (t, store) = paged(80, 8, 2, "conc");
        let store = Arc::new(store);
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    for round in 0..3 {
                        for p in 0..8 {
                            let shard = store.shard((p + w) % 8);
                            assert!(shard.elems() > 0, "round {round}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for p in 0..8 {
            let got = store.shard(p);
            t.with_shard_data(p, |want| assert_eq!(&*got, want));
        }
    }
}
