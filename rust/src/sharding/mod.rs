//! Uniformly sharded embedding tables (paper §4.2, Figure 2).
//!
//! Both `W (|U|×d)` and `H (|I|×d)` are split into contiguous row ranges,
//! one per TPU core, so the pod's combined HBM bounds the model size.
//! Storage is bfloat16 (paper §4.4's memory/communication-halving choice)
//! or f32 for the precision ablation.

use crate::linalg::Mat;
use crate::util::bf16::{self, Bf16};
use crate::util::Pcg64;

/// Element storage format of a sharded table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Storage {
    /// bfloat16 — the paper's default (half the memory + comm bytes).
    Bf16,
    /// float32 — ablation / high-precision mode.
    F32,
}

impl Storage {
    pub fn elem_bytes(self) -> u64 {
        match self {
            Storage::Bf16 => 2,
            Storage::F32 => 4,
        }
    }
}

/// One shard's row range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    pub start: usize,
    pub end: usize,
}

impl ShardRange {
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    #[inline]
    pub fn contains(&self, row: usize) -> bool {
        row >= self.start && row < self.end
    }
}

/// Physical storage of one shard.
#[derive(Clone, Debug)]
enum ShardData {
    Bf16(Vec<u16>),
    F32(Vec<f32>),
}

/// Write `src` into a shard at element offset `off`, rounding to the
/// storage precision (shared by [`ShardedTable::write_row`] and
/// [`ShardViewMut::write_row`] so both round identically).
#[inline]
fn write_row_data(data: &mut ShardData, off: usize, src: &[f32]) {
    match data {
        ShardData::Bf16(v) => {
            for (b, &x) in v[off..off + src.len()].iter_mut().zip(src) {
                *b = Bf16::from_f32(x).0;
            }
        }
        ShardData::F32(v) => v[off..off + src.len()].copy_from_slice(src),
    }
}

/// An embedding table uniformly sharded over `num_shards` cores.
#[derive(Clone, Debug)]
pub struct ShardedTable {
    pub rows: usize,
    pub dim: usize,
    ranges: Vec<ShardRange>,
    shards: Vec<ShardData>,
    storage: Storage,
}

impl ShardedTable {
    /// Uniform contiguous sharding: shard `i` holds rows
    /// `[i·ceil(n/M), min((i+1)·ceil(n/M), n))`.
    pub fn ranges_for(rows: usize, num_shards: usize) -> Vec<ShardRange> {
        let per = rows.div_ceil(num_shards.max(1)).max(1);
        (0..num_shards)
            .map(|i| ShardRange { start: (i * per).min(rows), end: ((i + 1) * per).min(rows) })
            .collect()
    }

    /// Create a zeroed table.
    pub fn zeros(rows: usize, dim: usize, num_shards: usize, storage: Storage) -> ShardedTable {
        let ranges = Self::ranges_for(rows, num_shards);
        let shards = ranges
            .iter()
            .map(|r| match storage {
                Storage::Bf16 => ShardData::Bf16(vec![0u16; r.len() * dim]),
                Storage::F32 => ShardData::F32(vec![0.0f32; r.len() * dim]),
            })
            .collect();
        ShardedTable { rows, dim, ranges, shards, storage }
    }

    /// Random-normal initialization scaled by `1/sqrt(d)` (the usual MF
    /// init so initial scores are O(1)).
    pub fn randn(
        rows: usize,
        dim: usize,
        num_shards: usize,
        storage: Storage,
        rng: &mut Pcg64,
    ) -> ShardedTable {
        let mut t = Self::zeros(rows, dim, num_shards, storage);
        let scale = 1.0 / (dim as f64).sqrt();
        for s in 0..t.num_shards() {
            let mut srng = rng.split();
            let n = t.ranges[s].len() * dim;
            match &mut t.shards[s] {
                ShardData::Bf16(v) => {
                    for x in v.iter_mut().take(n) {
                        *x = Bf16::from_f32((srng.next_normal() * scale) as f32).0;
                    }
                }
                ShardData::F32(v) => {
                    for x in v.iter_mut().take(n) {
                        *x = (srng.next_normal() * scale) as f32;
                    }
                }
            }
        }
        t
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn storage(&self) -> Storage {
        self.storage
    }

    pub fn range(&self, shard: usize) -> ShardRange {
        self.ranges[shard]
    }

    /// Which shard owns `row`.
    #[inline]
    pub fn shard_of(&self, row: usize) -> usize {
        debug_assert!(row < self.rows);
        let per = self.rows.div_ceil(self.num_shards()).max(1);
        (row / per).min(self.num_shards() - 1)
    }

    /// Total stored bytes (the HBM-footprint number the capacity model uses).
    pub fn memory_bytes(&self) -> u64 {
        self.rows as u64 * self.dim as u64 * self.storage.elem_bytes()
    }

    /// Read one row into `out` (widened to f32).
    #[inline]
    pub fn read_row(&self, row: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let s = self.shard_of(row);
        let off = (row - self.ranges[s].start) * self.dim;
        match &self.shards[s] {
            ShardData::Bf16(v) => {
                for (o, &b) in out.iter_mut().zip(&v[off..off + self.dim]) {
                    *o = Bf16(b).to_f32();
                }
            }
            ShardData::F32(v) => out.copy_from_slice(&v[off..off + self.dim]),
        }
    }

    /// Write one row (rounding to the storage precision).
    #[inline]
    pub fn write_row(&mut self, row: usize, data: &[f32]) {
        debug_assert_eq!(data.len(), self.dim);
        let s = self.shard_of(row);
        let off = (row - self.ranges[s].start) * self.dim;
        write_row_data(&mut self.shards[s], off, data);
    }

    /// Split the table into one mutable view per shard, so independent
    /// shard passes can scatter concurrently without locks (Fig. 2's
    /// layout: core μ only ever writes its own shard).
    pub fn shard_views_mut(&mut self) -> Vec<ShardViewMut<'_>> {
        let dim = self.dim;
        self.ranges
            .iter()
            .zip(self.shards.iter_mut())
            .map(|(&range, data)| ShardViewMut { range, dim, data })
            .collect()
    }

    /// Gather many rows into a dense `[ids.len() × dim]` matrix.
    pub fn gather(&self, ids: &[u32]) -> Mat {
        let mut out = Mat::zeros(ids.len(), self.dim);
        for (k, &id) in ids.iter().enumerate() {
            let dst = &mut out.data[k * self.dim..(k + 1) * self.dim];
            self.read_row(id as usize, dst);
        }
        out
    }

    /// Scatter rows of `data` into the table at `ids` (overwrite semantics —
    /// each ALS solve fully replaces the row, Algorithm 2 line 19).
    pub fn scatter(&mut self, ids: &[u32], data: &Mat) {
        assert_eq!(ids.len(), data.rows);
        assert_eq!(data.cols, self.dim);
        for (k, &id) in ids.iter().enumerate() {
            self.write_row(id as usize, data.row(k));
        }
    }

    /// Shard-local gramian `H_μᵀ H_μ` (Algorithm 2 line 5); the caller
    /// all-reduce-sums these across shards (line 6).
    pub fn local_gramian(&self, shard: usize) -> Mat {
        let d = self.dim;
        let n = self.ranges[shard].len();
        let mut g = Mat::zeros(d, d);
        let mut row = vec![0.0f32; d];
        for r in 0..n {
            let off = r * d;
            match &self.shards[shard] {
                ShardData::Bf16(v) => {
                    for (o, &b) in row.iter_mut().zip(&v[off..off + d]) {
                        *o = Bf16(b).to_f32();
                    }
                }
                ShardData::F32(v) => row.copy_from_slice(&v[off..off + d]),
            }
            crate::linalg::mat::syrk_update(&mut g.data, &row, 1.0);
        }
        crate::linalg::mat::symmetrize_upper(&mut g.data, d);
        g
    }

    /// Materialize the full table as a dense matrix (eval / small problems).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.dim);
        for r in 0..self.rows {
            let d = self.dim;
            let dst = &mut out.data[r * d..(r + 1) * d];
            self.read_row(r, dst);
        }
        out
    }

    /// Squared Frobenius norm (for the training objective's λ‖·‖² term).
    pub fn fro_norm_sq(&self) -> f64 {
        let mut acc = 0.0f64;
        for s in 0..self.num_shards() {
            match &self.shards[s] {
                ShardData::Bf16(v) => {
                    for &b in v {
                        let x = Bf16(b).to_f32() as f64;
                        acc += x * x;
                    }
                }
                ShardData::F32(v) => {
                    for &x in v {
                        acc += (x as f64) * (x as f64);
                    }
                }
            }
        }
        acc
    }

    /// Raw f32 view of a shard (copies; used by the collectives emulation).
    pub fn shard_f32(&self, shard: usize) -> Vec<f32> {
        match &self.shards[shard] {
            ShardData::Bf16(v) => bf16::unpack(v),
            ShardData::F32(v) => v.clone(),
        }
    }
}

/// Mutable view of a single shard (from [`ShardedTable::shard_views_mut`]).
/// Writes are restricted to the shard's own row range, which is what makes
/// lock-free parallel shard passes safe.
pub struct ShardViewMut<'a> {
    range: ShardRange,
    dim: usize,
    data: &'a mut ShardData,
}

impl ShardViewMut<'_> {
    pub fn range(&self) -> ShardRange {
        self.range
    }

    /// Write one row (global row id), rounding to the storage precision
    /// exactly like [`ShardedTable::write_row`].
    pub fn write_row(&mut self, row: usize, data: &[f32]) {
        assert!(self.range.contains(row), "row {row} outside shard {:?}", self.range);
        assert_eq!(data.len(), self.dim);
        write_row_data(self.data, (row - self.range.start) * self.dim, data);
    }

    /// Scatter solved rows into this shard (overwrite semantics, same as
    /// [`ShardedTable::scatter`]). Every id must fall inside the shard.
    pub fn scatter(&mut self, ids: &[u32], rows: &Mat) {
        assert_eq!(ids.len(), rows.rows);
        assert_eq!(rows.cols, self.dim);
        for (k, &id) in ids.iter().enumerate() {
            self.write_row(id as usize, rows.row(k));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_rows() {
        for (rows, shards) in [(10usize, 3usize), (7, 7), (5, 8), (100, 1), (1, 4)] {
            let rs = ShardedTable::ranges_for(rows, shards);
            assert_eq!(rs.len(), shards);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, rows, "rows={rows} shards={shards}");
            // Contiguous and ordered.
            let mut prev = 0;
            for r in &rs {
                assert_eq!(r.start, prev);
                prev = r.end;
            }
        }
    }

    #[test]
    fn shard_of_agrees_with_ranges() {
        let t = ShardedTable::zeros(103, 4, 7, Storage::F32);
        for row in 0..103 {
            let s = t.shard_of(row);
            assert!(t.range(s).contains(row), "row {row} shard {s}");
        }
    }

    #[test]
    fn write_read_roundtrip_f32() {
        let mut t = ShardedTable::zeros(20, 3, 4, Storage::F32);
        t.write_row(13, &[1.5, -2.25, 3.75]);
        let mut out = [0.0f32; 3];
        t.read_row(13, &mut out);
        assert_eq!(out, [1.5, -2.25, 3.75]);
    }

    #[test]
    fn bf16_storage_rounds() {
        let mut t = ShardedTable::zeros(4, 2, 2, Storage::Bf16);
        let x = 1.0 + 1.0 / 512.0; // not representable in bf16
        t.write_row(0, &[x, 1.0]);
        let mut out = [0.0f32; 2];
        t.read_row(0, &mut out);
        assert_eq!(out[0], Bf16::round(x));
        assert_eq!(out[1], 1.0);
        assert_ne!(out[0], x);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Pcg64::new(3);
        let mut t = ShardedTable::zeros(50, 8, 5, Storage::F32);
        let ids = [3u32, 17, 44, 9];
        let data = Mat::randn(4, 8, 1.0, &mut rng);
        t.scatter(&ids, &data);
        let got = t.gather(&ids);
        assert!(got.max_abs_diff(&data) < 1e-7);
    }

    #[test]
    fn local_gramians_sum_to_global() {
        let mut rng = Pcg64::new(5);
        let t = ShardedTable::randn(37, 6, 4, Storage::F32, &mut rng);
        let dense = t.to_dense();
        let global = dense.gramian();
        let mut summed = Mat::zeros(6, 6);
        for s in 0..t.num_shards() {
            let g = t.local_gramian(s);
            for (a, b) in summed.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        assert!(summed.max_abs_diff(&global) < 1e-3);
    }

    #[test]
    fn memory_bytes_by_storage() {
        let b = ShardedTable::zeros(1000, 128, 8, Storage::Bf16);
        let f = ShardedTable::zeros(1000, 128, 8, Storage::F32);
        assert_eq!(b.memory_bytes(), 1000 * 128 * 2);
        assert_eq!(f.memory_bytes(), 2 * b.memory_bytes());
    }

    #[test]
    fn randn_init_has_expected_scale() {
        let mut rng = Pcg64::new(7);
        let t = ShardedTable::randn(2000, 16, 4, Storage::F32, &mut rng);
        // E[‖row‖²] = d · (1/√d)² = 1.
        let norm_sq = t.fro_norm_sq() / 2000.0;
        assert!((norm_sq - 1.0).abs() < 0.1, "mean row norm² = {norm_sq}");
    }

    #[test]
    fn shard_views_scatter_matches_table_scatter() {
        let mut rng = Pcg64::new(41);
        for storage in [Storage::F32, Storage::Bf16] {
            let mut a = ShardedTable::zeros(23, 5, 4, storage);
            let mut b = ShardedTable::zeros(23, 5, 4, storage);
            let ids: Vec<u32> = (0..23).collect();
            let data = Mat::randn(23, 5, 1.0, &mut rng);
            a.scatter(&ids, &data);
            // Scatter the same rows through per-shard views, shard-local ids.
            for mut view in b.shard_views_mut() {
                let r = view.range();
                for id in r.start..r.end {
                    view.write_row(id, data.row(id));
                }
            }
            assert_eq!(a.to_dense().data, b.to_dense().data);
        }
    }

    #[test]
    #[should_panic(expected = "outside shard")]
    fn shard_view_rejects_foreign_rows() {
        let mut t = ShardedTable::zeros(20, 3, 4, Storage::F32);
        let mut views = t.shard_views_mut();
        views[0].write_row(19, &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn more_shards_than_rows_leaves_empty_shards() {
        let t = ShardedTable::zeros(3, 2, 8, Storage::F32);
        let nonempty = (0..8).filter(|&s| !t.range(s).is_empty()).count();
        assert_eq!(nonempty, 3);
        // All rows still reachable.
        for r in 0..3 {
            assert!(t.range(t.shard_of(r)).contains(r));
        }
    }
}
