//! Uniformly sharded embedding tables (paper §4.2, Figure 2).
//!
//! Both `W (|U|×d)` and `H (|I|×d)` are split into contiguous row ranges,
//! one per TPU core, so the pod's combined HBM bounds the model size.
//! Storage is bfloat16 (paper §4.4's memory/communication-halving choice)
//! or f32 for the precision ablation.
//!
//! *Where* the shards live is pluggable ([`TableStorage`]): the default
//! [`ResidentShards`] backend keeps every shard in host RAM (exactly the
//! pre-spill behaviour), while [`PagedTable`] demand-pages shards out of
//! a read-write-mapped `ALXTAB01` bank ([`bank::TableBank`]) with an LRU
//! residency cap — so the *model*, not just the training matrix, can
//! outgrow host RAM. Readers and the per-pass [`ShardViewMut`] scatter
//! views borrow lazily materialized slices; on a paged backend a view
//! checks its shard out on first write and writes the exact element bits
//! back on drop, which keeps spilled-model training bitwise identical to
//! resident.

pub mod bank;
pub mod storage;

pub use bank::{TableBank, TableBankWriter, ALXTAB01_MAGIC};
pub use storage::{PagedTable, ResidentShards, TableStorage};

use crate::linalg::Mat;
use crate::sparse::SpillStats;
use crate::util::bf16::{self, Bf16};
use crate::util::Pcg64;
use std::path::Path;

/// Element storage format of a sharded table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Storage {
    /// bfloat16 — the paper's default (half the memory + comm bytes).
    Bf16,
    /// float32 — ablation / high-precision mode.
    F32,
}

impl Storage {
    pub fn elem_bytes(self) -> u64 {
        match self {
            Storage::Bf16 => 2,
            Storage::F32 => 4,
        }
    }
}

/// One shard's row range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRange {
    pub start: usize,
    pub end: usize,
}

impl ShardRange {
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    #[inline]
    pub fn contains(&self, row: usize) -> bool {
        row >= self.start && row < self.end
    }
}

/// Physical payload of one shard: the raw element array in storage
/// precision. This is the unit every [`TableStorage`] backend serves and
/// the `ALXTAB01` bank persists — one decoded representation everywhere
/// is what makes spilled and resident tables bitwise interchangeable.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardData {
    Bf16(Vec<u16>),
    F32(Vec<f32>),
}

impl ShardData {
    /// The element format this payload stores.
    pub fn storage(&self) -> Storage {
        match self {
            ShardData::Bf16(_) => Storage::Bf16,
            ShardData::F32(_) => Storage::F32,
        }
    }

    /// Number of stored elements (`shard rows × dim`).
    pub fn elems(&self) -> usize {
        match self {
            ShardData::Bf16(v) => v.len(),
            ShardData::F32(v) => v.len(),
        }
    }

    /// Bytes this payload occupies in host memory.
    pub fn memory_bytes(&self) -> u64 {
        self.elems() as u64 * self.storage().elem_bytes()
    }

    /// Decode `out.len()` elements starting at element offset `off` into
    /// f32 — the single widening path every reader shares. Public so
    /// shard-streaming consumers outside this module (the MIPS index
    /// build, the serving scorer) can decode rows from a borrowed shard
    /// without round-tripping through [`ShardedTable::read_row`]'s
    /// per-row shard lookup.
    #[inline]
    pub fn read_row_f32(&self, off: usize, out: &mut [f32]) {
        match self {
            ShardData::Bf16(v) => {
                for (o, &b) in out.iter_mut().zip(&v[off..off + out.len()]) {
                    *o = Bf16(b).to_f32();
                }
            }
            ShardData::F32(v) => out.copy_from_slice(&v[off..off + out.len()]),
        }
    }
}

/// Write `src` into a shard at element offset `off`, rounding to the
/// storage precision (shared by [`ShardedTable::write_row`] and
/// [`ShardViewMut::write_row`] so both round identically).
#[inline]
fn write_row_data(data: &mut ShardData, off: usize, src: &[f32]) {
    match data {
        ShardData::Bf16(v) => {
            for (b, &x) in v[off..off + src.len()].iter_mut().zip(src) {
                *b = Bf16::from_f32(x).0;
            }
        }
        ShardData::F32(v) => v[off..off + src.len()].copy_from_slice(src),
    }
}

/// One shard's random-normal payload (`elems` elements drawn from
/// `srng`, rounded to the storage precision) — the shared generator of
/// [`ShardedTable::randn`] and [`ShardedTable::randn_spilled`], so the
/// resident and streamed-to-bank inits produce identical bits.
fn randn_shard(elems: usize, storage: Storage, scale: f64, srng: &mut Pcg64) -> ShardData {
    match storage {
        Storage::Bf16 => ShardData::Bf16(
            (0..elems).map(|_| Bf16::from_f32((srng.next_normal() * scale) as f32).0).collect(),
        ),
        Storage::F32 => {
            ShardData::F32((0..elems).map(|_| (srng.next_normal() * scale) as f32).collect())
        }
    }
}

/// Read one row at element offset `off` into `out`, widened to f32
/// (thin alias over [`ShardData::read_row_f32`] kept for the module's
/// internal call sites).
#[inline]
fn read_row_data(data: &ShardData, off: usize, out: &mut [f32]) {
    data.read_row_f32(off, out);
}

/// Process-wide count of [`ShardedTable::to_dense`] calls. A full-table
/// materialization on a spilled model defeats the whole out-of-core
/// design, so streaming paths (eval, index build, serving) are guarded
/// by tests that snapshot this counter and assert it does not move.
static DENSE_MATERIALIZATIONS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// How many times any table has been fully materialized via
/// [`ShardedTable::to_dense`] since process start (test instrumentation).
pub fn dense_materializations() -> u64 {
    DENSE_MATERIALIZATIONS.load(std::sync::atomic::Ordering::Relaxed)
}

/// An embedding table uniformly sharded over `num_shards` cores, stored
/// behind a pluggable [`TableStorage`] backend (resident by default,
/// demand-paged out of an `ALXTAB01` bank in spilled-model mode).
#[derive(Debug)]
pub struct ShardedTable {
    pub rows: usize,
    pub dim: usize,
    ranges: Vec<ShardRange>,
    store: Box<dyn TableStorage>,
    storage: Storage,
}

impl Clone for ShardedTable {
    fn clone(&self) -> ShardedTable {
        // Cloning a paged table shares the underlying bank + residency
        // manager (like cloning an `Arc`); cloning a resident one copies.
        ShardedTable {
            rows: self.rows,
            dim: self.dim,
            ranges: self.ranges.clone(),
            store: self.store.clone_box(),
            storage: self.storage,
        }
    }
}

impl ShardedTable {
    /// Uniform contiguous sharding: shard `i` holds rows
    /// `[i·ceil(n/M), min((i+1)·ceil(n/M), n))`.
    pub fn ranges_for(rows: usize, num_shards: usize) -> Vec<ShardRange> {
        let per = rows.div_ceil(num_shards.max(1)).max(1);
        (0..num_shards)
            .map(|i| ShardRange { start: (i * per).min(rows), end: ((i + 1) * per).min(rows) })
            .collect()
    }

    /// Create a zeroed table (resident storage).
    pub fn zeros(rows: usize, dim: usize, num_shards: usize, storage: Storage) -> ShardedTable {
        let ranges = Self::ranges_for(rows, num_shards);
        let shards = ranges
            .iter()
            .map(|r| match storage {
                Storage::Bf16 => ShardData::Bf16(vec![0u16; r.len() * dim]),
                Storage::F32 => ShardData::F32(vec![0.0f32; r.len() * dim]),
            })
            .collect();
        ShardedTable { rows, dim, ranges, store: Box::new(ResidentShards::new(shards)), storage }
    }

    /// Random-normal initialization scaled by `1/sqrt(d)` (the usual MF
    /// init so initial scores are O(1)). Builds resident storage;
    /// [`ShardedTable::randn_spilled`] is the out-of-core twin.
    pub fn randn(
        rows: usize,
        dim: usize,
        num_shards: usize,
        storage: Storage,
        rng: &mut Pcg64,
    ) -> ShardedTable {
        let ranges = Self::ranges_for(rows, num_shards);
        let scale = 1.0 / (dim as f64).sqrt();
        let shards = ranges
            .iter()
            .map(|r| {
                let mut srng = rng.split();
                randn_shard(r.len() * dim, storage, scale, &mut srng)
            })
            .collect();
        ShardedTable { rows, dim, ranges, store: Box::new(ResidentShards::new(shards)), storage }
    }

    /// [`ShardedTable::randn`] streamed straight into an `ALXTAB01` bank
    /// at `path` and reopened demand-paged: peak init memory is **one
    /// shard**, and the element bits are identical to building resident
    /// and spilling (same per-shard rng splits, same rounding) — which
    /// is what lets a model that never fits in host RAM start training.
    pub fn randn_spilled(
        rows: usize,
        dim: usize,
        num_shards: usize,
        storage: Storage,
        rng: &mut Pcg64,
        path: &Path,
        resident_table_shards: usize,
    ) -> std::io::Result<ShardedTable> {
        let ranges = Self::ranges_for(rows, num_shards);
        let scale = 1.0 / (dim as f64).sqrt();
        // Staged + fsynced + renamed: a crash or full disk mid-init never
        // leaves a half-written table bank at the destination path.
        let artifact = format!("table bank {}", path.display());
        crate::util::durable::write_atomic(path, &artifact, |f| {
            let mut w = TableBankWriter::create(&mut *f, rows, dim, num_shards, storage)?;
            for r in &ranges {
                let mut srng = rng.split();
                w.write_shard(&randn_shard(r.len() * dim, storage, scale, &mut srng))?;
            }
            w.finish()?;
            Ok(())
        })?;
        Self::open_bank(path, resident_table_shards)
    }

    /// [`ShardedTable::zeros`] streamed straight into an `ALXTAB01` bank
    /// at `path` and reopened demand-paged — the landing pad checkpoint
    /// restore uses when the model should never be fully resident: peak
    /// memory is one zero shard, and the caller then streams real shards
    /// in via [`ShardedTable::update_shard`].
    pub fn zeros_spilled(
        rows: usize,
        dim: usize,
        num_shards: usize,
        storage: Storage,
        path: &Path,
        resident_table_shards: usize,
    ) -> std::io::Result<ShardedTable> {
        let ranges = Self::ranges_for(rows, num_shards);
        let artifact = format!("table bank {}", path.display());
        crate::util::durable::write_atomic(path, &artifact, |f| {
            let mut w = TableBankWriter::create(&mut *f, rows, dim, num_shards, storage)?;
            for r in &ranges {
                let shard = match storage {
                    Storage::Bf16 => ShardData::Bf16(vec![0u16; r.len() * dim]),
                    Storage::F32 => ShardData::F32(vec![0.0f32; r.len() * dim]),
                };
                w.write_shard(&shard)?;
            }
            w.finish()?;
            Ok(())
        })?;
        Self::open_bank(path, resident_table_shards)
    }

    pub fn num_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn storage(&self) -> Storage {
        self.storage
    }

    pub fn range(&self, shard: usize) -> ShardRange {
        self.ranges[shard]
    }

    /// Which shard owns `row`.
    #[inline]
    pub fn shard_of(&self, row: usize) -> usize {
        debug_assert!(row < self.rows);
        let per = self.rows.div_ceil(self.num_shards()).max(1);
        (row / per).min(self.num_shards() - 1)
    }

    /// Total stored bytes (the HBM-footprint number the capacity model uses).
    pub fn memory_bytes(&self) -> u64 {
        self.rows as u64 * self.dim as u64 * self.storage.elem_bytes()
    }

    /// Run `f` over shard `s`'s raw payload — borrowed in place on a
    /// resident backend, one residency handle (fault or cache hit) on a
    /// paged one. The shard-streaming read path gramians, norms and
    /// checkpoints use.
    #[inline]
    pub fn with_shard_data<R>(&self, s: usize, f: impl FnOnce(&ShardData) -> R) -> R {
        if let Some(data) = self.store.resident(s) {
            return f(data);
        }
        let handle = self.store.shard(s);
        f(&handle)
    }

    /// Mutate shard `s` wholesale: in place on a resident backend, as a
    /// checkout → edit → write-back cycle on a paged one. The closure
    /// receives the shard's current contents. The shard-streaming write
    /// path checkpoint restore uses.
    pub fn update_shard<R>(&mut self, s: usize, f: impl FnOnce(&mut ShardData) -> R) -> R {
        if let Some(shards) = self.store.resident_mut() {
            return f(&mut shards[s]);
        }
        let mut data = self.store.checkout(s);
        let r = f(&mut data);
        self.store.checkin(s, data);
        r
    }

    /// Hint that shard `s` is about to be read (background prefetch on
    /// paged storage; no-op for resident shards).
    pub fn prefetch_shard(&self, s: usize) {
        self.store.prefetch(s);
    }

    /// Whether this table is demand-paged out of a bank (vs. fully
    /// resident in host RAM).
    pub fn is_spilled(&self) -> bool {
        self.store.resident(0).is_none()
    }

    /// Residency/fault accounting (all zero for resident storage).
    pub fn spill_stats(&self) -> SpillStats {
        self.store.spill_stats()
    }

    /// Bytes of table data currently resident in host memory (the whole
    /// table for resident storage; at most the residency cap for paged).
    pub fn resident_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }

    /// Read one row into `out` (widened to f32).
    #[inline]
    pub fn read_row(&self, row: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let s = self.shard_of(row);
        let off = (row - self.ranges[s].start) * self.dim;
        self.with_shard_data(s, |data| read_row_data(data, off, out));
    }

    /// Write one row (rounding to the storage precision). On paged
    /// storage this checks the owning shard out and back in per call —
    /// correct but slow; bulk writers should use
    /// [`ShardedTable::update_shard`] or per-shard views instead.
    #[inline]
    pub fn write_row(&mut self, row: usize, data: &[f32]) {
        debug_assert_eq!(data.len(), self.dim);
        let s = self.shard_of(row);
        let off = (row - self.ranges[s].start) * self.dim;
        if let Some(shards) = self.store.resident_mut() {
            write_row_data(&mut shards[s], off, data);
            return;
        }
        let mut shard = self.store.checkout(s);
        write_row_data(&mut shard, off, data);
        self.store.checkin(s, shard);
    }

    /// Split the table into one mutable view per shard, so independent
    /// shard passes can scatter concurrently without locks (Fig. 2's
    /// layout: core μ only ever writes its own shard). On a paged
    /// backend each view materializes its shard lazily — checked out on
    /// the first write, written back through the bank when the view
    /// drops — so creating the views never faults the whole table in.
    pub fn shard_views_mut(&mut self) -> Vec<ShardViewMut<'_>> {
        let dim = self.dim;
        if self.store.resident_mut().is_some() {
            let shards = self.store.resident_mut().expect("checked resident above");
            return self
                .ranges
                .iter()
                .zip(shards.iter_mut())
                .map(|(&range, data)| ShardViewMut { range, dim, state: ViewState::Direct(data) })
                .collect();
        }
        let store: &dyn TableStorage = &*self.store;
        self.ranges
            .iter()
            .enumerate()
            .map(|(shard, &range)| ShardViewMut {
                range,
                dim,
                state: ViewState::Paged { store, shard, data: None },
            })
            .collect()
    }

    /// Gather many rows into a dense `[ids.len() × dim]` matrix.
    pub fn gather(&self, ids: &[u32]) -> Mat {
        let mut out = Mat::zeros(ids.len(), self.dim);
        for (k, &id) in ids.iter().enumerate() {
            let dst = &mut out.data[k * self.dim..(k + 1) * self.dim];
            self.read_row(id as usize, dst);
        }
        out
    }

    /// Scatter rows of `data` into the table at `ids` (overwrite semantics —
    /// each ALS solve fully replaces the row, Algorithm 2 line 19).
    pub fn scatter(&mut self, ids: &[u32], data: &Mat) {
        assert_eq!(ids.len(), data.rows);
        assert_eq!(data.cols, self.dim);
        for (k, &id) in ids.iter().enumerate() {
            self.write_row(id as usize, data.row(k));
        }
    }

    /// Shard-local gramian `H_μᵀ H_μ` (Algorithm 2 line 5); the caller
    /// all-reduce-sums these across shards (line 6). Streams through one
    /// shard handle, so a paged table's gramian never needs more than
    /// one shard resident per worker.
    pub fn local_gramian(&self, shard: usize) -> Mat {
        let d = self.dim;
        let n = self.ranges[shard].len();
        let mut g = Mat::zeros(d, d);
        let mut row = vec![0.0f32; d];
        self.with_shard_data(shard, |data| {
            for r in 0..n {
                read_row_data(data, r * d, &mut row);
                crate::linalg::mat::syrk_update(&mut g.data, &row, 1.0);
            }
        });
        crate::linalg::mat::symmetrize_upper(&mut g.data, d);
        g
    }

    /// Materialize the full table as a dense matrix (eval / small problems).
    /// Bumps the process-wide [`dense_materializations`] counter so tests
    /// can assert a streaming code path never fell back to this.
    pub fn to_dense(&self) -> Mat {
        DENSE_MATERIALIZATIONS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut out = Mat::zeros(self.rows, self.dim);
        for r in 0..self.rows {
            let d = self.dim;
            let dst = &mut out.data[r * d..(r + 1) * d];
            self.read_row(r, dst);
        }
        out
    }

    /// Squared Frobenius norm (for the training objective's λ‖·‖² term).
    /// Accumulated in fixed shard order into one f64, so the value is
    /// bitwise identical across storage backends.
    pub fn fro_norm_sq(&self) -> f64 {
        let mut acc = 0.0f64;
        for s in 0..self.num_shards() {
            self.with_shard_data(s, |data| match data {
                ShardData::Bf16(v) => {
                    for &b in v {
                        let x = Bf16(b).to_f32() as f64;
                        acc += x * x;
                    }
                }
                ShardData::F32(v) => {
                    for &x in v {
                        acc += (x as f64) * (x as f64);
                    }
                }
            });
        }
        acc
    }

    /// Raw f32 view of a shard (copies; used by the collectives emulation).
    pub fn shard_f32(&self, shard: usize) -> Vec<f32> {
        self.with_shard_data(shard, |data| match data {
            ShardData::Bf16(v) => bf16::unpack(v),
            ShardData::F32(v) => v.clone(),
        })
    }

    /// Write every shard into an `ALXTAB01` bank at `path` — the spill
    /// half of moving a model out of host RAM (reopen demand-paged with
    /// [`ShardedTable::open_bank`]). Element bits are persisted exactly,
    /// so a spilled table reads back bitwise identical.
    pub fn spill_to_bank(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        // Staged + fsynced + renamed: a crash or full disk mid-spill never
        // leaves a half-written table bank at the destination path.
        let path = path.as_ref();
        let artifact = format!("table bank {}", path.display());
        crate::util::durable::write_atomic(path, &artifact, |f| {
            let mut w = TableBankWriter::create(
                &mut *f,
                self.rows,
                self.dim,
                self.num_shards(),
                self.storage,
            )?;
            for s in 0..self.num_shards() {
                self.with_shard_data(s, |data| w.write_shard(data))?;
            }
            w.finish()?;
            Ok(())
        })
    }

    /// Open an `ALXTAB01` bank as a demand-paged table with a residency
    /// cap of `resident_table_shards` decoded shards. The file is fully
    /// validated before this returns.
    pub fn open_bank(
        path: impl AsRef<Path>,
        resident_table_shards: usize,
    ) -> std::io::Result<ShardedTable> {
        let bank = TableBank::open(path)?;
        let rows = bank.rows;
        let dim = bank.dim;
        let storage = bank.storage();
        let ranges = Self::ranges_for(rows, bank.num_shards());
        Ok(ShardedTable {
            rows,
            dim,
            ranges,
            store: Box::new(PagedTable::new(bank, resident_table_shards)),
            storage,
        })
    }
}

/// How a [`ShardViewMut`] reaches its shard: a direct borrow on resident
/// storage, or a lazily checked-out owned copy on paged storage.
enum ViewState<'a> {
    Direct(&'a mut ShardData),
    Paged { store: &'a dyn TableStorage, shard: usize, data: Option<ShardData> },
}

/// Mutable view of a single shard (from [`ShardedTable::shard_views_mut`]).
/// Writes are restricted to the shard's own row range, which is what makes
/// lock-free parallel shard passes safe. On paged storage the shard is
/// checked out on the first write and written back when the view drops.
pub struct ShardViewMut<'a> {
    range: ShardRange,
    dim: usize,
    state: ViewState<'a>,
}

impl<'a> ShardViewMut<'a> {
    /// The paged-storage handle + shard id this view will check out on
    /// its first write — `None` for resident shards or once the shard is
    /// already materialized. Lets a scheduler stage the deduplicated
    /// background prefetch (`store.prefetch(shard)`) *outside* whatever
    /// lock guards the view itself: prefetch may spawn a thread, which
    /// does not belong in a claim critical section.
    pub fn stage_handle(&self) -> Option<(&'a dyn TableStorage, usize)> {
        match &self.state {
            ViewState::Paged { store, shard, data } if data.is_none() => Some((*store, *shard)),
            _ => None,
        }
    }
}

impl ShardViewMut<'_> {
    pub fn range(&self) -> ShardRange {
        self.range
    }

    /// Write one row (global row id), rounding to the storage precision
    /// exactly like [`ShardedTable::write_row`].
    pub fn write_row(&mut self, row: usize, data: &[f32]) {
        assert!(self.range.contains(row), "row {row} outside shard {:?}", self.range);
        assert_eq!(data.len(), self.dim);
        let off = (row - self.range.start) * self.dim;
        match &mut self.state {
            ViewState::Direct(shard) => write_row_data(shard, off, data),
            ViewState::Paged { store, shard, data: buf } => {
                let buf = buf.get_or_insert_with(|| store.checkout(*shard));
                write_row_data(buf, off, data);
            }
        }
    }

    /// Scatter solved rows into this shard (overwrite semantics, same as
    /// [`ShardedTable::scatter`]). Every id must fall inside the shard.
    pub fn scatter(&mut self, ids: &[u32], rows: &Mat) {
        assert_eq!(ids.len(), rows.rows);
        assert_eq!(rows.cols, self.dim);
        for (k, &id) in ids.iter().enumerate() {
            self.write_row(id as usize, rows.row(k));
        }
    }
}

impl Drop for ShardViewMut<'_> {
    fn drop(&mut self) {
        if let ViewState::Paged { store, shard, data } = &mut self.state {
            if let Some(d) = data.take() {
                if std::thread::panicking() {
                    // Already unwinding: write the dirty shard back without
                    // risking a double panic (which would abort the process
                    // and lose every other shard's write-back too). A
                    // failure here is logged by the backend, not silent.
                    let _ = store.checkin_nopanic(*shard, d);
                } else {
                    store.checkin(*shard, d);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_dropped_during_panic_still_writes_back() {
        // A worker panicking between checkout and drop must neither
        // deadlock later users of the table nor silently drop the dirty
        // shard: the view's Drop checks it in on the unwind path.
        let mut rng = Pcg64::new(3);
        let t = ShardedTable::randn(24, 4, 3, Storage::F32, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("alx_shard_unwind_{}.alxtab", std::process::id()));
        t.spill_to_bank(&path).unwrap();
        let mut paged = ShardedTable::open_bank(&path, 2).unwrap();
        let marker = [7.5f32, -1.5, 0.25, 3.0];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut views = paged.shard_views_mut();
            views[1].write_row(9, &marker); // checks shard 1 out
            panic!("worker died mid-pass");
        }));
        assert!(r.is_err());
        // The dirty shard was written back during the unwind...
        let mut row = [0.0f32; 4];
        paged.read_row(9, &mut row);
        assert_eq!(row, marker);
        // ...the table is not wedged for further checkouts...
        paged.write_row(9, &[1.0, 2.0, 3.0, 4.0]);
        // ...and a fresh open of the bank sees everything.
        drop(paged);
        let reopened = ShardedTable::open_bank(&path, 2).unwrap();
        reopened.read_row(9, &mut row);
        assert_eq!(row, [1.0, 2.0, 3.0, 4.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ranges_partition_rows() {
        for (rows, shards) in [(10usize, 3usize), (7, 7), (5, 8), (100, 1), (1, 4)] {
            let rs = ShardedTable::ranges_for(rows, shards);
            assert_eq!(rs.len(), shards);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, rows, "rows={rows} shards={shards}");
            // Contiguous and ordered.
            let mut prev = 0;
            for r in &rs {
                assert_eq!(r.start, prev);
                prev = r.end;
            }
        }
    }

    #[test]
    fn shard_of_agrees_with_ranges() {
        let t = ShardedTable::zeros(103, 4, 7, Storage::F32);
        for row in 0..103 {
            let s = t.shard_of(row);
            assert!(t.range(s).contains(row), "row {row} shard {s}");
        }
    }

    #[test]
    fn write_read_roundtrip_f32() {
        let mut t = ShardedTable::zeros(20, 3, 4, Storage::F32);
        t.write_row(13, &[1.5, -2.25, 3.75]);
        let mut out = [0.0f32; 3];
        t.read_row(13, &mut out);
        assert_eq!(out, [1.5, -2.25, 3.75]);
    }

    #[test]
    fn bf16_storage_rounds() {
        let mut t = ShardedTable::zeros(4, 2, 2, Storage::Bf16);
        let x = 1.0 + 1.0 / 512.0; // not representable in bf16
        t.write_row(0, &[x, 1.0]);
        let mut out = [0.0f32; 2];
        t.read_row(0, &mut out);
        assert_eq!(out[0], Bf16::round(x));
        assert_eq!(out[1], 1.0);
        assert_ne!(out[0], x);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Pcg64::new(3);
        let mut t = ShardedTable::zeros(50, 8, 5, Storage::F32);
        let ids = [3u32, 17, 44, 9];
        let data = Mat::randn(4, 8, 1.0, &mut rng);
        t.scatter(&ids, &data);
        let got = t.gather(&ids);
        assert!(got.max_abs_diff(&data) < 1e-7);
    }

    #[test]
    fn local_gramians_sum_to_global() {
        let mut rng = Pcg64::new(5);
        let t = ShardedTable::randn(37, 6, 4, Storage::F32, &mut rng);
        let dense = t.to_dense();
        let global = dense.gramian();
        let mut summed = Mat::zeros(6, 6);
        for s in 0..t.num_shards() {
            let g = t.local_gramian(s);
            for (a, b) in summed.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
        assert!(summed.max_abs_diff(&global) < 1e-3);
    }

    #[test]
    fn memory_bytes_by_storage() {
        let b = ShardedTable::zeros(1000, 128, 8, Storage::Bf16);
        let f = ShardedTable::zeros(1000, 128, 8, Storage::F32);
        assert_eq!(b.memory_bytes(), 1000 * 128 * 2);
        assert_eq!(f.memory_bytes(), 2 * b.memory_bytes());
    }

    #[test]
    fn randn_init_has_expected_scale() {
        let mut rng = Pcg64::new(7);
        let t = ShardedTable::randn(2000, 16, 4, Storage::F32, &mut rng);
        // E[‖row‖²] = d · (1/√d)² = 1.
        let norm_sq = t.fro_norm_sq() / 2000.0;
        assert!((norm_sq - 1.0).abs() < 0.1, "mean row norm² = {norm_sq}");
    }

    #[test]
    fn shard_views_scatter_matches_table_scatter() {
        let mut rng = Pcg64::new(41);
        for storage in [Storage::F32, Storage::Bf16] {
            let mut a = ShardedTable::zeros(23, 5, 4, storage);
            let mut b = ShardedTable::zeros(23, 5, 4, storage);
            let ids: Vec<u32> = (0..23).collect();
            let data = Mat::randn(23, 5, 1.0, &mut rng);
            a.scatter(&ids, &data);
            // Scatter the same rows through per-shard views, shard-local ids.
            for mut view in b.shard_views_mut() {
                let r = view.range();
                for id in r.start..r.end {
                    view.write_row(id, data.row(id));
                }
            }
            assert_eq!(a.to_dense().data, b.to_dense().data);
        }
    }

    #[test]
    #[should_panic(expected = "outside shard")]
    fn shard_view_rejects_foreign_rows() {
        let mut t = ShardedTable::zeros(20, 3, 4, Storage::F32);
        let mut views = t.shard_views_mut();
        views[0].write_row(19, &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn more_shards_than_rows_leaves_empty_shards() {
        let t = ShardedTable::zeros(3, 2, 8, Storage::F32);
        let nonempty = (0..8).filter(|&s| !t.range(s).is_empty()).count();
        assert_eq!(nonempty, 3);
        // All rows still reachable.
        for r in 0..3 {
            assert!(t.range(t.shard_of(r)).contains(r));
        }
    }

    fn tab_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_shtab_{}_{}.alxtab", tag, std::process::id()))
    }

    #[test]
    fn spilled_table_roundtrips_bitwise() {
        let mut rng = Pcg64::new(11);
        for storage in [Storage::F32, Storage::Bf16] {
            let t = ShardedTable::randn(53, 6, 5, storage, &mut rng);
            let path = tab_path(&format!("rt{}", storage.elem_bytes()));
            t.spill_to_bank(&path).unwrap();
            let paged = ShardedTable::open_bank(&path, 2).unwrap();
            assert_eq!(paged.rows, t.rows);
            assert_eq!(paged.dim, t.dim);
            assert_eq!(paged.num_shards(), t.num_shards());
            assert_eq!(paged.storage(), t.storage());
            assert_eq!(paged.to_dense().data, t.to_dense().data, "{storage:?}");
            let s = paged.spill_stats();
            assert!(s.bank_bytes > 0);
            assert!(s.shard_faults > 0);
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn randn_spilled_matches_resident_randn_bitwise() {
        for storage in [Storage::F32, Storage::Bf16] {
            let mut rng_a = Pcg64::new(23);
            let mut rng_b = Pcg64::new(23);
            let resident = ShardedTable::randn(41, 6, 5, storage, &mut rng_a);
            let path = tab_path(&format!("rns{}", storage.elem_bytes()));
            let spilled =
                ShardedTable::randn_spilled(41, 6, 5, storage, &mut rng_b, &path, 2).unwrap();
            assert_eq!(spilled.to_dense().data, resident.to_dense().data, "{storage:?}");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn paged_views_write_back_through_the_bank() {
        let mut rng = Pcg64::new(13);
        for storage in [Storage::F32, Storage::Bf16] {
            let reference = ShardedTable::zeros(23, 5, 4, storage);
            let path = tab_path(&format!("wb{}", storage.elem_bytes()));
            reference.spill_to_bank(&path).unwrap();
            let mut resident = ShardedTable::zeros(23, 5, 4, storage);
            let mut paged = ShardedTable::open_bank(&path, 1).unwrap();
            let data = Mat::randn(23, 5, 1.0, &mut rng);
            // Write only every other row, so the write-back must merge
            // with (not replace) the untouched rows.
            for table in [&mut resident, &mut paged] {
                for mut view in table.shard_views_mut() {
                    let r = view.range();
                    for id in (r.start..r.end).step_by(2) {
                        view.write_row(id, data.row(id));
                    }
                }
            }
            assert_eq!(paged.to_dense().data, resident.to_dense().data, "{storage:?}");
            // A fresh attach to the same bank sees the writes.
            let reopened = ShardedTable::open_bank(&path, 2).unwrap();
            assert_eq!(reopened.to_dense().data, resident.to_dense().data);
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn zeros_spilled_matches_resident_zeros() {
        for storage in [Storage::F32, Storage::Bf16] {
            let path = tab_path(&format!("zs{}", storage.elem_bytes()));
            let mut spilled = ShardedTable::zeros_spilled(19, 3, 4, storage, &path, 1).unwrap();
            assert!(spilled.is_spilled());
            assert_eq!(spilled.to_dense().data, vec![0.0f32; 19 * 3]);
            // The landing pad accepts streamed shard writes like any
            // other paged table.
            spilled.write_row(7, &[1.0, 2.0, 3.0]);
            let mut out = [0.0f32; 3];
            spilled.read_row(7, &mut out);
            assert_eq!(out, [1.0, 2.0, 3.0]);
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn paged_write_row_and_scatter_work() {
        let t = ShardedTable::zeros(20, 3, 4, Storage::F32);
        let path = tab_path("wr");
        t.spill_to_bank(&path).unwrap();
        let mut paged = ShardedTable::open_bank(&path, 1).unwrap();
        paged.write_row(13, &[1.5, -2.25, 3.75]);
        paged.scatter(&[2, 19], &Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        let mut out = [0.0f32; 3];
        paged.read_row(13, &mut out);
        assert_eq!(out, [1.5, -2.25, 3.75]);
        paged.read_row(19, &mut out);
        assert_eq!(out, [4.0, 5.0, 6.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn paged_update_shard_streams() {
        let mut rng = Pcg64::new(17);
        let t = ShardedTable::randn(24, 4, 3, Storage::F32, &mut rng);
        let path = tab_path("upd");
        t.spill_to_bank(&path).unwrap();
        let mut paged = ShardedTable::open_bank(&path, 1).unwrap();
        for s in 0..paged.num_shards() {
            paged.update_shard(s, |data| {
                if let ShardData::F32(v) = data {
                    for x in v.iter_mut() {
                        *x *= 2.0;
                    }
                }
            });
        }
        let want: Vec<f32> = t.to_dense().data.iter().map(|x| x * 2.0).collect();
        assert_eq!(paged.to_dense().data, want);
        let _ = std::fs::remove_file(&path);
    }
}
