//! Minimal leveled logger (offline substitute for the `log` + `env_logger`
//! stack). Controlled by `ALX_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("ALX_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level, lazily initialized from the environment.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[doc(hidden)]
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>10}.{:03} {tag} {module}] {msg}", t.as_secs(), t.subsec_millis());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
