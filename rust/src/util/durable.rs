//! Classified IO failures, bounded retry, and atomic publication — the
//! durability substrate the spill banks and checkpoints share.
//!
//! Three concerns, in order of appearance on a failing run:
//!
//! * [`classify`] sorts an `io::Error` into transient (worth retrying),
//!   disk-full (recoverable by the operator) or permanent;
//! * [`retry`] runs an operation up to a small bounded number of attempts
//!   with exponential backoff, retrying only transient failures;
//! * [`write_atomic`] publishes a file the way the checkpoint writer does
//!   — write to a sibling `*.tmp.<pid>`, flush, `sync_all`, then rename —
//!   so a crash or error at any byte never leaves a half-published
//!   artifact at the destination path, and a failure names the artifact
//!   it lost.

use std::io;
use std::path::{Path, PathBuf};

/// What kind of failure an IO error represents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoClass {
    /// Worth retrying in place (EINTR, EWOULDBLOCK, timeouts).
    Transient,
    /// The disk (or quota) is full: the operation cannot succeed until the
    /// operator frees space, but already-published artifacts are intact.
    DiskFull,
    /// Everything else: corrupt data, permissions, missing files.
    Permanent,
}

/// Classify an IO error. ENOSPC/EDQUOT are recognized by raw os error so
/// the classification works on every toolchain in use.
pub fn classify(e: &io::Error) -> IoClass {
    if let Some(raw) = e.raw_os_error() {
        // ENOSPC / EDQUOT (linux numbering; both mean "no room").
        if raw == 28 || raw == 122 {
            return IoClass::DiskFull;
        }
    }
    match e.kind() {
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            IoClass::Transient
        }
        _ => IoClass::Permanent,
    }
}

/// Attempts [`retry`] makes before giving up on a transient failure.
pub const RETRY_ATTEMPTS: u32 = 3;

/// Run `op`, retrying transient failures up to [`RETRY_ATTEMPTS`] times
/// with exponential backoff (1ms, 4ms). Non-transient errors return
/// immediately.
pub fn retry<T>(what: &str, mut op: impl FnMut() -> io::Result<T>) -> io::Result<T> {
    let mut delay_ms = 1u64;
    let mut attempt = 1;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < RETRY_ATTEMPTS && classify(&e) == IoClass::Transient => {
                crate::log_warn!(
                    "transient IO failure in {what} (attempt {attempt}/{RETRY_ATTEMPTS}): {e}; retrying"
                );
                std::thread::sleep(std::time::Duration::from_millis(delay_ms));
                delay_ms *= 4;
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Annotate `e` with the artifact it hit; a disk-full failure additionally
/// states what was (and was not) lost, so the operator knows the run is
/// recoverable.
pub fn annotate(e: io::Error, artifact: &str) -> io::Error {
    let msg = match classify(&e) {
        IoClass::DiskFull => format!(
            "disk full writing {artifact}: {e} \
             (the partial file was removed; previously published artifacts are \
             untouched — free space and re-run)"
        ),
        _ => format!("{artifact}: {e}"),
    };
    io::Error::new(e.kind(), msg)
}

/// The sibling temp path [`write_atomic`] stages into: per-process, so
/// concurrent writers to the same destination degrade to
/// last-rename-wins instead of interleaving one file.
pub fn tmp_path(dst: &Path) -> PathBuf {
    PathBuf::from(format!("{}.tmp.{}", dst.display(), std::process::id()))
}

/// Write `dst` atomically: `write` streams into `{dst}.tmp.{pid}`, the
/// file is flushed and fsynced, then renamed over `dst`. On any error the
/// temp file is removed and the error is [`annotate`]d with `artifact`;
/// `dst` itself is never touched except by the final rename, so it either
/// keeps its previous content or holds the complete new artifact.
pub fn write_atomic<T>(
    dst: &Path,
    artifact: &str,
    write: impl FnOnce(&mut io::BufWriter<std::fs::File>) -> io::Result<T>,
) -> io::Result<T> {
    let tmp = tmp_path(dst);
    let staged = (|| -> io::Result<T> {
        let f = retry(artifact, || std::fs::File::create(&tmp))?;
        let mut w = io::BufWriter::new(f);
        let v = write(&mut w)?;
        io::Write::flush(&mut w)?;
        // fsync before the rename: otherwise a power loss can persist the
        // rename with unwritten data, destroying the previous good file
        // the atomic-rename dance is meant to protect.
        w.get_ref().sync_all()?;
        Ok(v)
    })();
    match staged {
        Ok(v) => {
            std::fs::rename(&tmp, dst).map_err(|e| annotate(e, artifact))?;
            Ok(v)
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(annotate(e, artifact))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_splits_the_three_classes() {
        assert_eq!(classify(&io::Error::from_raw_os_error(28)), IoClass::DiskFull);
        assert_eq!(classify(&io::Error::from_raw_os_error(122)), IoClass::DiskFull);
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::Interrupted, "x")),
            IoClass::Transient
        );
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::TimedOut, "x")),
            IoClass::Transient
        );
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::InvalidData, "x")),
            IoClass::Permanent
        );
        assert_eq!(
            classify(&io::Error::new(io::ErrorKind::NotFound, "x")),
            IoClass::Permanent
        );
    }

    #[test]
    fn retry_recovers_from_transient_failures() {
        let mut calls = 0;
        let v = retry("test", || {
            calls += 1;
            if calls < 3 {
                Err(io::Error::new(io::ErrorKind::Interrupted, "flaky"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(v, 42);
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_gives_up_after_bounded_attempts() {
        let mut calls = 0;
        let e = retry("test", || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::Interrupted, "always flaky"))
        })
        .unwrap_err();
        assert_eq!(calls, RETRY_ATTEMPTS);
        assert_eq!(e.kind(), io::ErrorKind::Interrupted);
    }

    #[test]
    fn retry_does_not_retry_permanent_failures() {
        let mut calls = 0;
        let _ = retry("test", || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::InvalidData, "corrupt"))
        });
        assert_eq!(calls, 1);
    }

    #[test]
    fn annotate_names_the_artifact_and_disk_full_recovery() {
        let e = annotate(io::Error::from_raw_os_error(28), "bank shards/train.alxbank");
        assert!(e.to_string().contains("disk full"), "{e}");
        assert!(e.to_string().contains("train.alxbank"), "{e}");
        let e = annotate(io::Error::new(io::ErrorKind::NotFound, "gone"), "ckpt");
        assert!(e.to_string().contains("ckpt"), "{e}");
    }

    #[test]
    fn write_atomic_publishes_complete_files_only() {
        let dir = std::env::temp_dir();
        let dst = dir.join(format!("alx_durable_ok_{}.bin", std::process::id()));
        write_atomic(&dst, "test artifact", |w| {
            io::Write::write_all(w, b"hello world")
        })
        .unwrap();
        assert_eq!(std::fs::read(&dst).unwrap(), b"hello world");

        // A failing writer must leave the previous content untouched and
        // clean up its temp file.
        let e = write_atomic(&dst, "test artifact", |w| -> io::Result<()> {
            io::Write::write_all(w, b"partial")?;
            Err(io::Error::new(io::ErrorKind::InvalidData, "boom"))
        })
        .unwrap_err();
        assert!(e.to_string().contains("test artifact"), "{e}");
        assert_eq!(std::fs::read(&dst).unwrap(), b"hello world", "dst clobbered");
        assert!(!tmp_path(&dst).exists(), "temp file left behind");
        let _ = std::fs::remove_file(&dst);
    }
}
