//! Small self-contained utilities: deterministic RNG, software bfloat16,
//! timers, a minimal logger, descriptive statistics and a scoped thread pool.
//!
//! The build environment is offline, so everything that would normally come
//! from `rand`, `half`, `log` or `rayon` is implemented here.

pub mod bf16;
pub mod durable;
pub mod fault;
pub mod logger;
pub mod mem;
pub mod mmap;
pub mod net;
pub mod rng;
pub mod stats;
pub mod threads;
pub mod timer;

pub use bf16::Bf16;
pub use rng::Pcg64;
pub use timer::Timer;
