//! Software bfloat16 — the storage format of the sharded embedding tables.
//!
//! TPUs store and multiply-accumulate in bfloat16 natively (paper §4.1,
//! §4.4); on our CPU substrate we emulate the format in software: 1 sign
//! bit, 8 exponent bits (same range as f32), 7 mantissa bits. Conversion
//! uses round-to-nearest-even, which is what the TPU vector units do.
//!
//! The paper's Figure 4 precision study — naive bf16 collapses, mixed
//! bf16-storage/f32-solve is stable — is reproduced by routing all table
//! storage through [`Bf16`] and optionally also rounding the sufficient-
//! statistic accumulation (see `als::PrecisionPolicy`).

/// A bfloat16 value stored as its raw 16-bit pattern.
#[derive(Clone, Copy, PartialEq, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3f80);

    /// Convert from f32 with round-to-nearest-even (RNE).
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Preserve NaN; set the quiet bit so truncation cannot produce Inf.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // RNE: add 0x7fff + lsb of the kept part.
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x7fff + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Widen to f32 (exact — bf16 is a prefix of the f32 bit pattern).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Round-trip an f32 through bf16 precision ("storage rounding").
    #[inline]
    pub fn round(x: f32) -> f32 {
        Self::from_f32(x).to_f32()
    }
}

impl std::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bf16({})", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Round every element of a slice to bf16 precision in place.
pub fn round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = Bf16::round(*x);
    }
}

/// Convert an f32 slice into packed bf16 words.
pub fn pack(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| Bf16::from_f32(x).0).collect()
}

/// Unpack bf16 words into f32.
pub fn unpack(xs: &[u16]) -> Vec<f32> {
    xs.iter().map(|&b| Bf16(b).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values_roundtrip() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1.5] {
            assert_eq!(Bf16::round(x), x, "{x} should be exactly representable");
        }
    }

    #[test]
    fn widening_is_exact() {
        for bits in (0..=u16::MAX).step_by(7) {
            let b = Bf16(bits);
            let f = b.to_f32();
            if f.is_nan() {
                assert!(Bf16::from_f32(f).to_f32().is_nan());
            } else {
                assert_eq!(Bf16::from_f32(f).0, bits, "bits={bits:#06x}");
            }
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between 1.0 and 1.0078125 (the next
        // bf16). RNE must choose the even mantissa, i.e. 1.0.
        let halfway = 1.0f32 + 2.0f32.powi(-8);
        assert_eq!(Bf16::round(halfway), 1.0);
        // Slightly above halfway rounds up.
        let above = 1.0f32 + 2.0f32.powi(-8) + 2.0f32.powi(-16);
        assert_eq!(Bf16::round(above), 1.0078125);
    }

    #[test]
    fn relative_error_bound() {
        // bf16 has 8 mantissa bits incl. hidden one: rel err <= 2^-8.
        let mut rng = crate::util::Pcg64::new(23);
        for _ in 0..10_000 {
            let x = (rng.next_f32() - 0.5) * 1e6;
            if x == 0.0 {
                continue;
            }
            let r = Bf16::round(x);
            assert!(((r - x) / x).abs() <= 1.0 / 256.0, "x={x} r={r}");
        }
    }

    #[test]
    fn infinity_and_nan() {
        assert_eq!(Bf16::round(f32::INFINITY), f32::INFINITY);
        assert_eq!(Bf16::round(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(Bf16::round(f32::NAN).is_nan());
    }

    #[test]
    fn large_finite_does_not_overflow_spuriously() {
        // Values below the bf16 max (~3.39e38) must stay finite.
        let x = 1e38f32;
        assert!(Bf16::round(x).is_finite());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let xs = vec![0.0f32, 1.0, -2.5, 100.0];
        assert_eq!(unpack(&pack(&xs)), xs);
    }
}
