//! PCG64 (XSL-RR 128/64) pseudo-random number generator.
//!
//! Deterministic, seedable and fast; used for dataset synthesis, embedding
//! initialization and the property-test generators. The constants are the
//! reference PCG constants (O'Neill, 2014).

/// PCG64 XSL-RR generator with 128-bit state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed. Two generators with different
    /// seeds produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into state + stream.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc };
        // Warm up to decorrelate low-entropy seeds.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample from a (truncated) power-law / Zipf distribution over
    /// `{0, .., n-1}` with exponent `s > 0` using inverse-CDF on the
    /// continuous Pareto approximation. Rank 0 is the most popular item.
    pub fn next_zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        let u = self.next_f64().max(1e-12);
        let idx = if (s - 1.0).abs() < 1e-9 {
            // CDF ~ ln(1+x)/ln(1+n)
            ((1.0 + n as f64).powf(u) - 1.0).floor()
        } else {
            let e = 1.0 - s;
            // CDF ~ ((1+x)^e - 1) / ((1+n)^e - 1)
            ((1.0 + (u * ((1.0 + n as f64).powf(e) - 1.0))).powf(1.0 / e) - 1.0).floor()
        };
        (idx as usize).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-shard streams).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg64::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = Pcg64::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let mut r = Pcg64::new(17);
        let n = 1000;
        let mut head = 0;
        for _ in 0..20_000 {
            let z = r.next_zipf(n, 1.2);
            assert!(z < n);
            if z < 10 {
                head += 1;
            }
        }
        // With s=1.2 the top-10 of 1000 should carry a large share.
        assert!(head > 5_000, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
