//! Scoped data-parallel helpers (offline substitute for `rayon`).
//!
//! The simulated multi-core SPMD execution in the coordinator maps each
//! "TPU core" to a closure; [`parallel_map_indexed`] fans those out over OS
//! threads via `std::thread::scope`. On single-CPU hosts it degrades to a
//! sequential loop with no thread overhead.

/// Lock a mutex, recovering from poisoning. The storage layers guard
/// plain data (residency queues, mapped banks) whose invariants hold
/// between operations, so a panic on one thread — injected or real — must
/// not cascade into every other thread that touches the same lock.
pub fn lock_or_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// How long a consumer waits on an in-flight background load before
/// assuming the loader died and taking over (`ALX_STALL_MS` override,
/// default 2000ms). A dead prefetch thread then degrades to an on-demand
/// fault instead of hanging the epoch.
pub fn stall_timeout_ms() -> u64 {
    static MS: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    *MS.get_or_init(|| {
        std::env::var("ALX_STALL_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .filter(|&ms| ms > 0)
            .unwrap_or(2000)
    })
}

/// Number of worker threads to use (``ALX_THREADS`` override, else the
/// machine's available parallelism).
pub fn worker_threads() -> usize {
    if let Ok(v) = std::env::var("ALX_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker count: `0` means "auto" (the `ALX_THREADS`
/// override, else the machine's available parallelism).
pub fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        worker_threads()
    } else {
        requested
    }
}

/// Apply `f(i)` for `i in 0..n`, potentially in parallel, collecting results
/// in index order. `f` must be `Sync` because multiple threads share it.
pub fn parallel_map_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_indexed_with(worker_threads(), n, f)
}

/// [`parallel_map_indexed`] with an explicit worker count. Results are
/// identical for every worker count (each index is computed independently
/// and collected in index order), which is what lets the trainer's
/// determinism contract hold across `ALX_THREADS` settings.
pub fn parallel_map_indexed_with<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let out_ptr = SyncSlice(out.as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let fref = &f;
            let nextref = &next;
            let slice = &out_ptr;
            scope.spawn(move || loop {
                let i = nextref.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so writes never alias.
                unsafe { slice.0.add(i).write(Some(v)) };
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker wrote every index")).collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-index write pattern.
struct SyncSlice<T>(*mut Option<T>);
unsafe impl<T: Send> Sync for SyncSlice<T> {}
unsafe impl<T: Send> Send for SyncSlice<T> {}

/// Chunked parallel for-each over a mutable slice: splits `xs` into
/// `chunks` contiguous pieces and runs `f(chunk_index, chunk)` on each.
pub fn parallel_chunks_mut<T, F>(xs: &mut [T], chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunks = chunks.max(1);
    let len = xs.len();
    let chunk_size = len.div_ceil(chunks);
    if chunk_size == 0 {
        return;
    }
    std::thread::scope(|scope| {
        for (ci, chunk) in xs.chunks_mut(chunk_size).enumerate() {
            let fref = &f;
            scope.spawn(move || fref(ci, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = parallel_map_indexed(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn map_handles_empty_and_single() {
        assert!(parallel_map_indexed(0, |i| i).is_empty());
        assert_eq!(parallel_map_indexed(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn chunks_cover_all_elements() {
        let mut xs = vec![0u32; 37];
        parallel_chunks_mut(&mut xs, 4, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(xs.iter().all(|&x| x == 1));
    }

    #[test]
    fn worker_threads_positive() {
        assert!(worker_threads() >= 1);
    }

    #[test]
    fn explicit_worker_counts_agree() {
        let expect: Vec<usize> = (0..57).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 64] {
            assert_eq!(parallel_map_indexed_with(workers, 57, |i| i * i), expect);
        }
    }

    #[test]
    fn resolve_workers_zero_is_auto() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(3), 3);
    }
}
