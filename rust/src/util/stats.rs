//! Descriptive statistics helpers used by dataset reports, the dense-batch
//! padding accounting and the benchmark harnesses.

/// Summary of a sample: count, mean, std, min, max and selected quantiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Compute a [`Summary`] of `xs`. Empty input yields an all-zero summary.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { count: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        count: xs.len(),
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: quantile_sorted(&sorted, 0.50),
        p90: quantile_sorted(&sorted, 0.90),
        p99: quantile_sorted(&sorted, 0.99),
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Geometric mean (positive inputs).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Format a byte count with binary units.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count like the paper's tables ("365.4M", "29904M").
pub fn human_count(c: u64) -> String {
    if c >= 1_000_000_000 {
        format!("{:.1}B", c as f64 / 1e9)
    } else if c >= 1_000_000 {
        format!("{:.1}M", c as f64 / 1e6)
    } else if c >= 1_000 {
        format!("{:.1}K", c as f64 / 1e3)
    } else {
        format!("{c}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let s = summarize(&xs);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile_sorted(&xs, 0.5), 5.0);
        assert_eq!(quantile_sorted(&xs, 0.0), 0.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn empty_summary_is_zeros() {
        let s = summarize(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn geomean_matches_hand_value() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_count(365_400_000), "365.4M");
        assert_eq!(human_count(999), "999");
    }
}
