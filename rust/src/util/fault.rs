//! Deterministic fault injection: named failpoints threaded through the
//! IO and threading choke points (chunked reader/writer, bank writers and
//! opens, prefetch threads, checkpoint save/load, the CLI tools).
//!
//! A failpoint is a call to [`failpoint`] (or [`failpoint_bytes`]) with a
//! stable name like `"ckpt.write"`. Which failpoints actually fire — and
//! how — is selected at runtime from the `ALX_FAILPOINTS` environment
//! variable, the `[fault] points` config key, or [`configure`]:
//!
//! ```text
//! ALX_FAILPOINTS='name=trigger[:action][;name=trigger[:action]...]'
//!
//! triggers:  once         fire on the first hit
//!            hit:N        fire on exactly the Nth hit (1-based)
//!            every:N      fire on every Nth hit
//!            after:BYTES  fire once the byte counter passes BYTES
//! actions:   err          io::ErrorKind::Other (default)
//!            transient    io::ErrorKind::Interrupted (retryable)
//!            enospc       raw os error 28 (disk full)
//!            panic        panic the calling thread
//!            abort        abort the whole process (crash torture)
//! ```
//!
//! Triggers are counted per failpoint in hit order, so a run with a fixed
//! thread schedule hits the same failpoint at the same operation every
//! time — the crash-torture suite derives `N` from a seeded RNG and
//! replays kills deterministically.
//!
//! Unless the crate is built with `--features failpoints`, every hook
//! compiles to an inlined `Ok(())` and the registry does not exist: the
//! production binary carries zero overhead and cannot be made to fail by
//! the environment.

/// Whether fault injection is compiled in.
pub const ENABLED: bool = cfg!(feature = "failpoints");

#[cfg(feature = "failpoints")]
mod imp {
    use std::collections::HashMap;
    use std::io;
    use std::sync::{Mutex, OnceLock};

    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Trigger {
        Once,
        Hit(u64),
        Every(u64),
        After(u64),
    }

    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Action {
        Err,
        Transient,
        Enospc,
        Panic,
        Abort,
    }

    struct FpState {
        trigger: Trigger,
        action: Action,
        hits: u64,
        bytes: u64,
        fired: bool,
    }

    fn registry() -> &'static Mutex<HashMap<String, FpState>> {
        static REG: OnceLock<Mutex<HashMap<String, FpState>>> = OnceLock::new();
        REG.get_or_init(|| {
            let mut map = HashMap::new();
            if let Ok(spec) = std::env::var("ALX_FAILPOINTS") {
                if let Err(e) = parse_into(&spec, &mut map) {
                    eprintln!("ALX_FAILPOINTS ignored: {e}");
                    map.clear();
                }
            }
            Mutex::new(map)
        })
    }

    fn parse_into(spec: &str, map: &mut HashMap<String, FpState>) -> Result<(), String> {
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, val) = part
                .split_once('=')
                .ok_or_else(|| format!("'{part}': expected name=trigger[:action]"))?;
            let toks: Vec<&str> = val.split(':').collect();
            let (trigger, rest) = match toks[0] {
                "once" => (Trigger::Once, &toks[1..]),
                kind @ ("hit" | "every" | "after") => {
                    let n = toks
                        .get(1)
                        .and_then(|s| s.parse::<u64>().ok())
                        .filter(|&n| n > 0)
                        .ok_or_else(|| format!("'{part}': {kind} needs a positive count"))?;
                    let t = match kind {
                        "hit" => Trigger::Hit(n),
                        "every" => Trigger::Every(n),
                        _ => Trigger::After(n),
                    };
                    (t, &toks[2..])
                }
                other => return Err(format!("'{part}': unknown trigger '{other}'")),
            };
            let action = match rest {
                [] => Action::Err,
                [a] => match *a {
                    "err" => Action::Err,
                    "transient" => Action::Transient,
                    "enospc" => Action::Enospc,
                    "panic" => Action::Panic,
                    "abort" => Action::Abort,
                    other => return Err(format!("'{part}': unknown action '{other}'")),
                },
                _ => return Err(format!("'{part}': too many ':' fields")),
            };
            map.insert(
                name.trim().to_string(),
                FpState { trigger, action, hits: 0, bytes: 0, fired: false },
            );
        }
        Ok(())
    }

    fn fire(name: &str, action: Action) -> io::Result<()> {
        match action {
            Action::Err => Err(io::Error::other(format!("injected fault at failpoint '{name}'"))),
            Action::Transient => Err(io::Error::new(
                io::ErrorKind::Interrupted,
                format!("injected transient fault at failpoint '{name}'"),
            )),
            // Real raw code so util::durable classifies it as DiskFull.
            Action::Enospc => Err(io::Error::from_raw_os_error(28)),
            Action::Panic => panic!("injected panic at failpoint '{name}'"),
            Action::Abort => {
                eprintln!("injected abort at failpoint '{name}'");
                std::process::abort()
            }
        }
    }

    /// Hit the named failpoint. Returns the configured failure when the
    /// trigger is due, `Ok(())` otherwise (including for unconfigured
    /// names).
    pub fn failpoint(name: &str) -> io::Result<()> {
        failpoint_bytes(name, 0)
    }

    /// [`failpoint`] that also advances the failpoint's byte counter (for
    /// `after:BYTES` triggers on streaming writers/readers).
    pub fn failpoint_bytes(name: &str, bytes: u64) -> io::Result<()> {
        let mut reg = registry().lock().unwrap_or_else(|p| p.into_inner());
        let Some(st) = reg.get_mut(name) else { return Ok(()) };
        st.hits += 1;
        st.bytes = st.bytes.saturating_add(bytes);
        let due = match st.trigger {
            Trigger::Once => !st.fired,
            Trigger::Hit(n) => st.hits == n,
            Trigger::Every(n) => st.hits % n == 0,
            Trigger::After(b) => !st.fired && st.bytes >= b,
        };
        if !due {
            return Ok(());
        }
        st.fired = true;
        let action = st.action;
        // Release the registry before panicking/aborting so a caught
        // injected panic cannot poison it for the rest of the process.
        drop(reg);
        fire(name, action)
    }

    /// Install failpoints from a spec string (same grammar as
    /// `ALX_FAILPOINTS`); merges over whatever is already configured.
    pub fn configure(spec: &str) -> Result<(), String> {
        let mut fresh = HashMap::new();
        parse_into(spec, &mut fresh)?;
        let mut reg = registry().lock().unwrap_or_else(|p| p.into_inner());
        reg.extend(fresh);
        Ok(())
    }

    /// Remove every configured failpoint (tests).
    pub fn reset() {
        registry().lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// How many times the named failpoint has been hit (0 when not
    /// configured — unconfigured hits are not counted).
    pub fn hits(name: &str) -> u64 {
        registry()
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(name)
            .map(|s| s.hits)
            .unwrap_or(0)
    }
}

#[cfg(not(feature = "failpoints"))]
mod imp {
    use std::io;

    #[inline(always)]
    pub fn failpoint(_name: &str) -> io::Result<()> {
        Ok(())
    }

    #[inline(always)]
    pub fn failpoint_bytes(_name: &str, _bytes: u64) -> io::Result<()> {
        Ok(())
    }

    /// Asking for live failpoints in a build that compiled them out is a
    /// configuration error, not a silent no-op.
    pub fn configure(spec: &str) -> Result<(), String> {
        if spec.trim().is_empty() {
            Ok(())
        } else {
            Err("failpoints are compiled out (rebuild with --features failpoints)".to_string())
        }
    }

    pub fn reset() {}

    pub fn hits(_name: &str) -> u64 {
        0
    }
}

pub use imp::{configure, failpoint, failpoint_bytes, hits, reset};

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    #[test]
    fn unconfigured_failpoints_pass() {
        assert!(failpoint("fault.test.unconfigured").is_ok());
        assert_eq!(hits("fault.test.unconfigured"), 0);
    }

    #[test]
    fn hit_n_fires_exactly_once_at_n() {
        configure("fault.test.hitn=hit:3").unwrap();
        assert!(failpoint("fault.test.hitn").is_ok());
        assert!(failpoint("fault.test.hitn").is_ok());
        let e = failpoint("fault.test.hitn").unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::Other);
        assert!(e.to_string().contains("fault.test.hitn"), "{e}");
        assert!(failpoint("fault.test.hitn").is_ok(), "hit:N fires only on the Nth hit");
    }

    #[test]
    fn once_fires_on_first_hit_only() {
        configure("fault.test.once=once:transient").unwrap();
        let e = failpoint("fault.test.once").unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::Interrupted);
        assert!(failpoint("fault.test.once").is_ok());
    }

    #[test]
    fn every_n_fires_periodically() {
        configure("fault.test.every=every:2").unwrap();
        let fired: Vec<bool> =
            (0..6).map(|_| failpoint("fault.test.every").is_err()).collect();
        assert_eq!(fired, [false, true, false, true, false, true]);
    }

    #[test]
    fn after_bytes_fires_once_past_threshold() {
        configure("fault.test.bytes=after:100:enospc").unwrap();
        assert!(failpoint_bytes("fault.test.bytes", 60).is_ok());
        let e = failpoint_bytes("fault.test.bytes", 60).unwrap_err();
        assert_eq!(e.raw_os_error(), Some(28));
        assert!(failpoint_bytes("fault.test.bytes", 1000).is_ok(), "after fires once");
    }

    #[test]
    fn injected_panic_is_catchable() {
        configure("fault.test.panic=once:panic").unwrap();
        let r = std::panic::catch_unwind(|| failpoint("fault.test.panic"));
        assert!(r.is_err());
        // The registry survives the caught panic.
        assert!(failpoint("fault.test.panic").is_ok());
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in ["noeq", "a=", "a=hit", "a=hit:0", "a=hit:x", "a=once:nope", "a=once:err:x"] {
            assert!(configure(bad).is_err(), "spec '{bad}' should be rejected");
        }
    }
}

#[cfg(all(test, not(feature = "failpoints")))]
mod tests {
    use super::*;

    #[test]
    fn compiled_out_hooks_are_noops() {
        assert!(!ENABLED);
        assert!(failpoint("anything").is_ok());
        assert!(failpoint_bytes("anything", u64::MAX).is_ok());
        assert_eq!(hits("anything"), 0);
        assert!(configure("").is_ok());
        assert!(configure("a=once").is_err(), "live spec must not be silently ignored");
    }
}
