//! Wall-clock timers and a tiny accumulating profiler used by the epoch
//! loop and the benchmark harnesses.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A simple start/elapsed timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates named durations across a run; the coordinator uses one of
/// these to break an epoch into gather/solve/scatter/batching time.
///
/// With the pipelined trainer, stage buckets are fed concurrently from
/// many threads, so totals are **aggregate busy time** (utilization),
/// not wall-clock shares: `total_secs()` can legitimately exceed the
/// epoch's `seconds` by up to the worker count, and the per-bucket
/// percentages compare stage cost, not elapsed time.
#[derive(Default)]
pub struct Profiler {
    buckets: Mutex<BTreeMap<&'static str, (Duration, u64)>>,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under the given bucket name.
    pub fn time<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Add an externally measured duration.
    pub fn add(&self, name: &'static str, d: Duration) {
        let mut map = self.buckets.lock().unwrap();
        let e = map.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Snapshot of (name, total_seconds, count), sorted by name.
    pub fn snapshot(&self) -> Vec<(&'static str, f64, u64)> {
        self.buckets
            .lock()
            .unwrap()
            .iter()
            .map(|(k, (d, n))| (*k, d.as_secs_f64(), *n))
            .collect()
    }

    /// Total seconds across all buckets.
    pub fn total_secs(&self) -> f64 {
        self.buckets.lock().unwrap().values().map(|(d, _)| d.as_secs_f64()).sum()
    }

    pub fn reset(&self) {
        self.buckets.lock().unwrap().clear();
    }

    /// Render a human-readable breakdown.
    pub fn report(&self) -> String {
        let total = self.total_secs().max(1e-12);
        let mut rows: Vec<_> = self.snapshot();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut s = String::new();
        for (name, secs, n) in rows {
            s.push_str(&format!(
                "  {name:<24} {secs:>9.4}s  {:>5.1}%  x{n}\n",
                100.0 * secs / total
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_positive_time() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn profiler_accumulates_counts_and_time() {
        let p = Profiler::new();
        p.time("a", || std::thread::sleep(Duration::from_millis(1)));
        p.time("a", || std::thread::sleep(Duration::from_millis(1)));
        p.time("b", || {});
        let snap = p.snapshot();
        let a = snap.iter().find(|(n, _, _)| *n == "a").unwrap();
        assert_eq!(a.2, 2);
        assert!(a.1 > 0.0);
        assert!(p.total_secs() >= a.1);
    }

    #[test]
    fn profiler_reset_clears() {
        let p = Profiler::new();
        p.time("a", || {});
        p.reset();
        assert!(p.snapshot().is_empty());
    }
}
