//! Process-memory introspection for the ingestion/perf accounting in
//! [`crate::coordinator::RunReport`].

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`). Returns 0 on platforms without procfs — callers
/// treat 0 as "unavailable".
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb = rest.trim().trim_end_matches("kB").trim();
            return kb.parse::<u64>().unwrap_or(0) * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_sane() {
        let rss = peak_rss_bytes();
        // On Linux this must be nonzero and at least a few hundred KiB;
        // elsewhere 0 is the documented "unavailable" value.
        if cfg!(target_os = "linux") {
            assert!(rss > 100 * 1024, "peak RSS {rss} implausibly small");
        }
    }
}
