//! Shared wire-format primitives: length-prefixed little-endian frames
//! and a bounds-checked payload cursor.
//!
//! Every ALX network protocol (the `alx serve` Top-K protocol, the
//! distributed-training data plane) speaks the same outer framing:
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]          len ≤ cap
//! ```
//!
//! The cap is the caller's: serving keeps its tight 1 MiB bound (a
//! hostile length prefix must not drive a large allocation on a public
//! port), while the distributed fabric uses a larger cap sized for
//! whole table shards. Both inherit the same EOF discipline — a clean
//! EOF at a frame boundary is `Ok(None)`, an EOF mid-frame is an error.

use std::io::{self, Read, Write};

/// Read one frame's payload, rejecting frames larger than `cap` bytes
/// before allocating. `Ok(None)` on a clean EOF at a frame boundary
/// (peer closed); an EOF mid-frame is an error.
pub fn read_frame_capped(r: &mut impl Read, cap: u32) -> io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        let n = r.read(&mut len4[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "EOF inside frame length"));
        }
        filled += n;
    }
    let len = u32::from_le_bytes(len4);
    if len > cap {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {cap}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Write one frame. Frames above `cap` are a caller bug, not a runtime
/// condition: the matching reader would reject them anyway.
pub fn write_frame_capped(w: &mut impl Write, payload: &[u8], cap: u32) -> io::Result<()> {
    assert!(payload.len() as u64 <= cap as u64, "oversized outbound frame");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Little-endian cursor over a frame payload. Every read is
/// bounds-checked; decode errors are `String`s describing the protocol
/// violation (answered with an error frame by servers, surfaced as
/// `InvalidData` by clients).
pub struct Cursor<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn done(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!("{} trailing bytes after payload", self.buf.len() - self.pos));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_per_cap() {
        let mut wire = Vec::new();
        write_frame_capped(&mut wire, b"abc", 8).unwrap();
        write_frame_capped(&mut wire, b"", 8).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame_capped(&mut r, 8).unwrap().unwrap(), b"abc");
        assert_eq!(read_frame_capped(&mut r, 8).unwrap().unwrap(), b"");
        assert!(read_frame_capped(&mut r, 8).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn cap_is_readers_own() {
        // A frame legal under a big cap is rejected by a small-cap reader.
        let mut wire = Vec::new();
        write_frame_capped(&mut wire, &[0u8; 100], 1 << 20).unwrap();
        assert!(read_frame_capped(&mut &wire[..], 16).is_err());
        assert_eq!(read_frame_capped(&mut &wire[..], 100).unwrap().unwrap().len(), 100);
    }

    #[test]
    fn eof_mid_frame_is_an_error() {
        let truncated = [5u8, 0, 0, 0, b'x'];
        assert!(read_frame_capped(&mut &truncated[..], 64).is_err());
        let half_len = [5u8, 0];
        assert!(read_frame_capped(&mut &half_len[..], 64).is_err());
    }

    #[test]
    fn cursor_reads_and_bounds() {
        let mut buf = Vec::new();
        buf.push(7u8);
        buf.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        buf.extend_from_slice(&42u64.to_le_bytes());
        let mut c = Cursor::new(&buf);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.remaining(), 8);
        assert_eq!(c.u64().unwrap(), 42);
        c.done().unwrap();
        assert!(c.u8().is_err(), "reads past the end are errors");
    }
}
