//! Read-only memory mapping for the `ALXBANK01` shard banks.
//!
//! The build environment is offline (no `memmap2`), so the unix mapping is
//! a minimal FFI binding to `mmap`/`munmap` — std already links libc, no
//! new dependency is introduced. Non-unix platforms fall back to reading
//! the file into an owned buffer, which keeps the API total at the cost of
//! residency (the fallback is a correctness path, not a scale path).

use std::fs::File;
use std::io::{Error, ErrorKind, Result};

/// An immutable byte view of a whole file. On unix this is a shared
/// read-only mapping: pages are faulted in on access and reclaimable by
/// the OS, so a mapped bank does not count against the process's working
/// set until (and only while) its pages are touched.
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut core::ffi::c_void,
    #[cfg(not(unix))]
    buf: Vec<u8>,
    len: usize,
}

// The mapping is read-only for its whole lifetime, so concurrent access
// from many threads is safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    use core::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

impl Mmap {
    /// Map `file` read-only in its entirety. Zero-length files map to an
    /// empty view (POSIX rejects `len == 0` mappings).
    #[cfg(unix)]
    pub fn map(file: &File) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| Error::new(ErrorKind::InvalidData, "file exceeds the address space"))?;
        if len == 0 {
            return Ok(Mmap { ptr: core::ptr::null_mut(), len: 0 });
        }
        // SAFETY: a fresh private read-only mapping of a file we hold open;
        // the pointer is owned by this Mmap and unmapped exactly once.
        let ptr = unsafe {
            sys::mmap(
                core::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(Error::last_os_error());
        }
        Ok(Mmap { ptr, len })
    }

    /// Portable fallback: read the whole file into memory.
    #[cfg(not(unix))]
    pub fn map(file: &File) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::new();
        let mut f = file;
        f.read_to_end(&mut buf)?;
        let len = buf.len();
        Ok(Mmap { buf, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cfg(unix)]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len come from a successful mmap that lives as long
        // as self; the mapping is never written.
        unsafe { core::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    #[cfg(not(unix))]
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: exact pointer/length pair returned by mmap.
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path =
            std::env::temp_dir().join(format!("alx_mmap_{}_{}.bin", tag, std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.flush().unwrap();
        path
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let path = tmp("contents", &data);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(&m[..], &data[..]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp("empty", &[]);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shared_across_threads() {
        let data = vec![7u8; 4096];
        let path = tmp("threads", &data);
        let m = std::sync::Arc::new(Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || m.iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
        let _ = std::fs::remove_file(&path);
    }
}
