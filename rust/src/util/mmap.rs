//! Memory mapping for the on-disk banks: read-only [`Mmap`] for the
//! `ALXBANK01` matrix banks, shared read-write [`MmapMut`] for the
//! `ALXTAB01` embedding-table banks (whose shards are written back in
//! place after every pass).
//!
//! The build environment is offline (no `memmap2`), so the unix mapping is
//! a minimal FFI binding to `mmap`/`munmap` — std already links libc, no
//! new dependency is introduced. Non-unix platforms fall back to reading
//! the file into an owned buffer, which keeps the API total at the cost of
//! residency (the fallback is a correctness path, not a scale path).

use std::fs::File;
use std::io::{Error, ErrorKind, Result};

/// An immutable byte view of a whole file. On unix this is a shared
/// read-only mapping: pages are faulted in on access and reclaimable by
/// the OS, so a mapped bank does not count against the process's working
/// set until (and only while) its pages are touched.
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut core::ffi::c_void,
    #[cfg(not(unix))]
    buf: Vec<u8>,
    len: usize,
}

// The mapping is read-only for its whole lifetime, so concurrent access
// from many threads is safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    use core::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

impl Mmap {
    /// Map `file` read-only in its entirety. Zero-length files map to an
    /// empty view (POSIX rejects `len == 0` mappings).
    #[cfg(unix)]
    pub fn map(file: &File) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| Error::new(ErrorKind::InvalidData, "file exceeds the address space"))?;
        if len == 0 {
            return Ok(Mmap { ptr: core::ptr::null_mut(), len: 0 });
        }
        // SAFETY: a fresh private read-only mapping of a file we hold open;
        // the pointer is owned by this Mmap and unmapped exactly once.
        let ptr = unsafe {
            sys::mmap(
                core::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(Error::last_os_error());
        }
        Ok(Mmap { ptr, len })
    }

    /// Portable fallback: read the whole file into memory.
    #[cfg(not(unix))]
    pub fn map(file: &File) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::new();
        let mut f = file;
        f.read_to_end(&mut buf)?;
        let len = buf.len();
        Ok(Mmap { buf, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cfg(unix)]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len come from a successful mmap that lives as long
        // as self; the mapping is never written.
        unsafe { core::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    #[cfg(not(unix))]
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: exact pointer/length pair returned by mmap.
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
    }
}

/// A shared read-write mapping of a whole file — the mutable counterpart
/// of [`Mmap`], used by the `ALXTAB01` table banks whose shard segments
/// are written back in place after each training pass.
///
/// On unix this is `MAP_SHARED` with `PROT_READ | PROT_WRITE`: writes
/// through [`MmapMut::bytes_mut`] are immediately visible to subsequent
/// reads of the same mapping (and of any later mapping of the file) and
/// reach the backing file without an explicit flush. The non-unix
/// fallback keeps an owned buffer and writes dirty ranges back through
/// the file handle via [`MmapMut::flush_range`].
pub struct MmapMut {
    #[cfg(unix)]
    ptr: *mut core::ffi::c_void,
    #[cfg(not(unix))]
    buf: Vec<u8>,
    #[cfg(not(unix))]
    file: File,
    len: usize,
}

// The mapping is only written through `&mut self`, so exclusive access is
// enforced by the borrow checker exactly as for an owned buffer.
unsafe impl Send for MmapMut {}
unsafe impl Sync for MmapMut {}

impl MmapMut {
    /// Map `file` read-write in its entirety (the file must be opened
    /// with both read and write access). Zero-length files map to an
    /// empty view.
    #[cfg(unix)]
    pub fn map_mut(file: &File) -> Result<MmapMut> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| Error::new(ErrorKind::InvalidData, "file exceeds the address space"))?;
        if len == 0 {
            return Ok(MmapMut { ptr: core::ptr::null_mut(), len: 0 });
        }
        // SAFETY: a fresh shared read-write mapping of a file we hold open;
        // the pointer is owned by this MmapMut and unmapped exactly once.
        let ptr = unsafe {
            sys::mmap(
                core::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(Error::last_os_error());
        }
        Ok(MmapMut { ptr, len })
    }

    /// Portable fallback: read the whole file into an owned buffer and
    /// keep the handle for [`MmapMut::flush_range`] write-backs.
    #[cfg(not(unix))]
    pub fn map_mut(file: &File) -> Result<MmapMut> {
        use std::io::Read;
        let mut buf = Vec::new();
        let mut f = file;
        f.read_to_end(&mut buf)?;
        let len = buf.len();
        Ok(MmapMut { buf, file: file.try_clone()?, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cfg(unix)]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len come from a successful mmap that lives as long
        // as self; writes require `&mut self`, so no alias can race this.
        unsafe { core::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    #[cfg(not(unix))]
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    #[cfg(unix)]
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        if self.len == 0 {
            return &mut [];
        }
        // SAFETY: exclusive borrow of a mapping writable by construction.
        unsafe { core::slice::from_raw_parts_mut(self.ptr as *mut u8, self.len) }
    }

    #[cfg(not(unix))]
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.buf
    }

    /// Persist `[off, off + len)` to the backing file. A no-op on unix
    /// (the shared mapping *is* the file); the owned-buffer fallback
    /// writes the range back through the file handle.
    #[cfg(unix)]
    pub fn flush_range(&mut self, _off: usize, _len: usize) -> Result<()> {
        Ok(())
    }

    #[cfg(not(unix))]
    pub fn flush_range(&mut self, off: usize, len: usize) -> Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        self.file.seek(SeekFrom::Start(off as u64))?;
        self.file.write_all(&self.buf[off..off + len])?;
        Ok(())
    }
}

impl std::fmt::Debug for MmapMut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapMut").field("len", &self.len).finish()
    }
}

#[cfg(unix)]
impl Drop for MmapMut {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: exact pointer/length pair returned by mmap.
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path =
            std::env::temp_dir().join(format!("alx_mmap_{}_{}.bin", tag, std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.flush().unwrap();
        path
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let path = tmp("contents", &data);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(&m[..], &data[..]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp("empty", &[]);
        let m = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mut_mapping_writes_reach_later_readers() {
        let path = tmp("rw", &[0u8; 256]);
        {
            let f = std::fs::OpenOptions::new().read(true).write(true).open(&path).unwrap();
            let mut m = MmapMut::map_mut(&f).unwrap();
            assert_eq!(m.len(), 256);
            m.bytes_mut()[10..14].copy_from_slice(&[1, 2, 3, 4]);
            m.flush_range(10, 4).unwrap();
            // The same mapping sees its own writes.
            assert_eq!(&m.bytes()[10..14], &[1, 2, 3, 4]);
        }
        // A fresh read-only mapping of the file sees them too.
        let m2 = Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap();
        assert_eq!(&m2[10..14], &[1, 2, 3, 4]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shared_across_threads() {
        let data = vec![7u8; 4096];
        let path = tmp("threads", &data);
        let m = std::sync::Arc::new(Mmap::map(&std::fs::File::open(&path).unwrap()).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || m.iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
        let _ = std::fs::remove_file(&path);
    }
}
