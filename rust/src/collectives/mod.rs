//! Collective operations over the sharded tables (paper §4.2).
//!
//! On a real pod these are XLA `all-gather` / `all-reduce` over the ICI
//! torus; here all shards share one address space so the collectives are
//! performed directly — but *the algorithm is executed exactly as the
//! paper describes it*, including the zero-out-of-invalid-rows trick, and
//! every collective is accounted in [`CommStats`] with the byte volume a
//! real pod would move. The `topo` cost model prices those bytes for the
//! Figure 6 scaling analysis.
//!
//! `sharded_gather` (Algorithm 2 line 9):
//! 1. all-gather the batch's item ids from every core,
//! 2. each core gathers whatever ids fall in its own shard, zeroing rows it
//!    does not own,
//! 3. all-reduce-sum the gathered tensors — since exactly one core owns
//!    each id, the sum reconstructs every embedding everywhere.
//!
//! `sharded_scatter` (line 19) is the mirror image for solved embeddings.

use crate::linalg::Mat;
use crate::sharding::ShardedTable;
use std::sync::atomic::{AtomicU64, Ordering};

/// Byte/op accounting for all collectives issued during a pass.
#[derive(Default, Debug)]
pub struct CommStats {
    pub all_gather_ops: AtomicU64,
    pub all_gather_bytes: AtomicU64,
    pub all_reduce_ops: AtomicU64,
    pub all_reduce_bytes: AtomicU64,
}

impl CommStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_all_gather(&self, bytes: u64) {
        self.all_gather_ops.fetch_add(1, Ordering::Relaxed);
        self.all_gather_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_all_reduce(&self, bytes: u64) {
        self.all_reduce_ops.fetch_add(1, Ordering::Relaxed);
        self.all_reduce_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes.load(Ordering::Relaxed) + self.all_reduce_bytes.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.all_gather_ops.store(0, Ordering::Relaxed);
        self.all_gather_bytes.store(0, Ordering::Relaxed);
        self.all_reduce_ops.store(0, Ordering::Relaxed);
        self.all_reduce_bytes.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.all_gather_ops.load(Ordering::Relaxed),
            self.all_gather_bytes.load(Ordering::Relaxed),
            self.all_reduce_ops.load(Ordering::Relaxed),
            self.all_reduce_bytes.load(Ordering::Relaxed),
        )
    }
}

/// Paper-faithful `sharded_gather`: reconstruct the embeddings of `ids`
/// from a sharded table via local-gather + zero + all-reduce-sum.
///
/// `ids` is the post-all-gather union of all cores' batches; the per-core
/// all-gather of the id lists is recorded too (4 bytes/id/core).
pub fn sharded_gather(table: &ShardedTable, ids: &[u32], stats: &CommStats) -> Mat {
    let m = table.num_shards();
    let d = table.dim;
    // Collective 1: all-gather of the id lists.
    stats.record_all_gather((ids.len() * 4) as u64 * m as u64);

    // Each shard produces its local contribution with invalid rows zeroed;
    // the all-reduce sums them. We fold the sum as we go (associative).
    let mut acc = Mat::zeros(ids.len(), d);
    let mut row = vec![0.0f32; d];
    for shard in 0..m {
        let range = table.range(shard);
        for (k, &id) in ids.iter().enumerate() {
            if range.contains(id as usize) {
                table.read_row(id as usize, &mut row);
                acc.row_mut(k).copy_from_slice(&row);
            }
            // else: that shard contributes zeros — nothing to add.
        }
    }
    // Collective 2: all-reduce-sum of the [ids × d] tensor.
    stats.record_all_reduce((ids.len() * d) as u64 * table.storage().elem_bytes());
    acc
}

/// Account the collective traffic of a `sharded_gather` without
/// materializing the gathered matrix — used by the fused
/// gather-into-accumulation path, which reads rows straight out of the
/// table. Byte-for-byte the same accounting as [`sharded_gather`].
pub fn record_gather_traffic(table: &ShardedTable, num_ids: usize, stats: &CommStats) {
    let m = table.num_shards() as u64;
    stats.record_all_gather((num_ids * 4) as u64 * m);
    stats.record_all_reduce((num_ids * table.dim) as u64 * table.storage().elem_bytes());
}

/// Account the collective traffic of a `sharded_scatter` performed through
/// a shard-local view (`ShardViewMut::scatter`). Byte-for-byte the same
/// accounting as [`sharded_scatter`].
pub fn record_scatter_traffic(
    num_ids: usize,
    dim: usize,
    elem_bytes: u64,
    num_shards: usize,
    stats: &CommStats,
) {
    stats.record_all_gather((num_ids * dim) as u64 * elem_bytes * num_shards as u64);
}

/// Paper-faithful `sharded_scatter`: write solved rows back into the
/// sharded table. All cores all-gather the solved embeddings, then each
/// core keeps only the rows inside its shard bounds.
pub fn sharded_scatter(table: &mut ShardedTable, ids: &[u32], rows: &Mat, stats: &CommStats) {
    assert_eq!(ids.len(), rows.rows);
    let m = table.num_shards() as u64;
    stats.record_all_gather(
        (ids.len() * table.dim) as u64 * table.storage().elem_bytes() * m,
    );
    // Each shard takes the rows it owns (emulated by a single pass since
    // ownership is disjoint).
    table.scatter(ids, rows);
}

/// All-reduce-sum of per-shard gramians (Algorithm 2 line 6).
pub fn all_reduce_gramian(locals: &[Mat], stats: &CommStats) -> Mat {
    reduce_gramians(locals, Some(stats))
}

/// The single gramian-reduction path: fixed-shard-order sum, with the
/// all-reduce priced when `stats` is given (the training pass) and
/// comm-free when it is not (the objective — a real pod computes it from
/// partials that ride the epoch's existing all-reduce). One entry point
/// for both keeps the reduction grouping — part of the bitwise-
/// determinism contract — impossible to change on one path only.
pub fn reduce_gramians(locals: &[Mat], stats: Option<&CommStats>) -> Mat {
    let g = sum_gramians(locals);
    if let Some(stats) = stats {
        stats.record_all_reduce((g.rows * g.cols * 4) as u64);
    }
    g
}

/// Fixed-shard-order sum of per-shard gramians — the reduction grouping
/// both the training path ([`all_reduce_gramian`]) and the comm-free
/// objective path share. The grouping is part of the bitwise-determinism
/// contract: change it in one place or not at all.
pub fn sum_gramians(locals: &[Mat]) -> Mat {
    assert!(!locals.is_empty());
    let d = locals[0].rows;
    let mut g = Mat::zeros(d, d);
    for l in locals {
        assert_eq!((l.rows, l.cols), (d, d));
        for (a, b) in g.data.iter_mut().zip(&l.data) {
            *a += b;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::Storage;
    use crate::util::Pcg64;

    #[test]
    fn sharded_gather_equals_direct_gather() {
        let mut rng = Pcg64::new(11);
        let t = ShardedTable::randn(64, 8, 5, Storage::F32, &mut rng);
        let ids = [0u32, 13, 63, 31, 13, 50];
        let stats = CommStats::new();
        let via_collective = sharded_gather(&t, &ids, &stats);
        let direct = t.gather(&ids);
        assert!(via_collective.max_abs_diff(&direct) < 1e-7);
    }

    #[test]
    fn sharded_gather_works_with_bf16_tables() {
        let mut rng = Pcg64::new(13);
        let t = ShardedTable::randn(32, 4, 3, Storage::Bf16, &mut rng);
        let ids = [1u32, 30, 16];
        let stats = CommStats::new();
        let got = sharded_gather(&t, &ids, &stats);
        assert!(got.max_abs_diff(&t.gather(&ids)) < 1e-7);
    }

    #[test]
    fn scatter_then_gather_roundtrips() {
        let mut rng = Pcg64::new(17);
        let mut t = ShardedTable::zeros(40, 6, 4, Storage::F32);
        let ids = [2u32, 39, 20];
        let rows = Mat::randn(3, 6, 1.0, &mut rng);
        let stats = CommStats::new();
        sharded_scatter(&mut t, &ids, &rows, &stats);
        let got = sharded_gather(&t, &ids, &stats);
        assert!(got.max_abs_diff(&rows) < 1e-7);
    }

    #[test]
    fn comm_bytes_accounted() {
        let mut rng = Pcg64::new(19);
        let t = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let ids: Vec<u32> = (0..10).collect();
        let stats = CommStats::new();
        sharded_gather(&t, &ids, &stats);
        let (ag_ops, ag_bytes, ar_ops, ar_bytes) = stats.snapshot();
        assert_eq!(ag_ops, 1);
        assert_eq!(ag_bytes, 10 * 4 * 4); // ids × 4B × 4 shards
        assert_eq!(ar_ops, 1);
        assert_eq!(ar_bytes, 10 * 8 * 2); // rows × dim × bf16
    }

    #[test]
    fn bf16_halves_all_reduce_traffic() {
        let mut rng = Pcg64::new(23);
        let tb = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let tf = ShardedTable::randn(64, 8, 4, Storage::F32, &mut rng);
        let ids: Vec<u32> = (0..16).collect();
        let sb = CommStats::new();
        let sf = CommStats::new();
        sharded_gather(&tb, &ids, &sb);
        sharded_gather(&tf, &ids, &sf);
        assert_eq!(
            sb.all_reduce_bytes.load(Ordering::Relaxed) * 2,
            sf.all_reduce_bytes.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn gramian_all_reduce_sums() {
        let a = Mat::from_rows(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let b = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let stats = CommStats::new();
        let g = all_reduce_gramian(&[a, b], &stats);
        assert_eq!(g.data, vec![3.0, 1.0, 1.0, 3.0]);
        assert_eq!(stats.all_reduce_ops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fused_traffic_accounting_matches_materialized() {
        let mut rng = Pcg64::new(29);
        let mut t = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let ids: Vec<u32> = (0..12).collect();
        let rows = Mat::randn(12, 8, 1.0, &mut rng);

        let a = CommStats::new();
        sharded_gather(&t, &ids, &a);
        sharded_scatter(&mut t, &ids, &rows, &a);

        let b = CommStats::new();
        record_gather_traffic(&t, ids.len(), &b);
        record_scatter_traffic(ids.len(), t.dim, t.storage().elem_bytes(), t.num_shards(), &b);

        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn reset_zeroes_counters() {
        let stats = CommStats::new();
        stats.record_all_gather(100);
        stats.record_all_reduce(50);
        assert_eq!(stats.total_bytes(), 150);
        stats.reset();
        assert_eq!(stats.total_bytes(), 0);
    }
}
