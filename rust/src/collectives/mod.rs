//! Collective operations over the sharded tables (paper §4.2).
//!
//! On a real pod these are XLA `all-gather` / `all-reduce` over the ICI
//! torus; here all shards share one address space so the collectives are
//! performed directly — but *the algorithm is executed exactly as the
//! paper describes it*, including the zero-out-of-invalid-rows trick, and
//! every collective is accounted in [`CommStats`] with the byte volume a
//! real pod would move. The `topo` cost model prices those bytes for the
//! Figure 6 scaling analysis.
//!
//! `sharded_gather` (Algorithm 2 line 9):
//! 1. all-gather the batch's item ids from every core,
//! 2. each core gathers whatever ids fall in its own shard, zeroing rows it
//!    does not own,
//! 3. all-reduce-sum the gathered tensors — since exactly one core owns
//!    each id, the sum reconstructs every embedding everywhere.
//!
//! `sharded_scatter` (line 19) is the mirror image for solved embeddings.

use crate::densebatch::DenseBatch;
use crate::linalg::{Mat, SolveOptions, SolverKind};
use crate::sharding::{ShardViewMut, ShardedTable};
use std::sync::atomic::{AtomicU64, Ordering};

/// Byte/op accounting for all collectives issued during a pass.
#[derive(Default, Debug)]
pub struct CommStats {
    pub all_gather_ops: AtomicU64,
    pub all_gather_bytes: AtomicU64,
    pub all_reduce_ops: AtomicU64,
    pub all_reduce_bytes: AtomicU64,
}

/// A consistent point-in-time copy of [`CommStats`] — per-collective op
/// and byte counters with names instead of tuple positions. This is the
/// conformance oracle of the transport abstraction: a run over the `Tcp`
/// backend must report a snapshot equal to the `Local` backend's, field
/// for field.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommSnapshot {
    pub all_gather_ops: u64,
    pub all_gather_bytes: u64,
    pub all_reduce_ops: u64,
    pub all_reduce_bytes: u64,
}

impl CommSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes + self.all_reduce_bytes
    }

    /// Counter-wise difference against an earlier snapshot.
    pub fn since(&self, earlier: &CommSnapshot) -> CommSnapshot {
        CommSnapshot {
            all_gather_ops: self.all_gather_ops - earlier.all_gather_ops,
            all_gather_bytes: self.all_gather_bytes - earlier.all_gather_bytes,
            all_reduce_ops: self.all_reduce_ops - earlier.all_reduce_ops,
            all_reduce_bytes: self.all_reduce_bytes - earlier.all_reduce_bytes,
        }
    }
}

impl CommStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_all_gather(&self, bytes: u64) {
        self.all_gather_ops.fetch_add(1, Ordering::Relaxed);
        self.all_gather_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_all_reduce(&self, bytes: u64) {
        self.all_reduce_ops.fetch_add(1, Ordering::Relaxed);
        self.all_reduce_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes.load(Ordering::Relaxed) + self.all_reduce_bytes.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.all_gather_ops.store(0, Ordering::Relaxed);
        self.all_gather_bytes.store(0, Ordering::Relaxed);
        self.all_reduce_ops.store(0, Ordering::Relaxed);
        self.all_reduce_bytes.store(0, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            all_gather_ops: self.all_gather_ops.load(Ordering::Relaxed),
            all_gather_bytes: self.all_gather_bytes.load(Ordering::Relaxed),
            all_reduce_ops: self.all_reduce_ops.load(Ordering::Relaxed),
            all_reduce_bytes: self.all_reduce_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Transport-measured wire traffic — actual frame bytes moved over real
/// sockets, as opposed to [`CommStats`], which prices the paper's ideal
/// collectives identically for every backend. The two must never be
/// conflated: `CommStats` is the bitwise conformance oracle (a tcp run
/// reports exactly the local numbers), while `WireSnapshot` is where real
/// optimizations like gather-request dedup show up.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireSnapshot {
    /// Frame bytes written to / read from sockets (coordinator↔worker
    /// plus, in worker-compute mode, the worker↔worker peer mesh as
    /// reported in SOLVE_BATCH replies).
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    /// Fixed-side gather ids requested before / after per-request dedup.
    pub gather_ids_pre_dedup: u64,
    pub gather_ids_sent: u64,
}

impl WireSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent + self.bytes_recv
    }
}

/// Everything a remote solver needs to rebuild the coordinator's engine
/// exactly: both ends construct from the same five fields, so offloaded
/// solves are bitwise the coordinator's own.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SolveSpec {
    pub engine: crate::als::EngineKind,
    pub solver: SolverKind,
    pub block_dim: u32,
    pub cg_iters: u32,
    pub bf16_accumulate: bool,
}

impl SolveSpec {
    pub fn solve_options(&self) -> SolveOptions {
        SolveOptions { cg_iters: self.cg_iters as usize, bf16_accumulate: self.bf16_accumulate }
    }

    /// Construct the engine this spec describes. `workers` is the
    /// per-batch segment fan-out (1 = serial, the deterministic choice
    /// for remote solvers — engines are bitwise identical at any worker
    /// count, so this is a latency knob, not a results knob).
    pub fn build_engine(&self, workers: usize) -> Box<dyn crate::als::SolveEngine> {
        let opts = self.solve_options();
        match self.engine {
            crate::als::EngineKind::Qr => {
                Box::new(crate::als::NativeEngine::with_workers(self.solver, opts, workers))
            }
            crate::als::EngineKind::IalsPp => Box::new(crate::als::IalsPpEngine::with_workers(
                self.solver,
                opts,
                self.block_dim as usize,
                workers,
            )),
        }
    }
}

/// Which of the trainer's two embedding tables a collective targets. The
/// wire protocol and the shard-ownership maps key on this, so it is part
/// of the transport contract, not a trainer-internal detail.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableId {
    W,
    H,
}

impl TableId {
    pub fn index(self) -> u8 {
        match self {
            TableId::W => 0,
            TableId::H => 1,
        }
    }

    pub fn from_index(i: u8) -> Result<TableId, String> {
        match i {
            0 => Ok(TableId::W),
            1 => Ok(TableId::H),
            other => Err(format!("unknown table id {other}")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TableId::W => "W",
            TableId::H => "H",
        }
    }
}

/// The transport behind the collectives: where the authoritative table
/// bits live and how gathered rows / solved rows / gramian partials move.
///
/// Two backends implement this:
///
/// * [`LocalCollectives`] — the original single-process path. The
///   trainer's own `ShardedTable`s are authoritative; gathers use the
///   fused in-place read, scatters write through the shard views, and
///   every collective is *priced* in [`CommStats`] without moving bytes.
/// * `dist::TcpCollectives` — the real multi-process path. Worker
///   processes own the table shards; id lists go out, gathered rows and
///   gramian partials come back over length-prefixed frames, and the
///   trainer's local tables are just a staging copy refreshed by
///   [`Collectives::sync_table`].
///
/// Byte accounting is *not* part of this trait on purpose: the trainer
/// records the paper's collective volumes at the call sites, identically
/// for every backend, which is exactly what makes `CommStats` the
/// conformance oracle between the simulated and the real transport.
pub trait Collectives: Send + Sync {
    /// Backend name for reports ("local", "tcp").
    fn name(&self) -> &'static str;

    /// Materialize the rows of `ids` from the authoritative copy of the
    /// table. `Ok(None)` means the local `table` *is* authoritative and
    /// the caller should use its fused in-place gather (the Local
    /// answer); `Ok(Some(mat))` carries remotely gathered rows, bitwise
    /// identical to what the fused path would have read.
    fn gather_rows(
        &self,
        id: TableId,
        table: &ShardedTable,
        ids: &[u32],
    ) -> anyhow::Result<Option<Mat>>;

    /// Write solved rows for `ids` (all inside table shard `shard`) back
    /// to the authoritative copy. `view` is the local mutable view over
    /// exactly that shard: Local writes through it; a remote backend ships
    /// the rows to the owning worker instead and leaves the staging copy
    /// stale until the next [`Collectives::sync_table`].
    fn scatter_rows(
        &self,
        id: TableId,
        shard: usize,
        view: &mut ShardViewMut<'_>,
        ids: &[u32],
        rows: &Mat,
    ) -> anyhow::Result<()>;

    /// Per-shard gramian partials of the authoritative copy, in shard
    /// order (the fixed-order reduction over these is part of the
    /// bitwise-determinism contract — see [`sum_gramians`]).
    fn local_gramians(
        &self,
        id: TableId,
        table: &ShardedTable,
        workers: usize,
    ) -> anyhow::Result<Vec<Mat>>;

    /// Ship the local table bits to the authoritative owners (table
    /// init and checkpoint restore). No-op locally.
    fn push_table(&self, id: TableId, table: &ShardedTable) -> anyhow::Result<()>;

    /// Refresh the local staging copy from the authoritative owners
    /// (before the coordinator reads tables directly: objective, eval,
    /// checkpoints). No-op locally.
    fn sync_table(&self, id: TableId, table: &mut ShardedTable) -> anyhow::Result<()>;

    /// Broadcast the per-pass solve context (engine spec + reduced
    /// gramian + regularization) ahead of a shard pass, so a backend that
    /// solves remotely can rebuild the coordinator's engine exactly.
    /// No-op for backends that solve on the coordinator.
    fn begin_pass(
        &self,
        _target: TableId,
        _fixed: TableId,
        _gramian: &Mat,
        _lambda: f32,
        _alpha: f32,
        _spec: &SolveSpec,
    ) -> anyhow::Result<()> {
        Ok(())
    }

    /// Offer one dense batch (all target rows inside table shard `shard`)
    /// to the backend for remote solving. `Ok(true)` means the owner
    /// solved it and wrote the solutions into its authoritative shard —
    /// the caller skips its own solve *and* scatter; `Ok(false)` means
    /// the backend does not offload (the default) and the caller runs the
    /// local solve path.
    fn solve_batch_remote(
        &self,
        _target: TableId,
        _shard: usize,
        _batch: &DenseBatch,
    ) -> anyhow::Result<bool> {
        Ok(false)
    }

    /// Transport-measured wire traffic, if this backend moves real bytes
    /// (`None` for in-process backends). Distinct from [`CommStats`] by
    /// design — see [`WireSnapshot`].
    fn wire_snapshot(&self) -> Option<WireSnapshot> {
        None
    }

    /// Fail fast if the heartbeat monitor has declared a peer dead.
    fn check_health(&self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Politely stop remote workers (no-op locally). Drivers that own the
    /// fleet's lifecycle (`alx launch`) call this once training is done.
    fn shutdown(&self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The in-process backend: local tables are authoritative, no bytes move.
/// This is bit-for-bit the pre-trait behavior of the trainer.
#[derive(Default)]
pub struct LocalCollectives;

impl Collectives for LocalCollectives {
    fn name(&self) -> &'static str {
        "local"
    }

    fn gather_rows(
        &self,
        _id: TableId,
        _table: &ShardedTable,
        _ids: &[u32],
    ) -> anyhow::Result<Option<Mat>> {
        Ok(None) // local tables are authoritative: use the fused path
    }

    fn scatter_rows(
        &self,
        _id: TableId,
        _shard: usize,
        view: &mut ShardViewMut<'_>,
        ids: &[u32],
        rows: &Mat,
    ) -> anyhow::Result<()> {
        view.scatter(ids, rows);
        Ok(())
    }

    fn local_gramians(
        &self,
        _id: TableId,
        table: &ShardedTable,
        workers: usize,
    ) -> anyhow::Result<Vec<Mat>> {
        Ok(crate::util::threads::parallel_map_indexed_with(workers, table.num_shards(), |s| {
            table.local_gramian(s)
        }))
    }

    fn push_table(&self, _id: TableId, _table: &ShardedTable) -> anyhow::Result<()> {
        Ok(())
    }

    fn sync_table(&self, _id: TableId, _table: &mut ShardedTable) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Paper-faithful `sharded_gather`: reconstruct the embeddings of `ids`
/// from a sharded table via local-gather + zero + all-reduce-sum.
///
/// `ids` is the post-all-gather union of all cores' batches; the per-core
/// all-gather of the id lists is recorded too (4 bytes/id/core).
pub fn sharded_gather(table: &ShardedTable, ids: &[u32], stats: &CommStats) -> Mat {
    let m = table.num_shards();
    let d = table.dim;
    // Collective 1: all-gather of the id lists.
    stats.record_all_gather((ids.len() * 4) as u64 * m as u64);

    // Each shard produces its local contribution with invalid rows zeroed;
    // the all-reduce sums them. We fold the sum as we go (associative).
    let mut acc = Mat::zeros(ids.len(), d);
    let mut row = vec![0.0f32; d];
    for shard in 0..m {
        let range = table.range(shard);
        for (k, &id) in ids.iter().enumerate() {
            if range.contains(id as usize) {
                table.read_row(id as usize, &mut row);
                acc.row_mut(k).copy_from_slice(&row);
            }
            // else: that shard contributes zeros — nothing to add.
        }
    }
    // Collective 2: all-reduce-sum of the [ids × d] tensor.
    stats.record_all_reduce((ids.len() * d) as u64 * table.storage().elem_bytes());
    acc
}

/// Account the collective traffic of a `sharded_gather` without
/// materializing the gathered matrix — used by the fused
/// gather-into-accumulation path, which reads rows straight out of the
/// table. Byte-for-byte the same accounting as [`sharded_gather`].
pub fn record_gather_traffic(table: &ShardedTable, num_ids: usize, stats: &CommStats) {
    let m = table.num_shards() as u64;
    stats.record_all_gather((num_ids * 4) as u64 * m);
    stats.record_all_reduce((num_ids * table.dim) as u64 * table.storage().elem_bytes());
}

/// Account the collective traffic of a `sharded_scatter` performed through
/// a shard-local view (`ShardViewMut::scatter`). Byte-for-byte the same
/// accounting as [`sharded_scatter`].
pub fn record_scatter_traffic(
    num_ids: usize,
    dim: usize,
    elem_bytes: u64,
    num_shards: usize,
    stats: &CommStats,
) {
    stats.record_all_gather((num_ids * dim) as u64 * elem_bytes * num_shards as u64);
}

/// Paper-faithful `sharded_scatter`: write solved rows back into the
/// sharded table. All cores all-gather the solved embeddings, then each
/// core keeps only the rows inside its shard bounds.
pub fn sharded_scatter(table: &mut ShardedTable, ids: &[u32], rows: &Mat, stats: &CommStats) {
    assert_eq!(ids.len(), rows.rows);
    let m = table.num_shards() as u64;
    stats.record_all_gather(
        (ids.len() * table.dim) as u64 * table.storage().elem_bytes() * m,
    );
    // Each shard takes the rows it owns (emulated by a single pass since
    // ownership is disjoint).
    table.scatter(ids, rows);
}

/// All-reduce-sum of per-shard gramians (Algorithm 2 line 6).
pub fn all_reduce_gramian(locals: &[Mat], stats: &CommStats) -> Mat {
    reduce_gramians(locals, Some(stats))
}

/// The single gramian-reduction path: fixed-shard-order sum, with the
/// all-reduce priced when `stats` is given (the training pass) and
/// comm-free when it is not (the objective — a real pod computes it from
/// partials that ride the epoch's existing all-reduce). One entry point
/// for both keeps the reduction grouping — part of the bitwise-
/// determinism contract — impossible to change on one path only.
pub fn reduce_gramians(locals: &[Mat], stats: Option<&CommStats>) -> Mat {
    let g = sum_gramians(locals);
    if let Some(stats) = stats {
        stats.record_all_reduce((g.rows * g.cols * 4) as u64);
    }
    g
}

/// Fixed-shard-order sum of per-shard gramians — the reduction grouping
/// both the training path ([`all_reduce_gramian`]) and the comm-free
/// objective path share. The grouping is part of the bitwise-determinism
/// contract: change it in one place or not at all.
pub fn sum_gramians(locals: &[Mat]) -> Mat {
    assert!(!locals.is_empty());
    let d = locals[0].rows;
    let mut g = Mat::zeros(d, d);
    for l in locals {
        assert_eq!((l.rows, l.cols), (d, d));
        for (a, b) in g.data.iter_mut().zip(&l.data) {
            *a += b;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::Storage;
    use crate::util::Pcg64;

    #[test]
    fn sharded_gather_equals_direct_gather() {
        let mut rng = Pcg64::new(11);
        let t = ShardedTable::randn(64, 8, 5, Storage::F32, &mut rng);
        let ids = [0u32, 13, 63, 31, 13, 50];
        let stats = CommStats::new();
        let via_collective = sharded_gather(&t, &ids, &stats);
        let direct = t.gather(&ids);
        assert!(via_collective.max_abs_diff(&direct) < 1e-7);
    }

    #[test]
    fn sharded_gather_works_with_bf16_tables() {
        let mut rng = Pcg64::new(13);
        let t = ShardedTable::randn(32, 4, 3, Storage::Bf16, &mut rng);
        let ids = [1u32, 30, 16];
        let stats = CommStats::new();
        let got = sharded_gather(&t, &ids, &stats);
        assert!(got.max_abs_diff(&t.gather(&ids)) < 1e-7);
    }

    #[test]
    fn scatter_then_gather_roundtrips() {
        let mut rng = Pcg64::new(17);
        let mut t = ShardedTable::zeros(40, 6, 4, Storage::F32);
        let ids = [2u32, 39, 20];
        let rows = Mat::randn(3, 6, 1.0, &mut rng);
        let stats = CommStats::new();
        sharded_scatter(&mut t, &ids, &rows, &stats);
        let got = sharded_gather(&t, &ids, &stats);
        assert!(got.max_abs_diff(&rows) < 1e-7);
    }

    #[test]
    fn comm_bytes_accounted() {
        let mut rng = Pcg64::new(19);
        let t = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let ids: Vec<u32> = (0..10).collect();
        let stats = CommStats::new();
        sharded_gather(&t, &ids, &stats);
        let snap = stats.snapshot();
        assert_eq!(snap.all_gather_ops, 1);
        assert_eq!(snap.all_gather_bytes, 10 * 4 * 4); // ids × 4B × 4 shards
        assert_eq!(snap.all_reduce_ops, 1);
        assert_eq!(snap.all_reduce_bytes, 10 * 8 * 2); // rows × dim × bf16
        assert_eq!(snap.total_bytes(), stats.total_bytes());
        assert_eq!(snap.since(&CommSnapshot::default()), snap);
    }

    #[test]
    fn local_backend_matches_direct_operations() {
        let mut rng = Pcg64::new(31);
        let mut t = ShardedTable::randn(48, 6, 4, Storage::F32, &mut rng);
        let be = LocalCollectives;
        // Gathers defer to the fused local path.
        assert!(be.gather_rows(TableId::H, &t, &[1, 2, 3]).unwrap().is_none());
        // Gramian partials equal the direct per-shard computation.
        let direct: Vec<Mat> = (0..t.num_shards()).map(|s| t.local_gramian(s)).collect();
        let via = be.local_gramians(TableId::H, &t, 2).unwrap();
        assert_eq!(direct.len(), via.len());
        for (a, b) in direct.iter().zip(&via) {
            assert_eq!(a.data, b.data);
        }
        // Scatters write through the local view.
        let ids = [0u32, 5];
        let rows = Mat::randn(2, 6, 1.0, &mut rng);
        {
            let mut views = t.shard_views_mut();
            be.scatter_rows(TableId::W, 0, &mut views[0], &ids, &rows).unwrap();
        }
        assert_eq!(t.gather(&ids).data, rows.data);
        // Push/sync are no-ops for the authoritative local copy.
        let before = t.shard_f32(0);
        be.push_table(TableId::W, &t).unwrap();
        be.sync_table(TableId::W, &mut t).unwrap();
        assert_eq!(t.shard_f32(0), before);
        be.check_health().unwrap();
    }

    #[test]
    fn bf16_halves_all_reduce_traffic() {
        let mut rng = Pcg64::new(23);
        let tb = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let tf = ShardedTable::randn(64, 8, 4, Storage::F32, &mut rng);
        let ids: Vec<u32> = (0..16).collect();
        let sb = CommStats::new();
        let sf = CommStats::new();
        sharded_gather(&tb, &ids, &sb);
        sharded_gather(&tf, &ids, &sf);
        assert_eq!(
            sb.all_reduce_bytes.load(Ordering::Relaxed) * 2,
            sf.all_reduce_bytes.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn gramian_all_reduce_sums() {
        let a = Mat::from_rows(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let b = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let stats = CommStats::new();
        let g = all_reduce_gramian(&[a, b], &stats);
        assert_eq!(g.data, vec![3.0, 1.0, 1.0, 3.0]);
        assert_eq!(stats.all_reduce_ops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fused_traffic_accounting_matches_materialized() {
        let mut rng = Pcg64::new(29);
        let mut t = ShardedTable::randn(64, 8, 4, Storage::Bf16, &mut rng);
        let ids: Vec<u32> = (0..12).collect();
        let rows = Mat::randn(12, 8, 1.0, &mut rng);

        let a = CommStats::new();
        sharded_gather(&t, &ids, &a);
        sharded_scatter(&mut t, &ids, &rows, &a);

        let b = CommStats::new();
        record_gather_traffic(&t, ids.len(), &b);
        record_scatter_traffic(ids.len(), t.dim, t.storage().elem_bytes(), t.num_shards(), &b);

        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn reset_zeroes_counters() {
        let stats = CommStats::new();
        stats.record_all_gather(100);
        stats.record_all_reduce(50);
        assert_eq!(stats.total_bytes(), 150);
        stats.reset();
        assert_eq!(stats.total_bytes(), 0);
    }
}
