//! Structural verification of every ALX on-disk format — the library
//! behind `alx verify <path>`.
//!
//! [`verify_file`] sniffs the leading magic and runs the format's own
//! open-time validator over the whole file:
//!
//! * `ALXCSR01` — full streaming parse ([`Csr::read_from_limited`]);
//! * `ALXCSR02` — header + every chunk walked ([`ChunkedReader`]);
//! * `ALXBANK01` — full bank validation ([`CsrBank::open`]) plus a decode
//!   of every shard;
//! * `ALXTAB01` — full bank validation ([`TableBank::open`]);
//! * `ALXCKPT1`/`ALXCKPT2` — full checkpoint load
//!   ([`crate::als::checkpoint::load`]).
//!
//! A clean file yields a [`VerifyReport`] naming the format and its
//! shape; a corrupt or truncated file yields the validator's own error —
//! never a panic, never an unbounded allocation (each validator already
//! guarantees that under `tests/corrupt_inputs.rs`).

use crate::sharding::{TableBank, ALXTAB01_MAGIC};
use crate::sparse::{ChunkedReader, Csr, CsrBank, ALXBANK01_MAGIC, ALXCSR02_MAGIC};
use std::io::{Error, ErrorKind, Read, Result};
use std::path::Path;

/// What a verified file turned out to be.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// The detected format name (e.g. `"ALXBANK01"`).
    pub format: &'static str,
    /// Human-readable shape summary.
    pub summary: String,
}

fn bad(msg: String) -> Error {
    Error::new(ErrorKind::InvalidData, msg)
}

/// Sniff `path`'s magic and structurally validate the whole file.
pub fn verify_file(path: impl AsRef<Path>) -> Result<VerifyReport> {
    let path = path.as_ref();
    let mut head = [0u8; 16];
    {
        let mut f = std::fs::File::open(path)?;
        let mut filled = 0;
        while filled < head.len() {
            let n = f.read(&mut head[filled..])?;
            if n == 0 {
                break;
            }
            filled += n;
        }
        if filled < 8 {
            return Err(bad(format!(
                "{}: {filled} bytes — too short for any ALX format magic",
                path.display()
            )));
        }
    }
    if &head[..9] == ALXBANK01_MAGIC.as_slice() {
        return verify_bank(path);
    }
    if &head[..8] == ALXTAB01_MAGIC.as_slice() {
        return verify_tab(path);
    }
    if &head[..8] == ALXCSR02_MAGIC.as_slice() {
        return verify_csr02(path);
    }
    match &head[..8] {
        b"ALXCSR01" => verify_csr01(path),
        b"ALXCKPT1" | b"ALXCKPT2" => verify_ckpt(path),
        _ => Err(bad(format!(
            "{}: unrecognized magic {:?} — not an ALX artifact",
            path.display(),
            String::from_utf8_lossy(&head[..8])
        ))),
    }
}

fn verify_csr01(path: &Path) -> Result<VerifyReport> {
    let len = std::fs::metadata(path)?.len();
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let m = Csr::read_from_limited(&mut r, Some(len))?;
    // The parser stops at the declared payload; trailing bytes mean the
    // file is not the artifact its header claims.
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        return Err(bad("trailing garbage after the ALXCSR01 payload".to_string()));
    }
    Ok(VerifyReport {
        format: "ALXCSR01",
        summary: format!("{}x{}, {} entries", m.rows, m.cols, m.nnz()),
    })
}

fn verify_csr02(path: &Path) -> Result<VerifyReport> {
    let mut r = ChunkedReader::open(path, 0)?;
    let h = *r.header();
    let mut chunks = 0usize;
    while r.next_chunk()?.is_some() {
        chunks += 1;
    }
    Ok(VerifyReport {
        format: "ALXCSR02",
        summary: format!("{}x{}, {} entries, {chunks} chunks", h.rows, h.cols, h.nnz),
    })
}

fn verify_bank(path: &Path) -> Result<VerifyReport> {
    let bank = CsrBank::open(path)?;
    // Decoding is infallible after open's validation; walking every shard
    // still forces each mapped segment through the decoder.
    for p in 0..bank.num_shards() {
        let _ = bank.load_shard(p);
    }
    Ok(VerifyReport {
        format: "ALXBANK01",
        summary: format!(
            "{}x{}, {} entries, {} shards",
            bank.rows,
            bank.cols,
            bank.nnz(),
            bank.num_shards()
        ),
    })
}

fn verify_tab(path: &Path) -> Result<VerifyReport> {
    let bank = TableBank::open(path)?;
    for p in 0..bank.num_shards() {
        let _ = bank.load_shard(p);
    }
    Ok(VerifyReport {
        format: "ALXTAB01",
        summary: format!(
            "{} rows x dim {}, {} shards, {:?} storage",
            bank.rows,
            bank.dim,
            bank.num_shards(),
            bank.storage()
        ),
    })
}

fn verify_ckpt(path: &Path) -> Result<VerifyReport> {
    let len = std::fs::metadata(path)?.len();
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    // Length-bounded load: a lying header can never allocate past the
    // file's own size.
    let ck = crate::als::checkpoint::load_limited(&mut r, 1, Some(len))?;
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        return Err(bad("trailing garbage after the checkpoint payload".to_string()));
    }
    Ok(VerifyReport {
        format: "ALXCKPT2",
        summary: format!(
            "epoch {}, {} users x {} items, d={}, {} storage, {} objective entries, \
             {} recall entries",
            ck.meta.epoch,
            ck.meta.users,
            ck.meta.items,
            ck.meta.dim,
            if ck.meta.storage_bf16 { "bf16" } else { "f32" },
            ck.objective_log.len(),
            ck.recall_log.len()
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::{ShardedTable, Storage};
    use crate::sparse::write_chunked;
    use crate::util::Pcg64;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_verify_{}_{}", tag, std::process::id()))
    }

    fn sample() -> Csr {
        let mut rng = Pcg64::new(11);
        let mut t = Vec::new();
        for r in 0..40u32 {
            for _ in 0..4 {
                t.push((r, rng.range(0, 30) as u32, 1.0));
            }
        }
        Csr::from_coo(40, 30, &t)
    }

    #[test]
    fn verifies_each_format_and_rejects_corruption() {
        let m = sample();

        // CSR01
        let p = tmp("csr01");
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        std::fs::write(&p, &buf).unwrap();
        assert_eq!(verify_file(&p).unwrap().format, "ALXCSR01");
        std::fs::write(&p, &buf[..buf.len() - 3]).unwrap();
        assert!(verify_file(&p).is_err(), "truncated CSR01 accepted");
        let _ = std::fs::remove_file(&p);

        // CSR02
        let p = tmp("csr02");
        let mut buf = Vec::new();
        write_chunked(&m, &mut buf, 16).unwrap();
        std::fs::write(&p, &buf).unwrap();
        let rep = verify_file(&p).unwrap();
        assert_eq!(rep.format, "ALXCSR02");
        assert!(rep.summary.contains("chunks"), "{}", rep.summary);
        std::fs::write(&p, &buf[..buf.len() - 1]).unwrap();
        assert!(verify_file(&p).is_err(), "truncated CSR02 accepted");
        let _ = std::fs::remove_file(&p);

        // BANK01
        let p = tmp("bank");
        crate::sparse::ShardedCsr::from_csr(&m, 3).spill_to_bank(&p).unwrap();
        assert_eq!(verify_file(&p).unwrap().format, "ALXBANK01");
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 2]).unwrap();
        assert!(verify_file(&p).is_err(), "truncated BANK01 accepted");
        let _ = std::fs::remove_file(&p);

        // TAB01
        let p = tmp("tab");
        let mut rng = Pcg64::new(5);
        ShardedTable::randn(20, 4, 2, Storage::Bf16, &mut rng).spill_to_bank(&p).unwrap();
        assert_eq!(verify_file(&p).unwrap().format, "ALXTAB01");
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[20] ^= 0xff; // rows field
        std::fs::write(&p, &bytes).unwrap();
        assert!(verify_file(&p).is_err(), "corrupt TAB01 header accepted");
        let _ = std::fs::remove_file(&p);

        // Not an ALX file at all.
        let p = tmp("noise");
        std::fs::write(&p, b"definitely not an alx artifact").unwrap();
        let e = verify_file(&p).unwrap_err();
        assert!(e.to_string().contains("unrecognized magic"), "{e}");
        let _ = std::fs::remove_file(&p);

        // Too short to classify.
        let p = tmp("short");
        std::fs::write(&p, b"abc").unwrap();
        assert!(verify_file(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
