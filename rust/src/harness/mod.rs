//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation section (see DESIGN.md §6 for the index).
//!
//! Each `run_*` function produces the rows/series the paper reports; the
//! `benches/` binaries and the `alx` CLI are thin wrappers around these so
//! EXPERIMENTS.md can cite a single entry point per artifact.

use crate::als::{EngineKind, PrecisionPolicy, TrainConfig, Trainer};
use crate::config::AlxConfig;
use crate::coordinator::Coordinator;
use crate::eval::EvalConfig;
use crate::linalg::SolverKind;
use crate::sparse::split_strong_generalization;
use crate::topo::{epoch_time, Topology, Workload};
use crate::util::stats::human_count;
use crate::util::Timer;
use crate::webgraph::{generate, Variant, VariantSpec};

// ---------------------------------------------------------------- Table 1

/// One row of Table 1 (dataset statistics).
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: &'static str,
    pub tld: &'static str,
    pub min_links: usize,
    pub nodes: usize,
    pub edges: usize,
    pub locality: f64,
    /// Paper's full-scale numbers for side-by-side comparison.
    pub paper_nodes: u64,
    pub paper_edges: u64,
}

/// Generate all six WebGraph variants at `scale` and report their stats.
pub fn run_table1(scale: f64, seed: u64) -> Vec<Table1Row> {
    Variant::ALL
        .iter()
        .map(|&v| {
            let spec = VariantSpec::preset(v).scaled(scale);
            let g = generate(&spec, seed);
            Table1Row {
                name: v.name(),
                tld: v.locale(),
                min_links: v.min_links(),
                nodes: g.nodes(),
                edges: g.edges(),
                locality: g.locality(),
                paper_nodes: v.paper_nodes(),
                paper_edges: v.paper_edges(),
            }
        })
        .collect()
}

pub fn print_table1(rows: &[Table1Row], scale: f64) {
    println!("\nTable 1: WebGraph variants (synthetic, scale={scale})");
    println!(
        "{:<22} {:>4} {:>9} {:>10} {:>12} {:>8}   {:>10} {:>10}",
        "Dataset", "TLD", "MinLinks", "Nodes", "Edges", "Local%", "paper-N", "paper-E"
    );
    for r in rows {
        println!(
            "{:<22} {:>4} {:>9} {:>10} {:>12} {:>7.1}%   {:>10} {:>10}",
            r.name,
            if r.tld.is_empty() { "-" } else { r.tld },
            r.min_links,
            human_count(r.nodes as u64),
            human_count(r.edges as u64),
            100.0 * r.locality,
            human_count(r.paper_nodes),
            human_count(r.paper_edges),
        );
    }
}

// ---------------------------------------------------------------- Table 2

/// One row of Table 2 (best hyper-parameters + recall).
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub name: &'static str,
    pub lambda: f32,
    pub alpha: f32,
    pub recall_at_20: f64,
    pub recall_at_50: f64,
    pub paper_recall_at_20: f64,
    pub paper_recall_at_50: f64,
    pub approximate: bool,
}

/// Paper Table 2 reference numbers (Recall@20, Recall@50).
pub fn paper_table2(v: Variant) -> (f64, f64) {
    match v {
        Variant::Sparse => (0.365, 0.377),
        Variant::Dense => (0.652, 0.724),
        Variant::DeSparse => (0.901, 0.936),
        Variant::DeDense => (0.946, 0.964),
        Variant::InSparse => (0.909, 0.941),
        Variant::InDense => (0.965, 0.974),
    }
}

/// Train one variant with the given hyper-parameters and evaluate.
/// The two largest variants use approximate MIPS, like the paper ("*").
pub fn run_table2_row(
    v: Variant,
    scale: f64,
    train: &TrainConfig,
    cores: usize,
    seed: u64,
) -> anyhow::Result<Table2Row> {
    let approximate = matches!(v, Variant::Sparse | Variant::Dense);
    let cfg = AlxConfig {
        variant: v,
        scale,
        cores,
        data_seed: seed,
        train: TrainConfig { compute_objective: false, ..train.clone() },
        approximate_eval: approximate,
        ..AlxConfig::default()
    };
    let mut coord = Coordinator::prepare(cfg)?;
    coord.trainer.fit()?;
    let recalls = coord.evaluate_with(&EvalConfig {
        approximate,
        ..EvalConfig::default()
    });
    let get =
        |k: usize| recalls.iter().find(|r| r.k == k).map(|r| r.recall).unwrap_or(0.0);
    let (p20, p50) = paper_table2(v);
    Ok(Table2Row {
        name: v.name(),
        lambda: train.lambda,
        alpha: train.alpha,
        recall_at_20: get(20),
        recall_at_50: get(50),
        paper_recall_at_20: p20,
        paper_recall_at_50: p50,
        approximate,
    })
}

pub fn print_table2(rows: &[Table2Row]) {
    println!("\nTable 2: recall after training (synthetic substrate; paper values right)");
    println!(
        "{:<22} {:>8} {:>8} {:>9} {:>9}   {:>9} {:>9}",
        "Dataset", "lambda", "alpha", "R@20", "R@50", "paper@20", "paper@50"
    );
    for r in rows {
        let star = if r.approximate { "*" } else { " " };
        println!(
            "{:<22} {:>8.0e} {:>8.0e} {:>8.3}{star} {:>8.3}{star}   {:>9.3} {:>9.3}",
            r.name, r.lambda, r.alpha, r.recall_at_20, r.recall_at_50,
            r.paper_recall_at_20, r.paper_recall_at_50,
        );
    }
    println!("(* = approximate top-K, like the paper's two largest variants)");
}

// ---------------------------------------------------------------- Figure 4

/// Per-epoch eval series for one precision policy.
#[derive(Clone, Debug)]
pub struct Fig4Series {
    pub precision: PrecisionPolicy,
    pub lambda: f32,
    /// Recall@20 after each epoch.
    pub recall_by_epoch: Vec<f64>,
    /// Training objective after each epoch (NaN = collapsed).
    pub objective_by_epoch: Vec<f64>,
}

/// Reproduce Figure 4: train under each precision policy at a low λ and
/// record the eval metric per epoch. Naive bf16 collapses; mixed ≈ f32.
pub fn run_fig4(
    variant: Variant,
    scale: f64,
    epochs: usize,
    dim: usize,
    lambda: f32,
    cores: usize,
    seed: u64,
) -> anyhow::Result<Vec<Fig4Series>> {
    let spec = VariantSpec::preset(variant).scaled(scale);
    let graph = generate(&spec, seed);
    let split = split_strong_generalization(&graph.adjacency, 0.9, 0.25, seed ^ 0x9);
    let mut out = Vec::new();
    for precision in [PrecisionPolicy::F32, PrecisionPolicy::Mixed, PrecisionPolicy::NaiveBf16] {
        let cfg = TrainConfig {
            dim,
            epochs,
            lambda,
            alpha: 1e-3,
            precision,
            batch_rows: 64,
            batch_width: 8,
            compute_objective: true,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(&split.train, cfg, Topology::new(cores))?;
        let mut recall_by_epoch = Vec::with_capacity(epochs);
        let mut objective_by_epoch = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            let stats = trainer.run_epoch()?;
            objective_by_epoch.push(stats.objective.unwrap_or(f64::NAN));
            let recalls =
                crate::eval::evaluate(&trainer, &split.test, &EvalConfig::default());
            recall_by_epoch.push(recalls.iter().find(|r| r.k == 20).map(|r| r.recall).unwrap_or(0.0));
        }
        out.push(Fig4Series { precision, lambda, recall_by_epoch, objective_by_epoch });
    }
    Ok(out)
}

pub fn print_fig4(series: &[Fig4Series]) {
    println!("\nFigure 4: eval metric by epoch per precision policy (λ={:.0e})", series[0].lambda);
    print!("{:<12}", "epoch");
    for s in series {
        print!("{:>14}", s.precision.name());
    }
    println!();
    let epochs = series[0].recall_by_epoch.len();
    for e in 0..epochs {
        print!("{:<12}", e + 1);
        for s in series {
            print!("{:>14.4}", s.recall_by_epoch[e]);
        }
        println!();
    }
}

// ---------------------------------------------------------------- Figure 5

/// One measured (solver, d) point.
#[derive(Clone, Debug)]
pub struct Fig5Point {
    pub solver: SolverKind,
    pub dim: usize,
    pub epoch_seconds: f64,
}

/// Reproduce Figure 5: wall-clock time of one training epoch per solver as
/// d grows. `engine_builder` lets the caller swap native/XLA engines.
pub fn run_fig5(
    variant: Variant,
    scale: f64,
    dims: &[usize],
    cores: usize,
    seed: u64,
    mut engine_builder: Option<&mut dyn FnMut(SolverKind, usize) -> anyhow::Result<Box<dyn crate::als::SolveEngine>>>,
) -> anyhow::Result<Vec<Fig5Point>> {
    let spec = VariantSpec::preset(variant).scaled(scale);
    let graph = generate(&spec, seed);
    let mut out = Vec::new();
    for &dim in dims {
        for solver in SolverKind::ALL {
            let cfg = TrainConfig {
                dim,
                epochs: 1,
                solver,
                batch_rows: 64,
                batch_width: 8,
                compute_objective: false,
                precision: PrecisionPolicy::Mixed,
                ..TrainConfig::default()
            };
            let topo = Topology::new(cores);
            let mut trainer = match &mut engine_builder {
                Some(builder) => {
                    Trainer::with_engine(&graph.adjacency, cfg, topo, builder(solver, dim)?)?
                }
                None => Trainer::new(&graph.adjacency, cfg, topo)?,
            };
            let timer = Timer::start();
            trainer.run_epoch()?;
            out.push(Fig5Point { solver, dim, epoch_seconds: timer.elapsed_secs() });
        }
    }
    Ok(out)
}

pub fn print_fig5(points: &[Fig5Point]) {
    println!("\nFigure 5: training time per epoch (s) by solver and embedding dim");
    let dims: Vec<usize> = {
        let mut d: Vec<usize> = points.iter().map(|p| p.dim).collect();
        d.sort_unstable();
        d.dedup();
        d
    };
    print!("{:<12}", "solver");
    for d in &dims {
        print!("{:>10}", format!("d={d}"));
    }
    println!();
    for solver in SolverKind::ALL {
        print!("{:<12}", solver.name());
        for d in &dims {
            if let Some(p) = points.iter().find(|p| p.solver == solver && p.dim == *d) {
                print!("{:>10.3}", p.epoch_seconds);
            } else {
                print!("{:>10}", "-");
            }
        }
        println!();
    }
}

// --------------------------------------------------- Figure 5 solver race

/// One contestant of the solver race (`benches/fig5_solvers.rs`).
#[derive(Clone, Debug)]
pub struct SolverRacePoint {
    pub engine: EngineKind,
    /// Subspace size (`= dim` for the direct engine).
    pub block_dim: usize,
    /// Epochs this contestant actually trained.
    pub epochs_run: usize,
    /// Recall@20 after the last epoch.
    pub recall_at_20: f64,
    /// Cumulative solve-stage busy-time (ms, summed across threads).
    pub solve_ms: f64,
}

/// Race the direct engine against the iALS++ subspace engine on one
/// split: the direct engine trains for `epochs` epochs to set the
/// recall@20 bar, then iALS++ trains until it matches the bar (capped at
/// `2 × epochs`). Solve time is the profiler's "solve" bucket, so the
/// comparison excludes the gather/statistics/scatter work that is
/// identical between engines.
pub fn run_solver_race(
    variant: Variant,
    scale: f64,
    dim: usize,
    block_dim: usize,
    epochs: usize,
    cores: usize,
    seed: u64,
) -> anyhow::Result<Vec<SolverRacePoint>> {
    let spec = VariantSpec::preset(variant).scaled(scale);
    let graph = generate(&spec, seed);
    let split = split_strong_generalization(&graph.adjacency, 0.9, 0.25, seed ^ 0x9);
    let base = TrainConfig {
        dim,
        lambda: 1e-3,
        alpha: 1e-3,
        solver: SolverKind::Qr,
        precision: PrecisionPolicy::F32,
        batch_rows: 64,
        batch_width: 8,
        compute_objective: false,
        ..TrainConfig::default()
    };
    let recall20 = |trainer: &Trainer| {
        let recalls = crate::eval::evaluate(trainer, &split.test, &EvalConfig::default());
        recalls.iter().find(|r| r.k == 20).map(|r| r.recall).unwrap_or(0.0)
    };

    // Contestant 1: full-dimension direct solves set the bar.
    let cfg = TrainConfig { epochs, ..base.clone() };
    let mut qr = Trainer::new(&split.train, cfg, Topology::new(cores))?;
    let mut qr_solve_ms = 0.0;
    for _ in 0..epochs {
        qr_solve_ms += qr.run_epoch()?.solve_ms;
    }
    let target = recall20(&qr);

    // Contestant 2: iALS++ chases the same bar in subspace steps.
    let cap = 2 * epochs;
    let cfg = TrainConfig {
        epochs: cap,
        engine: EngineKind::IalsPp,
        block_dim,
        ..base.clone()
    };
    let mut pp = Trainer::new(&split.train, cfg, Topology::new(cores))?;
    let mut pp_solve_ms = 0.0;
    let mut pp_epochs = 0;
    let mut pp_recall = 0.0;
    while pp_epochs < cap {
        pp_solve_ms += pp.run_epoch()?.solve_ms;
        pp_epochs += 1;
        pp_recall = recall20(&pp);
        if pp_recall >= target {
            break;
        }
    }
    Ok(vec![
        SolverRacePoint {
            engine: EngineKind::Qr,
            block_dim: dim,
            epochs_run: epochs,
            recall_at_20: target,
            solve_ms: qr_solve_ms,
        },
        SolverRacePoint {
            engine: EngineKind::IalsPp,
            block_dim,
            epochs_run: pp_epochs,
            recall_at_20: pp_recall,
            solve_ms: pp_solve_ms,
        },
    ])
}

pub fn print_solver_race(points: &[SolverRacePoint]) {
    println!("\nFigure 5 (solver race): solve busy-time to reach the direct engine's recall");
    println!(
        "{:<10} {:>9} {:>7} {:>10} {:>11}",
        "engine", "block_dim", "epochs", "recall@20", "solve(ms)"
    );
    for p in points {
        println!(
            "{:<10} {:>9} {:>7} {:>10.4} {:>11.1}",
            p.engine.name(),
            p.block_dim,
            p.epochs_run,
            p.recall_at_20,
            p.solve_ms
        );
    }
}

// ---------------------------------------------------------------- Figure 6

/// One point of the scaling analysis.
#[derive(Clone, Debug)]
pub struct Fig6Point {
    pub variant: Variant,
    pub cores: usize,
    /// Below the HBM floor — training cannot start (plotted as gap).
    pub feasible: bool,
    pub epoch_seconds: f64,
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

/// Reproduce Figure 6 via the calibrated topology model: epoch time vs
/// core count for the four biggest variants at full paper scale.
pub fn run_fig6(variants: &[Variant], core_counts: &[usize], dim: usize) -> Vec<Fig6Point> {
    let mut out = Vec::new();
    for &v in variants {
        let nodes = v.paper_nodes();
        let edges = v.paper_edges();
        let w = Workload {
            nnz: edges,
            rows_plus_cols: 2 * nodes,
            dim,
            elem_bytes: 2,
            batch_rows: 65536,
            batch_width: 16,
        };
        let core = crate::topo::CoreSpec::default();
        let min_cores = Topology::min_cores_for(w.table_bytes(), &core);
        for &m in core_counts {
            let topo = Topology::new(m);
            let cost = epoch_time(&topo, &w);
            out.push(Fig6Point {
                variant: v,
                cores: m,
                feasible: m >= min_cores,
                epoch_seconds: cost.total(),
                compute_seconds: cost.compute_s,
                comm_seconds: cost.comm_bandwidth_s + cost.comm_latency_s,
            });
        }
    }
    out
}

pub fn print_fig6(points: &[Fig6Point]) {
    println!("\nFigure 6: simulated epoch time (s) vs TPU cores (d=128, paper-scale data)");
    let mut variants: Vec<Variant> = points.iter().map(|p| p.variant).collect();
    variants.dedup();
    let mut cores: Vec<usize> = points.iter().map(|p| p.cores).collect();
    cores.sort_unstable();
    cores.dedup();
    print!("{:<22}", "dataset \\ cores");
    for m in &cores {
        print!("{:>9}", m);
    }
    println!();
    for v in variants {
        print!("{:<22}", v.name());
        for m in &cores {
            match points.iter().find(|p| p.variant == v && p.cores == *m) {
                Some(p) if p.feasible => print!("{:>9.1}", p.epoch_seconds),
                Some(_) => print!("{:>9}", "OOM"),
                None => print!("{:>9}", "-"),
            }
        }
        println!();
    }
    println!("(OOM = below the 16 GiB/core HBM floor for the sharded tables)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_six_rows_with_paper_refs() {
        let rows = run_table1(0.0005, 3);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.nodes > 0);
            assert!(r.edges > 0);
            assert!(r.paper_nodes >= 500_000);
        }
        // Ordering matches the paper's table: full, de, in.
        assert_eq!(rows[0].name, "WebGraph-sparse");
        assert_eq!(rows[5].name, "WebGraph-in-dense");
    }

    #[test]
    fn fig6_shows_floor_and_speedup() {
        let pts = run_fig6(&[Variant::Sparse], &[8, 32, 64, 256], 128);
        let p8 = pts.iter().find(|p| p.cores == 8).unwrap();
        assert!(!p8.feasible, "WebGraph-sparse must not fit on 8 cores");
        let p32 = pts.iter().find(|p| p.cores == 32).unwrap();
        let p64 = pts.iter().find(|p| p.cores == 64).unwrap();
        assert!(p32.feasible);
        assert!(p64.epoch_seconds < p32.epoch_seconds);
    }

    #[test]
    fn fig6_sparse_epoch_near_paper_20min_at_256() {
        // Paper: "one epoch of WebGraph-sparse takes around 20 minutes with
        // 256 TPU cores". Accept a 2.5× band — it is a model, not a pod.
        let pts = run_fig6(&[Variant::Sparse], &[256], 128);
        let t = pts[0].epoch_seconds;
        assert!(t > 1200.0 / 2.5 && t < 1200.0 * 2.5, "epoch {t}s vs paper 1200s");
    }
}
