//! PJRT runtime — loads and executes the AOT-compiled L2/L1 artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time: it
//! lowers the JAX ALS step (with the Pallas statistics kernel inside) to
//! HLO **text** per static shape, and writes `artifacts/manifest.tsv`.
//! This module is the only bridge between the rust hot path and those
//! artifacts: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`. Python never runs at training time.
//!
//! Interchange is HLO text (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;
pub mod xla_engine;
mod xla_stub;

pub use manifest::{ArtifactEntry, Manifest};
pub use xla_engine::XlaEngine;

// Compile against the pure-rust stub by default; swap for `use ::xla;`
// when linking the real PJRT bindings (see xla_stub.rs).
use xla_stub as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus a lazily compiled executable cache, keyed by
/// artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.tsv`).
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.tsv"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    /// The manifest of available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string of the PJRT backend.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.dir.join(&entry.file);
            let timer = crate::util::Timer::start();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("load {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            crate::log_debug!("compiled artifact '{name}' in {:.1}ms", timer.elapsed_ms());
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32/i32 literals and return the flattened
    /// outputs (the aot pipeline lowers with `return_tuple=True`).
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
        let mut lit = lit;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {name}: {e:?}"))?;
        Ok(parts)
    }

    /// Helper: literal from an f32 slice with the given dims.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
    }

    /// Helper: literal from an i32 slice with the given dims.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Runtime::literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(Runtime::literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/alx-artifacts").is_err());
    }
}
