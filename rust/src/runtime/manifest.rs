//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! Plain TSV (no JSON dependency): one artifact per line,
//!
//! ```text
//! name<TAB>file<TAB>key=value<TAB>key=value...
//! ```
//!
//! Keys describe the static shapes the artifact was compiled for
//! (`op`, `solver`, `d`, `b`, `l`, `n`, ...). The runtime selects
//! artifacts by these attributes, mirroring how XLA's static-shape
//! constraint forces one executable per shape (paper §4.3).

use std::collections::BTreeMap;
use std::path::Path;

/// One artifact line.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub attrs: BTreeMap<String, String>,
}

impl ArtifactEntry {
    /// Integer attribute accessor.
    pub fn attr_usize(&self, key: &str) -> Option<usize> {
        self.attrs.get(key)?.parse().ok()
    }

    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).map(|s| s.as_str())
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load and parse `manifest.tsv`.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {:?}: {e}. Run `make artifacts` first.",
                path.as_ref()
            )
        })?;
        Self::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let name = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing name", lineno + 1))?
                .to_string();
            let file = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing file", lineno + 1))?
                .to_string();
            let mut attrs = BTreeMap::new();
            for kv in parts {
                if let Some((k, v)) = kv.split_once('=') {
                    attrs.insert(k.to_string(), v.to_string());
                }
            }
            entries.push(ArtifactEntry { name, file, attrs });
        }
        Ok(Manifest { entries })
    }

    /// Look up by exact name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All entries matching `(key, value)` attribute pairs.
    pub fn find(&self, attrs: &[(&str, &str)]) -> Vec<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| attrs.iter().all(|(k, v)| e.attr(k) == Some(*v)))
            .collect()
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Canonical artifact name for an ALS solve step.
    pub fn solve_name(solver: &str, d: usize, b: usize, l: usize) -> String {
        format!("solve_{solver}_d{d}_b{b}_l{l}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
solve_cg_d16_b32_l8\tsolve_cg_d16_b32_l8.hlo.txt\top=solve\tsolver=cg\td=16\tb=32\tl=8
gramian_d16\tgramian_d16.hlo.txt\top=gramian\td=16\tn=1024
";

    #[test]
    fn parse_entries_and_attrs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.get("solve_cg_d16_b32_l8").unwrap();
        assert_eq!(e.file, "solve_cg_d16_b32_l8.hlo.txt");
        assert_eq!(e.attr("solver"), Some("cg"));
        assert_eq!(e.attr_usize("d"), Some(16));
        assert_eq!(e.attr_usize("missing"), None);
    }

    #[test]
    fn find_by_attrs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let hits = m.find(&[("op", "solve"), ("solver", "cg")]);
        assert_eq!(hits.len(), 1);
        assert!(m.find(&[("op", "nonexistent")]).is_empty());
    }

    #[test]
    fn solve_name_format() {
        assert_eq!(Manifest::solve_name("cg", 16, 32, 8), "solve_cg_d16_b32_l8");
    }

    #[test]
    fn blank_and_comment_lines_skipped() {
        let m = Manifest::parse("\n# x\n\n").unwrap();
        assert!(m.entries().is_empty());
    }

    #[test]
    fn malformed_line_missing_file() {
        // A name with no file column is an error.
        assert!(Manifest::parse("justaname").is_err());
    }
}
