//! The XLA solve engine — the production hot path.
//!
//! Executes the AOT-compiled L2 graph (`python/compile/model.py::solve_step`,
//! with the L1 Pallas statistics kernel lowered inside it) for every dense
//! batch. Shapes are static per artifact: the engine is bound to one
//! `(solver, d, B, L)` tuple at construction and validates every batch
//! against it — exactly the XLA constraint that motivates Dense Batching.
//!
//! Artifact signature (must match `aot.py`):
//!
//! ```text
//! inputs : h[B,L,D] f32, y[B,L] f32, mask[B,L] f32,
//!          onehot[B,S] f32 (S = B), gram[D,D] f32,
//!          lam f32 scalar, alpha f32 scalar
//! output : (w[S,D] f32,)
//! ```

use super::xla_stub as xla;
use super::{manifest::Manifest, Runtime};
use crate::als::SolveEngine;
use crate::densebatch::DenseBatch;
use crate::linalg::Mat;
use std::sync::Mutex;

/// PJRT-backed [`SolveEngine`] bound to one compiled shape.
///
/// The runtime sits behind a mutex: PJRT execution itself is thread-safe,
/// but the executable cache mutates on first use, and `SolveEngine` takes
/// `&self` so the trainer can drive shard passes from multiple threads.
pub struct XlaEngine {
    runtime: Mutex<Runtime>,
    artifact: String,
    pub d: usize,
    pub b: usize,
    pub l: usize,
}

impl XlaEngine {
    /// Open `artifacts_dir` and bind to the `(solver, d, b, l)` artifact.
    pub fn new(
        artifacts_dir: impl AsRef<std::path::Path>,
        solver: &str,
        d: usize,
        b: usize,
        l: usize,
    ) -> anyhow::Result<XlaEngine> {
        let mut runtime = Runtime::open(artifacts_dir)?;
        let artifact = Manifest::solve_name(solver, d, b, l);
        anyhow::ensure!(
            runtime.manifest().get(&artifact).is_some(),
            "artifact '{artifact}' not found — rebuild with `make artifacts` \
             (available: {:?})",
            runtime
                .manifest()
                .entries()
                .iter()
                .map(|e| e.name.as_str())
                .collect::<Vec<_>>()
        );
        // Compile eagerly so the first training batch is not penalized.
        runtime.executable(&artifact)?;
        Ok(XlaEngine { runtime: Mutex::new(runtime), artifact, d, b, l })
    }

    /// Access the underlying runtime (e.g. for gramian artifacts).
    pub fn runtime_mut(&mut self) -> &mut Runtime {
        self.runtime.get_mut().unwrap()
    }
}

impl SolveEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn solve_batch(
        &self,
        batch: &DenseBatch,
        h: &Mat,
        gramian: &Mat,
        lambda: f32,
        alpha: f32,
    ) -> anyhow::Result<Mat> {
        let (b, l, d) = (self.b, self.l, self.d);
        anyhow::ensure!(
            batch.rows == b && batch.width == l,
            "batch shape ({}, {}) does not match compiled artifact ({b}, {l})",
            batch.rows,
            batch.width
        );
        anyhow::ensure!(h.cols == d, "dim {} != compiled d {d}", h.cols);
        anyhow::ensure!(h.rows == b * l, "h rows {} != B*L {}", h.rows, b * l);
        let s = batch.num_segments();
        anyhow::ensure!(s <= b, "more segments than dense rows");

        // Segment one-hot (padded dense rows keep an all-zero row).
        let mut onehot = vec![0.0f32; b * b];
        for dr in 0..b {
            let valid = batch.mask[dr * l..(dr + 1) * l].iter().any(|&m| m != 0.0);
            if valid {
                let seg = batch.segments[dr] as usize;
                if seg < s {
                    onehot[dr * b + seg] = 1.0;
                }
            }
        }

        let inputs = [
            Runtime::literal_f32(&h.data, &[b as i64, l as i64, d as i64])?,
            Runtime::literal_f32(&batch.values, &[b as i64, l as i64])?,
            Runtime::literal_f32(&batch.mask, &[b as i64, l as i64])?,
            Runtime::literal_f32(&onehot, &[b as i64, b as i64])?,
            Runtime::literal_f32(&gramian.data, &[d as i64, d as i64])?,
            xla::Literal::scalar(lambda),
            xla::Literal::scalar(alpha),
        ];
        let outputs = self.runtime.lock().unwrap().execute(&self.artifact, &inputs)?;
        anyhow::ensure!(!outputs.is_empty(), "artifact returned no outputs");
        let w = outputs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output fetch: {e:?}"))?;
        anyhow::ensure!(w.len() == b * d, "output len {} != S*D {}", w.len(), b * d);
        // Keep only the live segments.
        Ok(Mat::from_rows(s, d, &w[..s * d]))
    }
}
