//! Pure-rust stand-in for the `xla` (xla-rs / PJRT) crate API surface the
//! runtime bridge uses, so the crate builds and tests with no native XLA
//! toolchain installed.
//!
//! [`Literal`] is fully functional (host-side tensor of f32/i32 with a
//! shape) — the literal helpers and their tests work unchanged. The client
//! / executable types are deliberately uninhabited: [`PjRtClient::cpu`]
//! returns an error, so every execution path fails fast with a clear
//! message instead of segfaulting into a missing library.
//!
//! To link the real PJRT runtime, add the `xla` crate to `Cargo.toml` and
//! swap the `use xla_stub as xla;` aliases in `runtime/{mod,xla_engine}.rs`
//! for `use ::xla;` — the call sites compile against either.

use std::convert::Infallible;
use std::path::Path;

/// Error type mirroring `xla::Error` (call sites only format it).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "PJRT runtime not linked in this build (pure-rust xla stub); \
         see rust/src/runtime/xla_stub.rs for how to enable it"
            .to_string(),
    )
}

/// Element payload of a [`Literal`].
#[derive(Clone, Debug)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side tensor literal (the only stub type that actually works).
#[derive(Clone, Debug)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Element types [`Literal::to_vec`] can extract.
pub trait NativeType: Sized {
    fn extract(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>, Error> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(Error("literal holds i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>, Error> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(Error("literal holds f32, asked for i32".into())),
        }
    }
}

/// Slice types [`Literal::vec1`] accepts.
pub trait FromSlice {
    fn payload(&self) -> Payload;
}

impl FromSlice for [f32] {
    fn payload(&self) -> Payload {
        Payload::F32(self.to_vec())
    }
}

impl FromSlice for [i32] {
    fn payload(&self) -> Payload {
        Payload::I32(self.to_vec())
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: FromSlice + ?Sized>(data: &T) -> Literal {
        let payload = data.payload();
        let len = match &payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        };
        Literal { payload, dims: vec![len as i64] }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { payload: Payload::F32(vec![x]), dims: Vec::new() }
    }

    /// Reshape, validating the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        let len = match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        };
        if n as usize != len {
            return Err(Error(format!("cannot reshape {len} elements to {dims:?}")));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Extract the flattened elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::extract(self)
    }

    /// Tuple decomposition — stub literals are never tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error(format!("literal of shape {:?} is not a tuple", self.dims)))
    }
}

/// Uninhabited: no PJRT client can exist in a stub build.
pub struct PjRtClient {
    never: Infallible,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match comp.never {}
    }
}

/// Uninhabited: produced only by [`PjRtClient::compile`].
pub struct PjRtLoadedExecutable {
    never: Infallible,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.never {}
    }
}

/// Uninhabited: produced only by [`PjRtLoadedExecutable::execute`].
pub struct PjRtBuffer {
    never: Infallible,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.never {}
    }
}

/// Uninhabited: loading HLO text requires the real parser.
pub struct HloModuleProto {
    never: Infallible,
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Uninhabited: wraps an [`HloModuleProto`].
pub struct XlaComputation {
    never: Infallible,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_and_scalar_shapes() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0][..]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert_eq!(Literal::scalar(7.0).to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn literal_i32_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4][..]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_unavailable_in_stub_build() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
