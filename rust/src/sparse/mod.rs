//! Sparse matrix substrate: CSR storage (monolithic and row-sharded, with
//! pluggable in-memory or mmap-backed shard banks), the chunked `ALXCSR02`
//! on-disk format with its bounded-memory cursor, the shard-major
//! `ALXBANK01` bank format behind spilled training, the transpose, and the
//! paper's strong-generalization train/test split (§5) in both in-memory
//! and streaming forms.

pub mod bank;
pub mod chunked;
pub mod csr;
pub mod shards;
pub mod split;
pub mod storage;

pub use bank::{BankWriter, CsrBank, ALXBANK01_MAGIC, DEFAULT_TRANSPOSE_SCRATCH_BYTES};
pub use chunked::{
    write_chunked, ChunkedHeader, ChunkedReader, ChunkedWriter, CsrChunk, ALXCSR02_MAGIC,
    DEFAULT_CHUNK_ROWS,
};
pub use csr::{Csr, RowMatrix};
pub use shards::{ShardedCsr, ShardedCsrBuilder};
pub use split::{
    split_strong_generalization, split_to_shards, RowDisposition, ShardedSplit, Split,
    SplitPlan, TestRow,
};
pub use storage::{CsrStorage, InMemory, MmapBank, PieceRows, ShardedMatrix, SpillStats};
