//! Sparse matrix substrate: CSR storage, transpose, and the paper's
//! strong-generalization train/test split (§5).

pub mod csr;
pub mod split;

pub use csr::Csr;
pub use split::{split_strong_generalization, Split, TestRow};
