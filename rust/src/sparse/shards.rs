//! Row-sharded CSR storage — the matrix-side twin of
//! [`crate::sharding::ShardedTable`].
//!
//! The trainer's shard pass μ only ever reads the training-matrix rows in
//! shard μ's row range (scatters are shard-local, paper Fig. 2), so the
//! matrix never needs to exist as one monolithic allocation: a
//! [`ShardedCsr`] stores one contiguous row-range piece per shard, and a
//! [`ShardedCsrBuilder`] assembles those pieces row by row — which is what
//! lets the streaming ingestion path (`ALXCSR02` chunks → split → shards)
//! run without ever materializing the full matrix.
//!
//! Row accessors take **global** row ids, so batching, the objective pass
//! and the feeder pipeline are oblivious to the layout.

use super::csr::{Csr, RowMatrix};

/// A CSR matrix stored as contiguous row-range pieces. Piece `p` holds
/// rows `[p·per, min((p+1)·per, rows))` with `per = ceil(rows / pieces)`
/// — the same uniform layout as [`crate::sharding::ShardedTable`].
#[derive(Clone, Debug, PartialEq)]
pub struct ShardedCsr {
    pub rows: usize,
    pub cols: usize,
    /// Rows per piece (the last piece may be short or empty).
    per: usize,
    pieces: Vec<Csr>,
    nnz: usize,
}

impl ShardedCsr {
    /// Rows-per-piece for a uniform partition (shared with the builder).
    fn per_for(rows: usize, num_pieces: usize) -> usize {
        rows.div_ceil(num_pieces.max(1)).max(1)
    }

    /// Copy a monolithic [`Csr`] into `num_pieces` row-range pieces.
    pub fn from_csr(m: &Csr, num_pieces: usize) -> ShardedCsr {
        let mut b = ShardedCsrBuilder::new(m.rows, m.cols, num_pieces);
        for r in 0..m.rows {
            b.push_row(m.row_indices(r), m.row_values(r));
        }
        b.finish()
    }

    pub fn num_pieces(&self) -> usize {
        self.pieces.len()
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Global row range `[start, end)` of piece `p`.
    pub fn piece_range(&self, p: usize) -> (usize, usize) {
        let start = (p * self.per).min(self.rows);
        let end = ((p + 1) * self.per).min(self.rows);
        (start, end)
    }

    /// The piece holding global row `r`, and `r`'s piece-local index.
    #[inline]
    fn locate(&self, r: usize) -> (usize, usize) {
        debug_assert!(r < self.rows);
        let p = (r / self.per).min(self.pieces.len() - 1);
        (p, r - p * self.per)
    }

    /// Column indices of global row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        let (p, local) = self.locate(r);
        self.pieces[p].row_indices(local)
    }

    /// Values of global row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        let (p, local) = self.locate(r);
        self.pieces[p].row_values(local)
    }

    /// Length of global row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        let (p, local) = self.locate(r);
        self.pieces[p].row_len(local)
    }

    /// Memory footprint of the stored arrays in bytes.
    pub fn memory_bytes(&self) -> u64 {
        self.pieces.iter().map(|p| p.memory_bytes()).sum()
    }

    /// Transpose into `num_pieces` column-range pieces via counting sort —
    /// O(nnz) time, and the only scratch beyond the output is the O(cols)
    /// per-column cursor table (never a full monolithic copy).
    pub fn transpose(&self, num_pieces: usize) -> ShardedCsr {
        assert!(self.rows <= u32::MAX as usize, "row ids must fit u32");
        let t_rows = self.cols;
        let per = Self::per_for(t_rows, num_pieces);

        // Count entries per transpose row (= per source column).
        let mut counts = vec![0usize; t_rows];
        for piece in &self.pieces {
            for &c in &piece.indices {
                counts[c as usize] += 1;
            }
        }

        // Allocate each piece exactly, with local indptr from the counts.
        let mut pieces: Vec<Csr> = Vec::with_capacity(num_pieces.max(1));
        for p in 0..num_pieces.max(1) {
            let start = (p * per).min(t_rows);
            let end = ((p + 1) * per).min(t_rows);
            let mut indptr = Vec::with_capacity(end - start + 1);
            indptr.push(0usize);
            let mut total = 0usize;
            for c in start..end {
                total += counts[c];
                indptr.push(total);
            }
            pieces.push(Csr {
                rows: end - start,
                cols: self.rows,
                indptr,
                indices: vec![0u32; total],
                values: vec![0.0f32; total],
            });
        }

        // Scatter pass in ascending source-row order, so each transpose
        // row ends up sorted by source row — same result as
        // [`Csr::transpose`].
        let mut cursor = counts; // reuse as per-column write cursors
        for c in cursor.iter_mut() {
            *c = 0;
        }
        for r in 0..self.rows {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for (&c, &v) in idx.iter().zip(val) {
                let c = c as usize;
                let p = (c / per).min(pieces.len() - 1);
                let local = c - p * per;
                let piece = &mut pieces[p];
                let off = piece.indptr[local] + cursor[c];
                piece.indices[off] = r as u32;
                piece.values[off] = v;
                cursor[c] += 1;
            }
        }

        ShardedCsr { rows: t_rows, cols: self.rows, per, pieces, nnz: self.nnz }
    }

    /// Concatenate the pieces back into one monolithic [`Csr`]
    /// (tests/debugging; defeats the purpose on large matrices).
    pub fn to_csr(&self) -> Csr {
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        for piece in &self.pieces {
            let base = indices.len();
            indptr.extend(piece.indptr[1..].iter().map(|&p| base + p));
            indices.extend_from_slice(&piece.indices);
            values.extend_from_slice(&piece.values);
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

impl RowMatrix for ShardedCsr {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        ShardedCsr::row_len(self, r)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        ShardedCsr::row_indices(self, r)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        ShardedCsr::row_values(self, r)
    }
}

/// Assembles a [`ShardedCsr`] from rows arriving in ascending order — the
/// sink of the streaming ingestion path. Memory grows only with the rows
/// pushed so far; there is no monolithic intermediate.
pub struct ShardedCsrBuilder {
    rows: usize,
    cols: usize,
    per: usize,
    num_pieces: usize,
    next_row: usize,
    nnz: usize,
    pieces: Vec<Csr>,
}

impl ShardedCsrBuilder {
    pub fn new(rows: usize, cols: usize, num_pieces: usize) -> ShardedCsrBuilder {
        assert!(rows <= u32::MAX as usize, "row ids must fit u32");
        let num_pieces = num_pieces.max(1);
        let per = ShardedCsr::per_for(rows, num_pieces);
        let pieces = (0..num_pieces)
            .map(|p| {
                let start = (p * per).min(rows);
                let end = ((p + 1) * per).min(rows);
                let mut indptr = Vec::with_capacity(end - start + 1);
                indptr.push(0usize);
                Csr { rows: end - start, cols, indptr, indices: Vec::new(), values: Vec::new() }
            })
            .collect();
        ShardedCsrBuilder { rows, cols, per, num_pieces, next_row: 0, nnz: 0, pieces }
    }

    /// Rows appended so far.
    pub fn rows_pushed(&self) -> usize {
        self.next_row
    }

    /// Append the next row (global id `rows_pushed()`); `indices` must be
    /// strictly ascending and `< cols` (the [`Csr`] invariant).
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) {
        assert!(self.next_row < self.rows, "pushed more than {} rows", self.rows);
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "row not sorted");
        debug_assert!(indices.iter().all(|&c| (c as usize) < self.cols), "index out of range");
        let p = (self.next_row / self.per).min(self.num_pieces - 1);
        let piece = &mut self.pieces[p];
        piece.indices.extend_from_slice(indices);
        piece.values.extend_from_slice(values);
        piece.indptr.push(piece.indices.len());
        self.next_row += 1;
        self.nnz += indices.len();
    }

    /// Append an empty row (held-out test rows stay in the id space).
    pub fn push_empty(&mut self) {
        self.push_row(&[], &[]);
    }

    pub fn finish(self) -> ShardedCsr {
        assert_eq!(self.next_row, self.rows, "builder got fewer rows than declared");
        ShardedCsr {
            rows: self.rows,
            cols: self.cols,
            per: self.per,
            pieces: self.pieces,
            nnz: self.nnz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn sample(rows: usize, cols: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = rng.range(0, 6);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r, c, (r as f32) + (c as f32) * 0.1));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    #[test]
    fn from_csr_preserves_every_row() {
        let m = sample(41, 17, 1);
        for pieces in [1usize, 2, 3, 8, 41, 64] {
            let s = ShardedCsr::from_csr(&m, pieces);
            assert_eq!(s.rows, m.rows);
            assert_eq!(s.nnz(), m.nnz());
            for r in 0..m.rows {
                assert_eq!(s.row_indices(r), m.row_indices(r), "pieces={pieces} row={r}");
                assert_eq!(s.row_values(r), m.row_values(r));
                assert_eq!(s.row_len(r), m.row_len(r));
            }
            assert_eq!(s.to_csr(), m);
        }
    }

    #[test]
    fn transpose_matches_monolithic_transpose() {
        let m = sample(29, 13, 2);
        let t_ref = m.transpose();
        for pieces in [1usize, 2, 5, 13, 29] {
            let s = ShardedCsr::from_csr(&m, pieces);
            let t = s.transpose(pieces);
            assert_eq!(t.rows, t_ref.rows);
            assert_eq!(t.cols, t_ref.cols);
            assert_eq!(t.to_csr(), t_ref, "pieces={pieces}");
        }
    }

    #[test]
    fn piece_ranges_partition_rows() {
        for (rows, pieces) in [(10usize, 3usize), (7, 7), (5, 8), (100, 1), (1, 4)] {
            let s = ShardedCsr::from_csr(&sample(rows, 6, 3), pieces);
            let mut prev = 0usize;
            let mut total = 0usize;
            for p in 0..s.num_pieces() {
                let (start, end) = s.piece_range(p);
                assert_eq!(start, prev.min(rows));
                assert!(end >= start);
                prev = end;
                total += end - start;
            }
            assert_eq!(total, rows, "rows={rows} pieces={pieces}");
        }
    }

    #[test]
    fn builder_matches_from_csr_and_tracks_empties() {
        let m = sample(23, 9, 4);
        let mut b = ShardedCsrBuilder::new(m.rows, m.cols, 4);
        for r in 0..m.rows {
            if m.row_len(r) == 0 {
                b.push_empty();
            } else {
                b.push_row(m.row_indices(r), m.row_values(r));
            }
        }
        let s = b.finish();
        assert_eq!(s.to_csr(), m);
        assert_eq!(s.memory_bytes(), ShardedCsr::from_csr(&m, 4).memory_bytes());
    }

    #[test]
    #[should_panic(expected = "fewer rows")]
    fn builder_rejects_short_input() {
        let b = ShardedCsrBuilder::new(5, 3, 2);
        b.finish();
    }

    #[test]
    fn empty_matrix_shards() {
        let m = Csr::from_coo(3, 3, &[]);
        let s = ShardedCsr::from_csr(&m, 2);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.transpose(2).nnz(), 0);
        assert_eq!(s.to_csr(), m);
    }
}
