//! Row-sharded CSR storage — the matrix-side twin of
//! [`crate::sharding::ShardedTable`].
//!
//! The trainer's shard pass μ only ever reads the training-matrix rows in
//! shard μ's row range (scatters are shard-local, paper Fig. 2), so the
//! matrix never needs to exist as one monolithic allocation: a
//! [`ShardedCsr`] stores one contiguous row-range piece per shard, and a
//! [`ShardedCsrBuilder`] assembles those pieces row by row — which is what
//! lets the streaming ingestion path (`ALXCSR02` chunks → split → shards)
//! run without ever materializing the full matrix.
//!
//! *Where* the pieces live is pluggable ([`super::CsrStorage`]): the
//! default [`InMemory`] backend keeps every piece resident (row accessors
//! take **global** row ids, so batching, the objective pass and the
//! feeder pipeline are oblivious to the layout), while the
//! [`super::MmapBank`] backend demand-pages pieces out of an on-disk
//! `ALXBANK01` bank so steady-state memory is bounded by the residency
//! cap instead of the matrix. The builder can spill completed pieces to a
//! bank as they fill ([`ShardedCsrBuilder::spill_to`]), which keeps even
//! *construction* memory at one piece.

use super::bank::{per_for, BankWriter, CsrBank};
use super::csr::{Csr, RowMatrix};
use super::storage::{CsrStorage, InMemory, MmapBank, ShardedMatrix, SpillStats};
use std::path::Path;
use std::sync::Arc;

/// A CSR matrix stored as contiguous row-range pieces. Piece `p` holds
/// rows `[p·per, min((p+1)·per, rows))` with `per = ceil(rows / pieces)`
/// — the same uniform layout as [`crate::sharding::ShardedTable`].
#[derive(Clone, Debug, PartialEq)]
pub struct ShardedCsr<S: CsrStorage = InMemory> {
    pub rows: usize,
    pub cols: usize,
    /// Rows per piece (the last piece may be short or empty).
    per: usize,
    nnz: usize,
    store: S,
}

impl<S: CsrStorage> ShardedCsr<S> {
    pub fn num_pieces(&self) -> usize {
        self.store.num_pieces()
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Global row range `[start, end)` of piece `p`.
    pub fn piece_range(&self, p: usize) -> (usize, usize) {
        let start = (p * self.per).min(self.rows);
        let end = ((p + 1) * self.per).min(self.rows);
        (start, end)
    }

    /// Materialized handle to piece `p` (a free clone on the in-memory
    /// backend; a residency-cache lookup or shard fault on a spilled one).
    pub fn piece(&self, p: usize) -> Arc<Csr> {
        self.store.piece(p)
    }

    /// Bytes currently resident in host memory (the whole matrix for
    /// [`InMemory`]; at most the residency cap for a spilled backend).
    pub fn memory_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }
}

impl<S: CsrStorage> ShardedMatrix for ShardedCsr<S> {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn num_pieces(&self) -> usize {
        self.store.num_pieces()
    }

    fn piece_range(&self, p: usize) -> (usize, usize) {
        let start = (p * self.per).min(self.rows);
        let end = ((p + 1) * self.per).min(self.rows);
        (start, end)
    }

    #[inline]
    fn piece_of(&self, r: usize) -> usize {
        debug_assert!(r < self.rows);
        (r / self.per).min(self.store.num_pieces() - 1)
    }

    fn piece(&self, p: usize) -> Arc<Csr> {
        self.store.piece(p)
    }

    fn prefetch(&self, p: usize) {
        self.store.prefetch(p);
    }

    fn spill_stats(&self) -> SpillStats {
        self.store.spill_stats()
    }

    fn resident_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }
}

impl ShardedCsr {
    /// Copy a monolithic [`Csr`] into `num_pieces` row-range pieces.
    pub fn from_csr(m: &Csr, num_pieces: usize) -> ShardedCsr {
        let mut b = ShardedCsrBuilder::new(m.rows, m.cols, num_pieces);
        for r in 0..m.rows {
            b.push_row(m.row_indices(r), m.row_values(r));
        }
        b.finish()
    }

    /// The piece holding global row `r`, and `r`'s piece-local index.
    #[inline]
    fn locate(&self, r: usize) -> (usize, usize) {
        debug_assert!(r < self.rows);
        let p = (r / self.per).min(self.store.pieces.len() - 1);
        (p, r - p * self.per)
    }

    /// Column indices of global row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        let (p, local) = self.locate(r);
        self.store.pieces[p].row_indices(local)
    }

    /// Values of global row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        let (p, local) = self.locate(r);
        self.store.pieces[p].row_values(local)
    }

    /// Length of global row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        let (p, local) = self.locate(r);
        self.store.pieces[p].row_len(local)
    }

    /// Transpose into `num_pieces` column-range pieces via counting sort —
    /// O(nnz) time, and the only scratch beyond the output is the O(cols)
    /// per-column cursor table (never a full monolithic copy).
    pub fn transpose(&self, num_pieces: usize) -> ShardedCsr {
        assert!(self.rows <= u32::MAX as usize, "row ids must fit u32");
        let t_rows = self.cols;
        let per = per_for(t_rows, num_pieces);

        // Count entries per transpose row (= per source column).
        let mut counts = vec![0usize; t_rows];
        for piece in &self.store.pieces {
            for &c in &piece.indices {
                counts[c as usize] += 1;
            }
        }

        // Allocate each piece exactly, with local indptr from the counts.
        let mut pieces: Vec<Csr> = Vec::with_capacity(num_pieces.max(1));
        for p in 0..num_pieces.max(1) {
            let start = (p * per).min(t_rows);
            let end = ((p + 1) * per).min(t_rows);
            let mut indptr = Vec::with_capacity(end - start + 1);
            indptr.push(0usize);
            let mut total = 0usize;
            for c in start..end {
                total += counts[c];
                indptr.push(total);
            }
            pieces.push(Csr {
                rows: end - start,
                cols: self.rows,
                indptr,
                indices: vec![0u32; total],
                values: vec![0.0f32; total],
            });
        }

        // Scatter pass in ascending source-row order, so each transpose
        // row ends up sorted by source row — same result as
        // [`Csr::transpose`].
        let mut cursor = counts; // reuse as per-column write cursors
        for c in cursor.iter_mut() {
            *c = 0;
        }
        for r in 0..self.rows {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for (&c, &v) in idx.iter().zip(val) {
                let c = c as usize;
                let p = (c / per).min(pieces.len() - 1);
                let local = c - p * per;
                let piece = &mut pieces[p];
                let off = piece.indptr[local] + cursor[c];
                piece.indices[off] = r as u32;
                piece.values[off] = v;
                cursor[c] += 1;
            }
        }

        ShardedCsr {
            rows: t_rows,
            cols: self.rows,
            per,
            nnz: self.nnz,
            store: InMemory::new(pieces),
        }
    }

    /// Concatenate the pieces back into one monolithic [`Csr`]
    /// (tests/debugging; defeats the purpose on large matrices).
    pub fn to_csr(&self) -> Csr {
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        for piece in &self.store.pieces {
            let base = indices.len();
            indptr.extend(piece.indptr[1..].iter().map(|&p| base + p));
            indices.extend_from_slice(&piece.indices);
            values.extend_from_slice(&piece.values);
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// Write every piece into an `ALXBANK01` bank at `path` (the resident
    /// counterpart of the builder's streaming
    /// [`ShardedCsrBuilder::spill_to`] — used to spill an already-built
    /// matrix before dropping it).
    pub fn spill_to_bank(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        // Staged + fsynced + renamed: a crash or full disk mid-spill never
        // leaves a half-written bank at the destination path.
        let path = path.as_ref();
        let artifact = format!("matrix bank {}", path.display());
        crate::util::durable::write_atomic(path, &artifact, |f| {
            let mut w = BankWriter::create(&mut *f, self.rows, self.cols, self.num_pieces())?;
            for piece in &self.store.pieces {
                w.write_shard(piece)?;
            }
            w.finish()?;
            Ok(())
        })
    }
}

impl ShardedCsr<MmapBank> {
    /// Open an `ALXBANK01` bank as a demand-paged sharded matrix with a
    /// residency cap of `resident_shards` decoded pieces. The file is
    /// fully validated before this returns.
    pub fn open_bank(
        path: impl AsRef<Path>,
        resident_shards: usize,
    ) -> std::io::Result<ShardedCsr<MmapBank>> {
        let bank = CsrBank::open(path)?;
        let nnz = usize::try_from(bank.nnz()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bank nnz exceeds usize")
        })?;
        Ok(ShardedCsr {
            rows: bank.rows,
            cols: bank.cols,
            per: bank.per(),
            nnz,
            store: MmapBank::new(bank, resident_shards),
        })
    }

    /// The demand-paged storage backend (residency/fault accounting).
    pub fn storage(&self) -> &MmapBank {
        &self.store
    }
}

impl RowMatrix for ShardedCsr {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        ShardedCsr::row_len(self, r)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        ShardedCsr::row_indices(self, r)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        ShardedCsr::row_values(self, r)
    }
}

/// Assembles a [`ShardedCsr`] from rows arriving in ascending order — the
/// sink of the streaming ingestion path. Memory grows only with the rows
/// pushed so far; there is no monolithic intermediate. With
/// [`ShardedCsrBuilder::spill_to`], completed pieces are flushed straight
/// into an on-disk bank and freed, so peak memory is **one piece** and the
/// full matrix never exists in RAM at all.
pub struct ShardedCsrBuilder {
    rows: usize,
    cols: usize,
    per: usize,
    num_pieces: usize,
    next_row: usize,
    nnz: usize,
    pieces: Vec<Csr>,
    spill: Option<BankWriter<std::io::BufWriter<std::fs::File>>>,
    spill_err: Option<std::io::Error>,
}

impl ShardedCsrBuilder {
    pub fn new(rows: usize, cols: usize, num_pieces: usize) -> ShardedCsrBuilder {
        assert!(rows <= u32::MAX as usize, "row ids must fit u32");
        let num_pieces = num_pieces.max(1);
        let per = per_for(rows, num_pieces);
        let pieces = (0..num_pieces)
            .map(|p| {
                let start = (p * per).min(rows);
                let end = ((p + 1) * per).min(rows);
                let mut indptr = Vec::with_capacity(end - start + 1);
                indptr.push(0usize);
                Csr { rows: end - start, cols, indptr, indices: Vec::new(), values: Vec::new() }
            })
            .collect();
        ShardedCsrBuilder {
            rows,
            cols,
            per,
            num_pieces,
            next_row: 0,
            nnz: 0,
            pieces,
            spill: None,
            spill_err: None,
        }
    }

    /// Redirect the builder into an on-disk `ALXBANK01` bank at `path`:
    /// from now on every piece is written out the moment its last row
    /// arrives and its memory is freed, so the builder never holds more
    /// than the piece currently filling. Must be called before the first
    /// row; finish with [`ShardedCsrBuilder::finish_spilled`].
    pub fn spill_to(&mut self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if self.next_row != 0 || self.spill.is_some() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "spill_to must be called on a fresh builder",
            ));
        }
        let path = path.as_ref();
        let f = crate::util::durable::retry("spill bank create", || std::fs::File::create(path))
            .map_err(|e| crate::util::durable::annotate(e, &format!("spill bank {}", path.display())))?;
        self.spill = Some(BankWriter::create(
            std::io::BufWriter::new(f),
            self.rows,
            self.cols,
            self.num_pieces,
        )?);
        Ok(())
    }

    /// Rows appended so far.
    pub fn rows_pushed(&self) -> usize {
        self.next_row
    }

    /// Append the next row (global id `rows_pushed()`); `indices` must be
    /// strictly ascending and `< cols` (the [`Csr`] invariant).
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) {
        assert!(self.next_row < self.rows, "pushed more than {} rows", self.rows);
        assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "row not sorted");
        debug_assert!(indices.iter().all(|&c| (c as usize) < self.cols), "index out of range");
        let p = (self.next_row / self.per).min(self.num_pieces - 1);
        let piece = &mut self.pieces[p];
        piece.indices.extend_from_slice(indices);
        piece.values.extend_from_slice(values);
        piece.indptr.push(piece.indices.len());
        self.next_row += 1;
        self.nnz += indices.len();
        // In spill mode, a piece is complete exactly when the cursor hits
        // its end row — flush it to the bank and free its arrays.
        if self.spill.is_some() {
            let end = ((p + 1) * self.per).min(self.rows);
            if self.next_row == end {
                self.flush_piece(p);
            }
        }
    }

    /// Append an empty row (held-out test rows stay in the id space).
    pub fn push_empty(&mut self) {
        self.push_row(&[], &[]);
    }

    /// Write piece `p` to the spill bank and free its memory. IO errors
    /// are remembered and surfaced by `finish_spilled` (the piece memory
    /// is freed either way, so a failing disk cannot also OOM the host).
    fn flush_piece(&mut self, p: usize) {
        let stub = Csr {
            rows: 0,
            cols: self.cols,
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
        };
        let piece = std::mem::replace(&mut self.pieces[p], stub);
        if self.spill_err.is_some() {
            return;
        }
        if let Some(w) = self.spill.as_mut() {
            if let Err(e) = w.write_shard(&piece) {
                self.spill_err = Some(e);
            }
        }
    }

    pub fn finish(self) -> ShardedCsr {
        assert!(
            self.spill.is_none() && self.spill_err.is_none(),
            "a spilling builder must use finish_spilled"
        );
        assert_eq!(self.next_row, self.rows, "builder got fewer rows than declared");
        ShardedCsr {
            rows: self.rows,
            cols: self.cols,
            per: self.per,
            nnz: self.nnz,
            store: InMemory::new(self.pieces),
        }
    }

    /// Flush the remaining (empty-tail) pieces, finalize the bank header,
    /// and return total stored entries. The bank is then ready for
    /// [`ShardedCsr::open_bank`].
    pub fn finish_spilled(mut self) -> std::io::Result<usize> {
        if self.spill.is_none() && self.spill_err.is_none() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "finish_spilled needs a prior spill_to",
            ));
        }
        if self.next_row != self.rows {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("builder got {} of the declared {} rows", self.next_row, self.rows),
            ));
        }
        // Pieces past the last data row (rows < pieces·per) never see a
        // cursor hit their end; flush them as the empty shards they are.
        let flushed = self.spill.as_ref().map(|w| w.shards_written()).unwrap_or(0);
        for p in flushed..self.num_pieces {
            self.flush_piece(p);
        }
        if let Some(e) = self.spill_err.take() {
            return Err(e);
        }
        let w = self.spill.take().expect("spill writer present");
        // fsync before the caller publishes (renames) the bank: rename
        // durability is only as good as the data it points at.
        w.finish()?.get_ref().sync_all()?;
        Ok(self.nnz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn sample(rows: usize, cols: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = rng.range(0, 6);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r, c, (r as f32) + (c as f32) * 0.1));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    #[test]
    fn from_csr_preserves_every_row() {
        let m = sample(41, 17, 1);
        for pieces in [1usize, 2, 3, 8, 41, 64] {
            let s = ShardedCsr::from_csr(&m, pieces);
            assert_eq!(s.rows, m.rows);
            assert_eq!(s.nnz(), m.nnz());
            for r in 0..m.rows {
                assert_eq!(s.row_indices(r), m.row_indices(r), "pieces={pieces} row={r}");
                assert_eq!(s.row_values(r), m.row_values(r));
                assert_eq!(s.row_len(r), m.row_len(r));
            }
            assert_eq!(s.to_csr(), m);
        }
    }

    #[test]
    fn transpose_matches_monolithic_transpose() {
        let m = sample(29, 13, 2);
        let t_ref = m.transpose();
        for pieces in [1usize, 2, 5, 13, 29] {
            let s = ShardedCsr::from_csr(&m, pieces);
            let t = s.transpose(pieces);
            assert_eq!(t.rows, t_ref.rows);
            assert_eq!(t.cols, t_ref.cols);
            assert_eq!(t.to_csr(), t_ref, "pieces={pieces}");
        }
    }

    #[test]
    fn piece_ranges_partition_rows() {
        for (rows, pieces) in [(10usize, 3usize), (7, 7), (5, 8), (100, 1), (1, 4)] {
            let s = ShardedCsr::from_csr(&sample(rows, 6, 3), pieces);
            let mut prev = 0usize;
            let mut total = 0usize;
            for p in 0..s.num_pieces() {
                let (start, end) = s.piece_range(p);
                assert_eq!(start, prev.min(rows));
                assert!(end >= start);
                prev = end;
                total += end - start;
            }
            assert_eq!(total, rows, "rows={rows} pieces={pieces}");
        }
    }

    #[test]
    fn builder_matches_from_csr_and_tracks_empties() {
        let m = sample(23, 9, 4);
        let mut b = ShardedCsrBuilder::new(m.rows, m.cols, 4);
        for r in 0..m.rows {
            if m.row_len(r) == 0 {
                b.push_empty();
            } else {
                b.push_row(m.row_indices(r), m.row_values(r));
            }
        }
        let s = b.finish();
        assert_eq!(s.to_csr(), m);
        assert_eq!(s.memory_bytes(), ShardedCsr::from_csr(&m, 4).memory_bytes());
    }

    #[test]
    #[should_panic(expected = "fewer rows")]
    fn builder_rejects_short_input() {
        let b = ShardedCsrBuilder::new(5, 3, 2);
        b.finish();
    }

    #[test]
    fn empty_matrix_shards() {
        let m = Csr::from_coo(3, 3, &[]);
        let s = ShardedCsr::from_csr(&m, 2);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.transpose(2).nnz(), 0);
        assert_eq!(s.to_csr(), m);
    }

    fn bank_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_shards_{}_{}.alxbank", tag, std::process::id()))
    }

    #[test]
    fn spilling_builder_produces_the_in_memory_bank() {
        let m = sample(37, 11, 7);
        for pieces in [1usize, 3, 5, 37, 50] {
            let path = bank_path(&format!("spillb{pieces}"));
            let mut b = ShardedCsrBuilder::new(m.rows, m.cols, pieces);
            b.spill_to(&path).unwrap();
            for r in 0..m.rows {
                b.push_row(m.row_indices(r), m.row_values(r));
            }
            assert_eq!(b.finish_spilled().unwrap(), m.nnz());
            let paged = ShardedCsr::open_bank(&path, 2).unwrap();
            let resident = ShardedCsr::from_csr(&m, pieces);
            assert_eq!(paged.rows, resident.rows);
            assert_eq!(paged.nnz(), resident.nnz());
            assert_eq!(paged.num_pieces(), resident.num_pieces());
            for p in 0..resident.num_pieces() {
                assert_eq!(paged.piece(p), resident.piece(p), "pieces={pieces} p={p}");
            }
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn spilling_builder_frees_completed_pieces() {
        let m = sample(64, 9, 8);
        let path = bank_path("free");
        let mut b = ShardedCsrBuilder::new(m.rows, m.cols, 8);
        b.spill_to(&path).unwrap();
        for r in 0..m.rows {
            b.push_row(m.row_indices(r), m.row_values(r));
            // Every piece except the one currently filling must be empty.
            let filling = (r / 8).min(7);
            for (p, piece) in b.pieces.iter().enumerate() {
                if p != filling {
                    assert!(
                        piece.indices.is_empty(),
                        "piece {p} still resident while filling {filling}"
                    );
                }
            }
        }
        b.finish_spilled().unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn spill_to_rejects_started_builders() {
        let mut b = ShardedCsrBuilder::new(4, 3, 2);
        b.push_row(&[1], &[1.0]);
        assert!(b.spill_to(bank_path("started")).is_err());
    }
}
