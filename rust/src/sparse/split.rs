//! Strong-generalization train/test split (paper §5).
//!
//! The linkage graph is split **by row** (source link): 90% of rows go to
//! the training set; for each of the remaining 10% test rows, 25% of the
//! outlinks are held out as ground truth and the rest form the "history"
//! used to fold the row into the embedding space via Eq. (4) at eval time.
//! Test rows therefore never contribute to training — the model must
//! generalize to unseen users (Marlin's "strong generalization" protocol).

use super::csr::Csr;
use crate::util::Pcg64;

/// One test row: its history (observed outlinks used for fold-in) and the
/// held-out ground-truth outlinks used to compute Recall@K.
#[derive(Clone, Debug)]
pub struct TestRow {
    pub row: u32,
    pub history: Vec<(u32, f32)>,
    pub holdout: Vec<u32>,
}

/// The result of the split.
#[derive(Clone, Debug)]
pub struct Split {
    /// Training matrix; test rows are present but empty so that row ids and
    /// shard layouts stay aligned with the full graph.
    pub train: Csr,
    pub test: Vec<TestRow>,
}

/// Perform the strong-generalization split.
///
/// * `train_frac` — fraction of rows kept fully in training (paper: 0.9).
/// * `holdout_frac` — fraction of a test row's outlinks held out (paper: 0.25).
pub fn split_strong_generalization(
    full: &Csr,
    train_frac: f64,
    holdout_frac: f64,
    seed: u64,
) -> Split {
    assert!((0.0..=1.0).contains(&train_frac));
    assert!((0.0..=1.0).contains(&holdout_frac));
    let mut rng = Pcg64::new(seed);
    let mut rows: Vec<u32> = (0..full.rows as u32).collect();
    rng.shuffle(&mut rows);
    let n_train = (full.rows as f64 * train_frac).round() as usize;
    let mut is_test = vec![false; full.rows];
    for &r in &rows[n_train..] {
        is_test[r as usize] = true;
    }

    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(full.nnz());
    let mut test = Vec::new();
    for r in 0..full.rows {
        let idx = full.row_indices(r);
        let val = full.row_values(r);
        if !is_test[r] {
            for (&c, &v) in idx.iter().zip(val) {
                triplets.push((r as u32, c, v));
            }
            continue;
        }
        if idx.is_empty() {
            continue;
        }
        // Hold out a random 25% (at least one if the row is non-trivial,
        // but always keep at least one history link for fold-in).
        let mut order: Vec<usize> = (0..idx.len()).collect();
        rng.shuffle(&mut order);
        let mut n_hold = (idx.len() as f64 * holdout_frac).round() as usize;
        n_hold = n_hold.clamp(usize::from(idx.len() >= 2), idx.len().saturating_sub(1));
        let mut history = Vec::with_capacity(idx.len() - n_hold);
        let mut holdout = Vec::with_capacity(n_hold);
        for (pos, &i) in order.iter().enumerate() {
            if pos < n_hold {
                holdout.push(idx[i]);
            } else {
                history.push((idx[i], val[i]));
            }
        }
        if holdout.is_empty() {
            continue; // single-link rows cannot be evaluated
        }
        holdout.sort_unstable();
        test.push(TestRow { row: r as u32, history, holdout });
    }

    Split { train: Csr::from_coo(full.rows, full.cols, &triplets), test }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_graph(rows: usize, cols: usize, links_per_row: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows {
            let mut seen = std::collections::HashSet::new();
            while seen.len() < links_per_row {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r as u32, c, 1.0));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    #[test]
    fn split_fractions_roughly_hold() {
        let g = dense_graph(200, 100, 8, 1);
        let s = split_strong_generalization(&g, 0.9, 0.25, 2);
        assert_eq!(s.test.len(), 20);
        // Train keeps all non-test links.
        assert_eq!(s.train.nnz(), 180 * 8);
    }

    #[test]
    fn test_rows_are_empty_in_train() {
        let g = dense_graph(50, 40, 5, 3);
        let s = split_strong_generalization(&g, 0.8, 0.25, 4);
        for tr in &s.test {
            assert_eq!(s.train.row_len(tr.row as usize), 0, "test row leaked into train");
        }
    }

    #[test]
    fn holdout_plus_history_partition_the_row() {
        let g = dense_graph(50, 40, 8, 5);
        let s = split_strong_generalization(&g, 0.8, 0.25, 6);
        for tr in &s.test {
            let mut all: Vec<u32> =
                tr.history.iter().map(|&(c, _)| c).chain(tr.holdout.iter().copied()).collect();
            all.sort_unstable();
            assert_eq!(all, g.row_indices(tr.row as usize));
            // ~25% of 8 links = 2 held out.
            assert_eq!(tr.holdout.len(), 2);
            assert_eq!(tr.history.len(), 6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = dense_graph(60, 30, 4, 7);
        let a = split_strong_generalization(&g, 0.9, 0.25, 8);
        let b = split_strong_generalization(&g, 0.9, 0.25, 8);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test.len(), b.test.len());
    }

    #[test]
    fn single_link_rows_are_skipped() {
        let g = Csr::from_coo(10, 10, &(0..10).map(|r| (r as u32, 0u32, 1.0)).collect::<Vec<_>>());
        let s = split_strong_generalization(&g, 0.0, 0.25, 9); // everything is a test row
        // Rows have 1 link: cannot hold out and keep history; all skipped.
        assert!(s.test.is_empty());
    }
}
