//! Strong-generalization train/test split (paper §5).
//!
//! The linkage graph is split **by row** (source link): 90% of rows go to
//! the training set; for each of the remaining 10% test rows, 25% of the
//! outlinks are held out as ground truth and the rest form the "history"
//! used to fold the row into the embedding space via Eq. (4) at eval time.
//! Test rows therefore never contribute to training — the model must
//! generalize to unseen users (Marlin's "strong generalization" protocol).

use super::csr::Csr;
use super::shards::{ShardedCsr, ShardedCsrBuilder};
use crate::util::Pcg64;

/// One test row: its history (observed outlinks used for fold-in) and the
/// held-out ground-truth outlinks used to compute Recall@K.
#[derive(Clone, Debug)]
pub struct TestRow {
    pub row: u32,
    pub history: Vec<(u32, f32)>,
    pub holdout: Vec<u32>,
}

/// The result of the split.
#[derive(Clone, Debug)]
pub struct Split {
    /// Training matrix; test rows are present but empty so that row ids and
    /// shard layouts stay aligned with the full graph.
    pub train: Csr,
    pub test: Vec<TestRow>,
}

/// What the split decides about one row.
#[derive(Clone, Debug)]
pub enum RowDisposition {
    /// Training row: keep every link in the training matrix.
    Train,
    /// Test row: empty in the training matrix, evaluated via its
    /// history/holdout partition.
    Test(TestRow),
    /// Unevaluable row (empty, or a single-link test row): empty in the
    /// training matrix and absent from the test set.
    Skip,
}

/// The streaming form of the strong-generalization split: all random
/// decisions are a function of the **row count and seed** alone plus each
/// row's links as it arrives, so the split can run over a chunked stream
/// without a full matrix in memory. Rows must be disposed in ascending
/// order, exactly once each; the RNG consumption pattern is identical to
/// the classic [`split_strong_generalization`], so both paths produce
/// bitwise-identical splits.
pub struct SplitPlan {
    is_test: Vec<bool>,
    rng: Pcg64,
    holdout_frac: f64,
    next_row: usize,
}

impl SplitPlan {
    /// * `train_frac` — fraction of rows kept fully in training (paper: 0.9).
    /// * `holdout_frac` — fraction of a test row's outlinks held out
    ///   (paper: 0.25).
    pub fn new(rows: usize, train_frac: f64, holdout_frac: f64, seed: u64) -> SplitPlan {
        assert!((0.0..=1.0).contains(&train_frac));
        assert!((0.0..=1.0).contains(&holdout_frac));
        let mut rng = Pcg64::new(seed);
        let mut row_ids: Vec<u32> = (0..rows as u32).collect();
        rng.shuffle(&mut row_ids);
        let n_train = (rows as f64 * train_frac).round() as usize;
        let mut is_test = vec![false; rows];
        for &r in &row_ids[n_train..] {
            is_test[r as usize] = true;
        }
        SplitPlan { is_test, rng, holdout_frac, next_row: 0 }
    }

    pub fn rows(&self) -> usize {
        self.is_test.len()
    }

    /// Whether row `r` was assigned to the test side (independent of its
    /// links — single-link test rows still end up skipped).
    pub fn is_test_row(&self, r: usize) -> bool {
        self.is_test[r]
    }

    /// Decide row `r` given its links. Must be called for every row in
    /// ascending order (the per-row RNG stream depends on it).
    pub fn dispose(&mut self, r: usize, idx: &[u32], val: &[f32]) -> RowDisposition {
        assert_eq!(r, self.next_row, "rows must be disposed in ascending order");
        self.next_row += 1;
        if !self.is_test[r] {
            return RowDisposition::Train;
        }
        if idx.is_empty() {
            return RowDisposition::Skip;
        }
        // Hold out a random 25% (at least one if the row is non-trivial,
        // but always keep at least one history link for fold-in).
        let mut order: Vec<usize> = (0..idx.len()).collect();
        self.rng.shuffle(&mut order);
        let mut n_hold = (idx.len() as f64 * self.holdout_frac).round() as usize;
        n_hold = n_hold.clamp(usize::from(idx.len() >= 2), idx.len().saturating_sub(1));
        let mut history = Vec::with_capacity(idx.len() - n_hold);
        let mut holdout = Vec::with_capacity(n_hold);
        for (pos, &i) in order.iter().enumerate() {
            if pos < n_hold {
                holdout.push(idx[i]);
            } else {
                history.push((idx[i], val[i]));
            }
        }
        if holdout.is_empty() {
            return RowDisposition::Skip; // single-link rows cannot be evaluated
        }
        holdout.sort_unstable();
        RowDisposition::Test(TestRow { row: r as u32, history, holdout })
    }
}

/// Perform the strong-generalization split over an in-memory matrix.
///
/// * `train_frac` — fraction of rows kept fully in training (paper: 0.9).
/// * `holdout_frac` — fraction of a test row's outlinks held out (paper: 0.25).
pub fn split_strong_generalization(
    full: &Csr,
    train_frac: f64,
    holdout_frac: f64,
    seed: u64,
) -> Split {
    let mut plan = SplitPlan::new(full.rows, train_frac, holdout_frac, seed);
    let mut indptr = Vec::with_capacity(full.rows + 1);
    indptr.push(0usize);
    let mut indices = Vec::with_capacity(full.nnz());
    let mut values = Vec::with_capacity(full.nnz());
    let mut test = Vec::new();
    for r in 0..full.rows {
        match plan.dispose(r, full.row_indices(r), full.row_values(r)) {
            RowDisposition::Train => {
                indices.extend_from_slice(full.row_indices(r));
                values.extend_from_slice(full.row_values(r));
            }
            RowDisposition::Test(tr) => test.push(tr),
            RowDisposition::Skip => {}
        }
        indptr.push(indices.len());
    }
    let train = Csr { rows: full.rows, cols: full.cols, indptr, indices, values };
    Split { train, test }
}

/// The sharded form of [`Split`]: the training matrix and its transpose as
/// row-/column-range shards, ready for [`crate::als::Trainer::from_sharded`].
pub struct ShardedSplit {
    pub train: ShardedCsr,
    pub train_t: ShardedCsr,
    pub test: Vec<TestRow>,
}

/// Split an in-memory matrix straight into per-shard CSRs (and their
/// transposes) — the same decisions as [`split_strong_generalization`]
/// (bitwise-identical content) without the monolithic intermediate copy.
pub fn split_to_shards(
    full: &Csr,
    num_shards: usize,
    train_frac: f64,
    holdout_frac: f64,
    seed: u64,
) -> ShardedSplit {
    let mut plan = SplitPlan::new(full.rows, train_frac, holdout_frac, seed);
    let mut builder = ShardedCsrBuilder::new(full.rows, full.cols, num_shards);
    let mut test = Vec::new();
    for r in 0..full.rows {
        match plan.dispose(r, full.row_indices(r), full.row_values(r)) {
            RowDisposition::Train => builder.push_row(full.row_indices(r), full.row_values(r)),
            RowDisposition::Test(tr) => {
                test.push(tr);
                builder.push_empty();
            }
            RowDisposition::Skip => builder.push_empty(),
        }
    }
    let train = builder.finish();
    let train_t = train.transpose(num_shards);
    ShardedSplit { train, train_t, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_graph(rows: usize, cols: usize, links_per_row: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows {
            let mut seen = std::collections::HashSet::new();
            while seen.len() < links_per_row {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r as u32, c, 1.0));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    #[test]
    fn split_fractions_roughly_hold() {
        let g = dense_graph(200, 100, 8, 1);
        let s = split_strong_generalization(&g, 0.9, 0.25, 2);
        assert_eq!(s.test.len(), 20);
        // Train keeps all non-test links.
        assert_eq!(s.train.nnz(), 180 * 8);
    }

    #[test]
    fn test_rows_are_empty_in_train() {
        let g = dense_graph(50, 40, 5, 3);
        let s = split_strong_generalization(&g, 0.8, 0.25, 4);
        for tr in &s.test {
            assert_eq!(s.train.row_len(tr.row as usize), 0, "test row leaked into train");
        }
    }

    #[test]
    fn holdout_plus_history_partition_the_row() {
        let g = dense_graph(50, 40, 8, 5);
        let s = split_strong_generalization(&g, 0.8, 0.25, 6);
        for tr in &s.test {
            let mut all: Vec<u32> =
                tr.history.iter().map(|&(c, _)| c).chain(tr.holdout.iter().copied()).collect();
            all.sort_unstable();
            assert_eq!(all, g.row_indices(tr.row as usize));
            // ~25% of 8 links = 2 held out.
            assert_eq!(tr.holdout.len(), 2);
            assert_eq!(tr.history.len(), 6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = dense_graph(60, 30, 4, 7);
        let a = split_strong_generalization(&g, 0.9, 0.25, 8);
        let b = split_strong_generalization(&g, 0.9, 0.25, 8);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test.len(), b.test.len());
    }

    #[test]
    fn sharded_split_is_bitwise_identical_to_classic() {
        let g = dense_graph(120, 60, 6, 11);
        let classic = split_strong_generalization(&g, 0.9, 0.25, 12);
        for shards in [1usize, 3, 8] {
            let sharded = split_to_shards(&g, shards, 0.9, 0.25, 12);
            assert_eq!(sharded.train.to_csr(), classic.train, "shards={shards}");
            assert_eq!(sharded.train_t.to_csr(), classic.train.transpose());
            assert_eq!(sharded.test.len(), classic.test.len());
            for (a, b) in sharded.test.iter().zip(&classic.test) {
                assert_eq!(a.row, b.row);
                assert_eq!(a.history, b.history);
                assert_eq!(a.holdout, b.holdout);
            }
        }
    }

    #[test]
    fn plan_requires_ascending_rows() {
        let mut plan = SplitPlan::new(5, 0.9, 0.25, 1);
        let _ = plan.dispose(0, &[], &[]);
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.dispose(2, &[], &[])
        }));
        assert!(out.is_err(), "out-of-order dispose must panic");
    }

    #[test]
    fn single_link_rows_are_skipped() {
        let g = Csr::from_coo(10, 10, &(0..10).map(|r| (r as u32, 0u32, 1.0)).collect::<Vec<_>>());
        let s = split_strong_generalization(&g, 0.0, 0.25, 9); // everything is a test row
        // Rows have 1 link: cannot hold out and keep history; all skipped.
        assert!(s.test.is_empty());
    }
}
