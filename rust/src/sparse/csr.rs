//! Compressed-sparse-row matrix over f32 values.
//!
//! The training set `S` (paper §3) is a sparse rating/link matrix: rows are
//! users (source pages), columns items (target pages), values the label
//! `y`. One epoch needs a row-major pass for the user side and a
//! column-major pass for the item side, so [`Csr::transpose`] is a core
//! operation (counting sort, O(nnz)).

/// CSR sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from COO triplets (row, col, value). Duplicate (row, col)
    /// entries are summed. Triplets need not be sorted.
    pub fn from_coo(rows: usize, cols: usize, triplets: &[(u32, u32, f32)]) -> Csr {
        // Counting sort by row.
        let mut counts = vec![0usize; rows + 1];
        for &(r, c, _) in triplets {
            assert!((r as usize) < rows && (c as usize) < cols, "triplet out of bounds");
            counts[r as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let mut order = counts.clone();
        let nnz = triplets.len();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        for &(r, c, v) in triplets {
            let slot = order[r as usize];
            order[r as usize] += 1;
            indices[slot] = c;
            values[slot] = v;
        }
        // Sort within each row and merge duplicates.
        let mut out_indices = Vec::with_capacity(nnz);
        let mut out_values = Vec::with_capacity(nnz);
        let mut indptr = vec![0usize; rows + 1];
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for r in 0..rows {
            scratch.clear();
            scratch.extend(
                indices[counts[r]..counts[r + 1]]
                    .iter()
                    .copied()
                    .zip(values[counts[r]..counts[r + 1]].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                out_indices.push(c);
                out_values.push(v);
                i = j;
            }
            indptr[r + 1] = out_indices.len();
        }
        Csr { rows, cols, indptr, indices: out_indices, values: out_values }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Length of row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Transpose in O(nnz) via counting sort; the item-side pass of ALS
    /// iterates rows of `Sᵀ`.
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let mut indptr = counts.clone();
        let mut slots = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            for (i, &c) in self.row_indices(r).iter().enumerate() {
                let v = self.row_values(r)[i];
                let slot = slots[c as usize];
                slots[c as usize] += 1;
                indices[slot] = r as u32;
                values[slot] = v;
            }
        }
        indptr[self.cols] = self.nnz();
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Row-length distribution as f64s (used for dense-batch tuning).
    pub fn row_length_histogram(&self) -> Vec<f64> {
        (0..self.rows).map(|r| self.row_len(r) as f64).collect()
    }

    /// Serialize to a simple little-endian binary format.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        w.write_all(b"ALXCSR01")?;
        for v in [self.rows as u64, self.cols as u64, self.nnz() as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        for &p in &self.indptr {
            w.write_all(&(p as u64).to_le_bytes())?;
        }
        for &i in &self.indices {
            w.write_all(&i.to_le_bytes())?;
        }
        for &v in &self.values {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialize the [`Csr::write_to`] format.
    pub fn read_from(r: &mut impl std::io::Read) -> std::io::Result<Csr> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != b"ALXCSR01" {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad magic"));
        }
        let mut u64buf = [0u8; 8];
        let mut read_u64 = |r: &mut dyn std::io::Read| -> std::io::Result<u64> {
            r.read_exact(&mut u64buf)?;
            Ok(u64::from_le_bytes(u64buf))
        };
        let rows = read_u64(r)? as usize;
        let cols = read_u64(r)? as usize;
        let nnz = read_u64(r)? as usize;
        let mut indptr = Vec::with_capacity(rows + 1);
        for _ in 0..=rows {
            indptr.push(read_u64(r)? as usize);
        }
        let mut indices = vec![0u32; nnz];
        let mut buf4 = [0u8; 4];
        for i in indices.iter_mut() {
            r.read_exact(&mut buf4)?;
            *i = u32::from_le_bytes(buf4);
        }
        let mut values = vec![0.0f32; nnz];
        for v in values.iter_mut() {
            r.read_exact(&mut buf4)?;
            *v = f32::from_le_bytes(buf4);
        }
        Ok(Csr { rows, cols, indptr, indices, values })
    }

    /// Memory footprint of the stored arrays in bytes.
    pub fn memory_bytes(&self) -> u64 {
        (self.indptr.len() * 8 + self.indices.len() * 4 + self.values.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[0 1 0]
        //  [2 0 3]
        //  [0 0 0]
        //  [4 5 6]]
        Csr::from_coo(
            4,
            3,
            &[(0, 1, 1.0), (1, 0, 2.0), (1, 2, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 2, 6.0)],
        )
    }

    #[test]
    fn from_coo_sorts_rows() {
        let m = Csr::from_coo(2, 4, &[(0, 3, 1.0), (0, 1, 2.0), (0, 2, 3.0)]);
        assert_eq!(m.row_indices(0), &[1, 2, 3]);
        assert_eq!(m.row_values(0), &[2.0, 3.0, 1.0]);
        assert_eq!(m.row_len(1), 0);
    }

    #[test]
    fn from_coo_merges_duplicates() {
        let m = Csr::from_coo(1, 2, &[(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_values(0), &[3.5]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.cols, 4);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_entries_match() {
        let m = sample();
        let t = m.transpose();
        // Column 0 of m = rows {1:2.0, 3:4.0}
        assert_eq!(t.row_indices(0), &[1, 3]);
        assert_eq!(t.row_values(0), &[2.0, 4.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::from_coo(3, 3, &[]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.transpose().nnz(), 0);
    }

    #[test]
    fn io_roundtrip() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let m2 = Csr::read_from(&mut &buf[..]).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn io_rejects_bad_magic() {
        let buf = b"NOTMAGIC".to_vec();
        assert!(Csr::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_coo_bounds_checked() {
        Csr::from_coo(2, 2, &[(2, 0, 1.0)]);
    }
}
