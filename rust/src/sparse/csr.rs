//! Compressed-sparse-row matrix over f32 values.
//!
//! The training set `S` (paper §3) is a sparse rating/link matrix: rows are
//! users (source pages), columns items (target pages), values the label
//! `y`. One epoch needs a row-major pass for the user side and a
//! column-major pass for the item side, so [`Csr::transpose`] is a core
//! operation (counting sort, O(nnz)).

/// Row-major read access to a sparse matrix — the minimal surface the
/// dense batcher, feeder pipeline and objective pass need. Implemented by
/// the monolithic [`Csr`] and by [`super::ShardedCsr`], so the trainer can
/// run over either storage layout.
pub trait RowMatrix {
    /// Length of row `r`.
    fn row_len(&self, r: usize) -> usize;
    /// Column indices of row `r` (sorted ascending).
    fn row_indices(&self, r: usize) -> &[u32];
    /// Values of row `r`.
    fn row_values(&self, r: usize) -> &[f32];
}

/// CSR sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from COO triplets (row, col, value). Duplicate (row, col)
    /// entries are summed. Triplets need not be sorted.
    pub fn from_coo(rows: usize, cols: usize, triplets: &[(u32, u32, f32)]) -> Csr {
        // Counting sort by row.
        let mut counts = vec![0usize; rows + 1];
        for &(r, c, _) in triplets {
            assert!((r as usize) < rows && (c as usize) < cols, "triplet out of bounds");
            counts[r as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let mut order = counts.clone();
        let nnz = triplets.len();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        for &(r, c, v) in triplets {
            let slot = order[r as usize];
            order[r as usize] += 1;
            indices[slot] = c;
            values[slot] = v;
        }
        // Sort within each row and merge duplicates.
        let mut out_indices = Vec::with_capacity(nnz);
        let mut out_values = Vec::with_capacity(nnz);
        let mut indptr = vec![0usize; rows + 1];
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for r in 0..rows {
            scratch.clear();
            scratch.extend(
                indices[counts[r]..counts[r + 1]]
                    .iter()
                    .copied()
                    .zip(values[counts[r]..counts[r + 1]].iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                out_indices.push(c);
                out_values.push(v);
                i = j;
            }
            indptr[r + 1] = out_indices.len();
        }
        Csr { rows, cols, indptr, indices: out_indices, values: out_values }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Length of row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Transpose in O(nnz) via counting sort; the item-side pass of ALS
    /// iterates rows of `Sᵀ`.
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let mut indptr = counts.clone();
        let mut slots = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            for (i, &c) in self.row_indices(r).iter().enumerate() {
                let v = self.row_values(r)[i];
                let slot = slots[c as usize];
                slots[c as usize] += 1;
                indices[slot] = r as u32;
                values[slot] = v;
            }
        }
        indptr[self.cols] = self.nnz();
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Row-length distribution as f64s (used for dense-batch tuning).
    pub fn row_length_histogram(&self) -> Vec<f64> {
        (0..self.rows).map(|r| self.row_len(r) as f64).collect()
    }

    /// Serialize to a simple little-endian binary format (`ALXCSR01`).
    /// Arrays are written in bulk blocks, not element by element — this is
    /// the epoch-0 load/save time for file-backed runs.
    pub fn write_to(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        w.write_all(b"ALXCSR01")?;
        for v in [self.rows as u64, self.cols as u64, self.nnz() as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        io::write_u64s(w, self.indptr.iter().map(|&p| p as u64))?;
        io::write_u32s(w, &self.indices)?;
        io::write_f32s(w, &self.values)?;
        Ok(())
    }

    /// Deserialize the [`Csr::write_to`] format from an unbounded stream.
    ///
    /// Allocations grow with the bytes actually read (never with the
    /// untrusted header alone), and the structural invariants are checked:
    /// `indptr` monotone with `indptr[0] == 0` and `indptr[rows] == nnz`,
    /// every column index `< cols`. A corrupt or truncated file yields
    /// `InvalidData`/`UnexpectedEof`, never a panic or an OOM allocation.
    pub fn read_from(r: &mut impl std::io::Read) -> std::io::Result<Csr> {
        Self::read_from_limited(r, None)
    }

    /// [`Csr::read_from`] with a known stream length (in bytes, counting
    /// the magic). The header is validated against it up front, so a lying
    /// header fails before any large allocation happens.
    pub fn read_from_limited(
        r: &mut impl std::io::Read,
        stream_len: Option<u64>,
    ) -> std::io::Result<Csr> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != b"ALXCSR01" {
            return Err(io::bad("bad magic (expected ALXCSR01)"));
        }
        let mut u64buf = [0u8; 8];
        let mut read_u64 = |r: &mut dyn std::io::Read| -> std::io::Result<u64> {
            r.read_exact(&mut u64buf)?;
            Ok(u64::from_le_bytes(u64buf))
        };
        let rows64 = read_u64(r)?;
        let cols64 = read_u64(r)?;
        let nnz64 = read_u64(r)?;
        if cols64 > u32::MAX as u64 + 1 {
            return Err(io::bad(format!("cols {cols64} exceeds the u32 index space")));
        }
        // Exact body size implied by the header; with a known stream
        // length this rejects oversized rows/nnz before any allocation.
        let body = (rows64 as u128 + 1) * 8 + nnz64 as u128 * 8;
        if let Some(len) = stream_len {
            let have = (len as u128).saturating_sub(32);
            if body > have {
                return Err(io::bad(format!(
                    "header claims {rows64} rows / {nnz64} nnz ({body} body bytes) \
                     but only {have} bytes remain in the stream"
                )));
            }
        }
        let rows = usize::try_from(rows64).map_err(|_| io::bad("rows exceeds usize"))?;
        let cols = usize::try_from(cols64).map_err(|_| io::bad("cols exceeds usize"))?;
        let nnz = usize::try_from(nnz64).map_err(|_| io::bad("nnz exceeds usize"))?;
        rows.checked_add(1).ok_or_else(|| io::bad("rows exceeds usize"))?;

        // indptr: stream in blocks, validating monotonicity as it arrives.
        let bounded = stream_len.is_some();
        let mut indptr: Vec<usize> = io::alloc_guarded(rows + 1, bounded)?;
        let mut prev = 0u64;
        io::read_u64s(r, rows + 1, |p| {
            if indptr.is_empty() && p != 0 {
                return Err(io::bad("indptr[0] != 0"));
            }
            if p < prev {
                return Err(io::bad("non-monotonic indptr"));
            }
            if p > nnz64 {
                return Err(io::bad(format!("indptr entry {p} exceeds nnz {nnz64}")));
            }
            prev = p;
            indptr.push(p as usize);
            Ok(())
        })?;
        if indptr[rows] != nnz {
            return Err(io::bad(format!(
                "indptr[rows] = {} but header claims nnz = {nnz}",
                indptr[rows]
            )));
        }

        let mut indices: Vec<u32> = io::alloc_guarded(nnz, bounded)?;
        io::read_u32s(r, nnz, |i| {
            if i as u64 >= cols64 {
                return Err(io::bad(format!("column index {i} out of range (cols = {cols})")));
            }
            indices.push(i);
            Ok(())
        })?;
        let mut values: Vec<f32> = io::alloc_guarded(nnz, bounded)?;
        io::read_f32s(r, nnz, |v| {
            values.push(v);
            Ok(())
        })?;
        Ok(Csr { rows, cols, indptr, indices, values })
    }

    /// Memory footprint of the stored arrays in bytes.
    pub fn memory_bytes(&self) -> u64 {
        (self.indptr.len() * 8 + self.indices.len() * 4 + self.values.len() * 4) as u64
    }
}

impl RowMatrix for Csr {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        Csr::row_len(self, r)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        Csr::row_indices(self, r)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        Csr::row_values(self, r)
    }
}

impl<M: RowMatrix + ?Sized> RowMatrix for &M {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        (**self).row_len(r)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        (**self).row_indices(r)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        (**self).row_values(r)
    }
}

impl<M: RowMatrix + ?Sized> RowMatrix for std::sync::Arc<M> {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        (**self).row_len(r)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        (**self).row_indices(r)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        (**self).row_values(r)
    }
}

/// Bulk little-endian array IO shared by the `ALXCSR01` and `ALXCSR02`
/// codecs: fixed-size staging blocks instead of per-element `read_exact`/
/// `write_all` calls, and allocation guards for untrusted element counts.
pub(crate) mod io {
    use std::io::{Read, Result, Write};

    /// Elements staged per IO block (64 Ki elements ≈ 256-512 KiB).
    const BLOCK: usize = 64 * 1024;

    pub(crate) fn bad(msg: impl Into<String>) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
    }

    /// A vector for `n` untrusted elements: preallocate only when the count
    /// was validated against the stream length; otherwise start at one
    /// block and let growth track the bytes actually read.
    pub(crate) fn alloc_guarded<T>(n: usize, trusted: bool) -> Result<Vec<T>> {
        Ok(Vec::with_capacity(if trusted { n } else { n.min(BLOCK) }))
    }

    /// Shared staging loop for 4-byte elements (u32 and bit-cast f32).
    fn write_u32_stream(
        w: &mut impl Write,
        xs: impl Iterator<Item = u32>,
    ) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(BLOCK * 4);
        for x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
            if buf.len() >= BLOCK * 4 {
                w.write_all(&buf)?;
                buf.clear();
            }
        }
        if !buf.is_empty() {
            w.write_all(&buf)?;
        }
        Ok(())
    }

    pub(crate) fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
        write_u32_stream(w, xs.iter().copied())
    }

    pub(crate) fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
        // f32::to_le_bytes is the LE encoding of the IEEE bit pattern, so
        // the bit-cast delegation is exact (mirrors `read_f32s`).
        write_u32_stream(w, xs.iter().map(|x| x.to_bits()))
    }

    pub(crate) fn write_u64s(
        w: &mut impl Write,
        xs: impl Iterator<Item = u64>,
    ) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(BLOCK * 8);
        for x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
            if buf.len() >= BLOCK * 8 {
                w.write_all(&buf)?;
                buf.clear();
            }
        }
        if !buf.is_empty() {
            w.write_all(&buf)?;
        }
        Ok(())
    }

    pub(crate) fn read_u32s(
        r: &mut impl Read,
        n: usize,
        mut sink: impl FnMut(u32) -> Result<()>,
    ) -> Result<()> {
        let mut byte_buf = vec![0u8; BLOCK.min(n.max(1)) * 4];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(BLOCK);
            let buf = &mut byte_buf[..take * 4];
            r.read_exact(buf)?;
            for b in buf.chunks_exact(4) {
                sink(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))?;
            }
            remaining -= take;
        }
        Ok(())
    }

    pub(crate) fn read_f32s(
        r: &mut impl Read,
        n: usize,
        mut sink: impl FnMut(f32) -> Result<()>,
    ) -> Result<()> {
        read_u32s(r, n, |bits| sink(f32::from_bits(bits)))
    }

    pub(crate) fn read_u64s(
        r: &mut impl Read,
        n: usize,
        mut sink: impl FnMut(u64) -> Result<()>,
    ) -> Result<()> {
        let mut byte_buf = vec![0u8; BLOCK.min(n.max(1)) * 8];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(BLOCK);
            let buf = &mut byte_buf[..take * 8];
            r.read_exact(buf)?;
            for b in buf.chunks_exact(8) {
                sink(u64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ]))?;
            }
            remaining -= take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[0 1 0]
        //  [2 0 3]
        //  [0 0 0]
        //  [4 5 6]]
        Csr::from_coo(
            4,
            3,
            &[(0, 1, 1.0), (1, 0, 2.0), (1, 2, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 2, 6.0)],
        )
    }

    #[test]
    fn from_coo_sorts_rows() {
        let m = Csr::from_coo(2, 4, &[(0, 3, 1.0), (0, 1, 2.0), (0, 2, 3.0)]);
        assert_eq!(m.row_indices(0), &[1, 2, 3]);
        assert_eq!(m.row_values(0), &[2.0, 3.0, 1.0]);
        assert_eq!(m.row_len(1), 0);
    }

    #[test]
    fn from_coo_merges_duplicates() {
        let m = Csr::from_coo(1, 2, &[(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row_values(0), &[3.5]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.cols, 4);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_entries_match() {
        let m = sample();
        let t = m.transpose();
        // Column 0 of m = rows {1:2.0, 3:4.0}
        assert_eq!(t.row_indices(0), &[1, 3]);
        assert_eq!(t.row_values(0), &[2.0, 4.0]);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::from_coo(3, 3, &[]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.transpose().nnz(), 0);
    }

    #[test]
    fn io_roundtrip() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let m2 = Csr::read_from(&mut &buf[..]).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn io_rejects_bad_magic() {
        let buf = b"NOTMAGIC".to_vec();
        assert!(Csr::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn io_roundtrip_with_known_length() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let m2 = Csr::read_from_limited(&mut &buf[..], Some(buf.len() as u64)).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn io_rejects_oversized_header_against_stream_length() {
        // A header claiming a multi-GB body must fail the length check
        // before any allocation, not OOM.
        let mut buf = Vec::new();
        buf.extend_from_slice(b"ALXCSR01");
        buf.extend_from_slice(&(1u64 << 40).to_le_bytes()); // rows
        buf.extend_from_slice(&8u64.to_le_bytes()); // cols
        buf.extend_from_slice(&(1u64 << 50).to_le_bytes()); // nnz
        let len = buf.len() as u64;
        let err = Csr::read_from_limited(&mut &buf[..], Some(len)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        // Unbounded streams fail on EOF instead, still without a huge
        // upfront allocation.
        assert!(Csr::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn io_rejects_non_monotonic_indptr() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        // indptr starts at byte 32; swap two entries to break monotonicity.
        let a = 32 + 8; // indptr[1]
        let b = 32 + 3 * 8; // indptr[3]
        for k in 0..8 {
            buf.swap(a + k, b + k);
        }
        let err = Csr::read_from(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn io_rejects_out_of_range_column() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        // First index lives right after the indptr block.
        let idx0 = 32 + (m.rows + 1) * 8;
        buf[idx0..idx0 + 4].copy_from_slice(&(m.cols as u32 + 7).to_le_bytes());
        let err = Csr::read_from(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn io_rejects_indptr_nnz_mismatch() {
        let m = sample();
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        // Inflate the final indptr entry past the header nnz.
        let last = 32 + m.rows * 8;
        buf[last..last + 8].copy_from_slice(&(m.nnz() as u64 + 3).to_le_bytes());
        let err = Csr::read_from(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_coo_bounds_checked() {
        Csr::from_coo(2, 2, &[(2, 0, 1.0)]);
    }
}
