//! Pluggable shard storage behind [`super::ShardedCsr`].
//!
//! The trainer only ever touches the training matrix one shard at a time
//! (shard pass μ reads matrix shard μ; the objective walks shards in
//! order), so where the shards *live* is a storage policy, not a trainer
//! concern. A [`CsrStorage`] backend hands out materialized shards as
//! `Arc<Csr>` handles:
//!
//! * [`InMemory`] — every shard resident, handles are free clones. The
//!   default; exactly the pre-spill behaviour.
//! * [`MmapBank`] — shards live in a memory-mapped `ALXBANK01` file and
//!   materialize on demand through a small residency manager: an LRU of
//!   at most `resident_shards` decoded shards plus background prefetch of
//!   the shard the trainer will claim next. Steady-state memory is
//!   bounded by the residency cap, not the matrix.
//!
//! Backends are *storage* only: a shard's decoded bytes are identical
//! whichever backend serves it, which is what makes spilled training
//! bitwise identical to resident training.

use super::bank::CsrBank;
use super::csr::{Csr, RowMatrix};
use crate::util::fault;
use crate::util::threads::{lock_or_recover, stall_timeout_ms};
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Residency/fault accounting of a storage backend (all zero for fully
/// resident backends).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Synchronous shard loads: the consumer had to wait for the decode.
    pub shard_faults: u64,
    /// Shard requests served from the residency cache (typically because
    /// a prefetch had already staged the shard).
    pub prefetch_hits: u64,
    /// Prefetches issued to the background loader.
    pub prefetches: u64,
    /// Background loads that died (panic or IO failure) and degraded to
    /// an on-demand fault instead of staging their shard.
    pub prefetch_failures: u64,
    /// Bytes of the on-disk bank backing this storage.
    pub bank_bytes: u64,
}

impl SpillStats {
    /// Fraction of shard requests that did not fault (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.shard_faults + self.prefetch_hits;
        if total == 0 {
            return 0.0;
        }
        self.prefetch_hits as f64 / total as f64
    }

    /// Field-wise sum (to combine the train and transpose banks).
    pub fn merged(&self, other: &SpillStats) -> SpillStats {
        SpillStats {
            shard_faults: self.shard_faults + other.shard_faults,
            prefetch_hits: self.prefetch_hits + other.prefetch_hits,
            prefetches: self.prefetches + other.prefetches,
            prefetch_failures: self.prefetch_failures + other.prefetch_failures,
            bank_bytes: self.bank_bytes + other.bank_bytes,
        }
    }
}

/// Where the row-range shards of a [`super::ShardedCsr`] live.
pub trait CsrStorage: Send + Sync + 'static {
    fn num_pieces(&self) -> usize;

    /// A materialized handle to piece `p`. Cheap for resident backends;
    /// may fault the shard in from disk for spilled ones. The returned
    /// data is identical across backends and calls.
    fn piece(&self, p: usize) -> Arc<Csr>;

    /// Hint that piece `p` will be requested soon (no-op by default).
    fn prefetch(&self, _p: usize) {}

    /// Residency/fault accounting.
    fn spill_stats(&self) -> SpillStats {
        SpillStats::default()
    }

    /// Bytes currently resident in host memory.
    fn resident_bytes(&self) -> u64;
}

/// The default backend: every shard resident, shared via `Arc`.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct InMemory {
    pub(crate) pieces: Vec<Arc<Csr>>,
}

impl InMemory {
    pub fn new(pieces: Vec<Csr>) -> InMemory {
        InMemory { pieces: pieces.into_iter().map(Arc::new).collect() }
    }
}

impl CsrStorage for InMemory {
    fn num_pieces(&self) -> usize {
        self.pieces.len()
    }

    fn piece(&self, p: usize) -> Arc<Csr> {
        Arc::clone(&self.pieces[p])
    }

    fn resident_bytes(&self) -> u64 {
        self.pieces.iter().map(|p| p.memory_bytes()).sum()
    }
}

/// LRU residency state of an [`MmapBank`]: front = most recently used.
struct Residency {
    resident: VecDeque<(usize, Arc<Csr>)>,
    loading: HashSet<usize>,
}

struct BankShared {
    bank: CsrBank,
    cap: usize,
    state: Mutex<Residency>,
    loaded: Condvar,
    faults: AtomicU64,
    hits: AtomicU64,
    prefetches: AtomicU64,
    prefetch_failures: AtomicU64,
}

impl BankShared {
    /// Insert a freshly decoded shard at the MRU position and evict past
    /// the cap. Evicted handles still in use elsewhere stay alive until
    /// their last `Arc` drops — eviction never invalidates a consumer.
    fn insert(&self, p: usize, csr: Arc<Csr>) {
        let mut g = lock_or_recover(&self.state);
        g.loading.remove(&p);
        if !g.resident.iter().any(|(q, _)| *q == p) {
            g.resident.push_front((p, csr));
            while g.resident.len() > self.cap {
                g.resident.pop_back();
            }
        }
        self.loaded.notify_all();
    }
}

/// Clears a piece's in-flight `loading` mark when dropped. Every loader
/// (synchronous fault or prefetch thread) holds one across the decode, so
/// a panic mid-decode wakes the waiters instead of wedging them on the
/// condvar forever — they retry (and surface the underlying failure on
/// their own thread) rather than hang the epoch. The successful path's
/// `insert` already removed the mark; the second removal is a no-op.
struct LoadingGuard<'a> {
    shared: &'a BankShared,
    p: usize,
}

impl Drop for LoadingGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock_or_recover(&self.shared.state);
        g.loading.remove(&self.p);
        drop(g);
        self.shared.loaded.notify_all();
    }
}

/// Demand-paged storage over a memory-mapped `ALXBANK01` bank.
#[derive(Clone)]
pub struct MmapBank {
    shared: Arc<BankShared>,
}

impl MmapBank {
    /// Wrap an opened bank with a residency cap of `resident_shards`
    /// decoded shards (clamped to at least 1).
    pub fn new(bank: CsrBank, resident_shards: usize) -> MmapBank {
        MmapBank {
            shared: Arc::new(BankShared {
                bank,
                cap: resident_shards.max(1),
                state: Mutex::new(Residency {
                    resident: VecDeque::new(),
                    loading: HashSet::new(),
                }),
                loaded: Condvar::new(),
                faults: AtomicU64::new(0),
                hits: AtomicU64::new(0),
                prefetches: AtomicU64::new(0),
                prefetch_failures: AtomicU64::new(0),
            }),
        }
    }

    pub fn bank(&self) -> &CsrBank {
        &self.shared.bank
    }

    /// Max decoded shards resident at once.
    pub fn resident_cap(&self) -> usize {
        self.shared.cap
    }
}

impl std::fmt::Debug for MmapBank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapBank")
            .field("shards", &self.shared.bank.num_shards())
            .field("cap", &self.shared.cap)
            .finish()
    }
}

impl CsrStorage for MmapBank {
    fn num_pieces(&self) -> usize {
        self.shared.bank.num_shards()
    }

    fn piece(&self, p: usize) -> Arc<Csr> {
        let s = &*self.shared;
        let mut g = lock_or_recover(&s.state);
        loop {
            if let Some(pos) = g.resident.iter().position(|(q, _)| *q == p) {
                let entry = g.resident.remove(pos).unwrap();
                let csr = Arc::clone(&entry.1);
                g.resident.push_front(entry);
                s.hits.fetch_add(1, Ordering::Relaxed);
                return csr;
            }
            if g.loading.contains(&p) {
                // A prefetch (or another consumer) is already decoding it.
                // Bounded wait: if the loader stalls or dies without
                // clearing its mark, steal the load and fault on demand
                // instead of hanging the epoch.
                let (ng, timeout) = s
                    .loaded
                    .wait_timeout(g, Duration::from_millis(stall_timeout_ms()))
                    .unwrap_or_else(|e| e.into_inner());
                g = ng;
                if timeout.timed_out() && g.loading.contains(&p) {
                    crate::log_warn!(
                        "background load of matrix shard {p} stalled past {}ms; \
                         loading on demand",
                        stall_timeout_ms()
                    );
                    g.loading.remove(&p);
                }
                continue;
            }
            // Fault: decode synchronously on this thread.
            g.loading.insert(p);
            drop(g);
            let guard = LoadingGuard { shared: s, p };
            let csr = Arc::new(s.bank.load_shard(p));
            s.faults.fetch_add(1, Ordering::Relaxed);
            s.insert(p, Arc::clone(&csr));
            drop(guard);
            return csr;
        }
    }

    fn prefetch(&self, p: usize) {
        let s = &*self.shared;
        {
            let mut g = lock_or_recover(&s.state);
            if g.loading.contains(&p) || g.resident.iter().any(|(q, _)| *q == p) {
                return;
            }
            g.loading.insert(p);
        }
        s.prefetches.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::clone(&self.shared);
        std::thread::spawn(move || {
            // Panic isolation: a dying prefetch thread clears its loading
            // mark (the guard) and is counted, and the consumer degrades
            // to an on-demand fault — never a hung epoch or lost shard.
            let guard = LoadingGuard { shared: &shared, p };
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                fault::failpoint("prefetch.matrix")?;
                let csr = Arc::new(shared.bank.load_shard(p));
                shared.insert(p, csr);
                Ok::<(), std::io::Error>(())
            }));
            match r {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    shared.prefetch_failures.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!(
                        "prefetch of matrix shard {p} failed ({e}); it will load on demand"
                    );
                }
                Err(_) => {
                    shared.prefetch_failures.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!(
                        "prefetch thread for matrix shard {p} panicked; it will load on demand"
                    );
                }
            }
            drop(guard);
        });
    }

    fn spill_stats(&self) -> SpillStats {
        let s = &*self.shared;
        SpillStats {
            shard_faults: s.faults.load(Ordering::Relaxed),
            prefetch_hits: s.hits.load(Ordering::Relaxed),
            prefetches: s.prefetches.load(Ordering::Relaxed),
            prefetch_failures: s.prefetch_failures.load(Ordering::Relaxed),
            bank_bytes: s.bank.file_bytes(),
        }
    }

    fn resident_bytes(&self) -> u64 {
        let g = lock_or_recover(&self.shared.state);
        g.resident.iter().map(|(_, c)| c.memory_bytes()).sum()
    }
}

/// Object-safe view of a sharded matrix for the trainer: shape plus
/// demand-paged shard access. Implemented by [`super::ShardedCsr`] over
/// every [`CsrStorage`] backend, so the trainer is oblivious to whether
/// the matrix is resident or spilled.
pub trait ShardedMatrix: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn nnz(&self) -> usize;
    fn num_pieces(&self) -> usize;
    /// Global row range `[start, end)` of piece `p`.
    fn piece_range(&self, p: usize) -> (usize, usize);
    /// The piece holding global row `r`.
    fn piece_of(&self, r: usize) -> usize;
    /// Materialized handle to piece `p`.
    fn piece(&self, p: usize) -> Arc<Csr>;
    /// Hint that piece `p` will be requested soon.
    fn prefetch(&self, p: usize);
    fn spill_stats(&self) -> SpillStats;
    fn resident_bytes(&self) -> u64;
}

/// Lazily materialized view of one piece, addressed by **global** row id
/// — the [`RowMatrix`] the feeder pipeline batches from. The shard is
/// faulted in on first row access, i.e. on the feeder's background
/// thread, so a demand-paged load overlaps the consumer's solve of the
/// previous shard instead of stalling it.
pub struct PieceRows {
    matrix: Arc<dyn ShardedMatrix>,
    p: usize,
    base: usize,
    piece: OnceLock<Arc<Csr>>,
}

impl PieceRows {
    pub fn new(matrix: Arc<dyn ShardedMatrix>, p: usize) -> PieceRows {
        let base = matrix.piece_range(p).0;
        PieceRows { matrix, p, base, piece: OnceLock::new() }
    }

    #[inline]
    fn piece(&self) -> &Csr {
        self.piece.get_or_init(|| self.matrix.piece(self.p)).as_ref()
    }
}

impl RowMatrix for PieceRows {
    #[inline]
    fn row_len(&self, r: usize) -> usize {
        self.piece().row_len(r - self.base)
    }

    #[inline]
    fn row_indices(&self, r: usize) -> &[u32] {
        self.piece().row_indices(r - self.base)
    }

    #[inline]
    fn row_values(&self, r: usize) -> &[f32] {
        self.piece().row_values(r - self.base)
    }
}

#[cfg(test)]
mod tests {
    use super::super::ShardedCsr;
    use super::*;
    use crate::util::Pcg64;

    fn sample(rows: usize, cols: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = rng.range(1, 6);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r, c, (r + c) as f32));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    fn bank_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_storage_{}_{}.alxbank", tag, std::process::id()))
    }

    #[test]
    fn mmap_bank_serves_identical_pieces() {
        let m = sample(40, 12, 1);
        let resident = ShardedCsr::from_csr(&m, 5);
        let path = bank_path("ident");
        resident.spill_to_bank(&path).unwrap();
        let paged = MmapBank::new(CsrBank::open(&path).unwrap(), 2);
        for p in 0..5 {
            assert_eq!(paged.piece(p), resident.piece(p), "piece {p}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lru_evicts_past_the_cap_and_counts_faults() {
        let m = sample(60, 10, 2);
        let resident = ShardedCsr::from_csr(&m, 6);
        let path = bank_path("lru");
        resident.spill_to_bank(&path).unwrap();
        let paged = MmapBank::new(CsrBank::open(&path).unwrap(), 2);
        // Cold pass: every piece faults, residency never exceeds the cap.
        for p in 0..6 {
            let _ = paged.piece(p);
            let g = paged.shared.state.lock().unwrap();
            assert!(g.resident.len() <= 2);
        }
        let s = paged.spill_stats();
        assert_eq!(s.shard_faults, 6);
        assert_eq!(s.prefetch_hits, 0);
        // Re-touching the MRU piece hits.
        let _ = paged.piece(5);
        assert_eq!(paged.spill_stats().prefetch_hits, 1);
        // An evicted piece faults again.
        let _ = paged.piece(0);
        assert_eq!(paged.spill_stats().shard_faults, 7);
        assert!(s.bank_bytes > 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prefetch_stages_a_piece_for_a_hit() {
        let m = sample(30, 8, 3);
        let resident = ShardedCsr::from_csr(&m, 3);
        let path = bank_path("prefetch");
        resident.spill_to_bank(&path).unwrap();
        let paged = MmapBank::new(CsrBank::open(&path).unwrap(), 2);
        paged.prefetch(1);
        // piece() must return the staged (or in-flight) shard without a
        // second decode racing the prefetch.
        let got = paged.piece(1);
        assert_eq!(got, resident.piece(1));
        let s = paged.spill_stats();
        assert_eq!(s.prefetches, 1);
        assert_eq!(s.shard_faults + s.prefetch_hits, 1);
        // Idempotent while resident or loading.
        paged.prefetch(1);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(paged.spill_stats().prefetches <= 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn concurrent_piece_calls_agree() {
        let m = sample(80, 16, 4);
        let resident = ShardedCsr::from_csr(&m, 8);
        let path = bank_path("concurrent");
        resident.spill_to_bank(&path).unwrap();
        let paged = Arc::new(MmapBank::new(CsrBank::open(&path).unwrap(), 2));
        let handles: Vec<_> = (0..4)
            .map(|w| {
                let paged = Arc::clone(&paged);
                std::thread::spawn(move || {
                    for round in 0..3 {
                        for p in 0..8 {
                            let piece = paged.piece((p + w) % 8);
                            assert!(piece.rows > 0 || piece.nnz() == 0, "round {round}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for p in 0..8 {
            assert_eq!(paged.piece(p), resident.piece(p));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn piece_rows_addresses_globally() {
        let m = sample(20, 9, 5);
        let sharded = Arc::new(ShardedCsr::from_csr(&m, 4));
        let view = PieceRows::new(sharded.clone() as Arc<dyn ShardedMatrix>, 2);
        let (start, end) = sharded.piece_range(2);
        for r in start..end {
            assert_eq!(view.row_indices(r), m.row_indices(r));
            assert_eq!(view.row_values(r), m.row_values(r));
            assert_eq!(view.row_len(r), m.row_len(r));
        }
    }
}
