//! `ALXBANK01` — the shard-major on-disk bank behind spilled training.
//!
//! `ALXCSR02` solved out-of-core *ingestion* (row-range chunks, read
//! once, front to back). Training has a different access pattern: each
//! shard pass needs one whole shard (and later its transpose shard)
//! resident, over and over, epoch after epoch. A bank therefore stores
//! the matrix **shard-major**: one self-contained CSR segment per shard,
//! with a validated directory of per-shard offsets and nnz, so a single
//! shard can be faulted in without touching the rest of the file.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "ALXBANK01" + 7 zero bytes          16 bytes
//! rows u64 | cols u64 | nnz u64 | num_shards u64
//! directory, num_shards entries:
//!   seg_offset u64 | seg_rows u64 | seg_nnz u64
//! per shard segment (back to back, in shard order):
//!   indptr  u64 × (seg_rows + 1)     (shard-local, indptr[0] == 0)
//!   indices u32 × seg_nnz            (sorted strictly ascending per row)
//!   values  f32 × seg_nnz
//! ```
//!
//! Shard `p` holds global rows `[p·per, min((p+1)·per, rows))` with
//! `per = ceil(rows / num_shards)` — the exact uniform partition of
//! [`super::ShardedCsr`] and [`crate::sharding::ShardedTable`], so bank
//! shard `p` is table shard `p`'s input.
//!
//! [`CsrBank::open`] memory-maps the file and validates **everything** up
//! front — header against the exact file length, the directory against
//! the canonical layout, every segment's `indptr` monotonicity and every
//! column index — so a corrupt or lying file fails with `InvalidData`
//! before any shard-sized allocation, and a successfully opened bank can
//! be decoded infallibly for the rest of the run.

use super::csr::{io, Csr};
use crate::util::mmap::Mmap;
use crate::util::{durable, fault};
use std::io::{Result, Seek, SeekFrom, Write};
use std::path::Path;

/// File magic of the bank format (padded to 16 bytes in the header).
pub const ALXBANK01_MAGIC: &[u8; 9] = b"ALXBANK01";
const MAGIC_BYTES: usize = 16;
/// Magic + rows/cols/nnz/num_shards.
const HEADER_BYTES: usize = MAGIC_BYTES + 4 * 8;
const DIR_ENTRY_BYTES: usize = 3 * 8;

/// Default scratch bound for the multi-writer transpose derivation
/// ([`CsrBank::write_transpose_bank_budgeted`]) when the caller has no
/// ingest budget configured: spill mode promises bounded memory, so an
/// unset budget must not mean "materialize the whole transpose in one
/// group" — 256 MiB still groups many transpose shards per scan on
/// typical datasets while keeping the bound honest.
pub const DEFAULT_TRANSPOSE_SCRATCH_BYTES: u64 = 256 << 20;

/// Rows-per-shard of the uniform partition every bank uses (shared with
/// [`super::ShardedCsr`]).
pub(crate) fn per_for(rows: usize, num_shards: usize) -> usize {
    rows.div_ceil(num_shards.max(1)).max(1)
}

fn shard_range(rows: usize, per: usize, p: usize) -> (usize, usize) {
    ((p * per).min(rows), ((p + 1) * per).min(rows))
}

/// Byte size of one shard segment.
fn segment_bytes(rows: usize, nnz: usize) -> u128 {
    (rows as u128 + 1) * 8 + nnz as u128 * 8
}

/// Writes an `ALXBANK01` file: shards are appended in order (each one a
/// complete shard-local [`Csr`]), and [`BankWriter::finish`] backpatches
/// the totals and the directory. Streaming writers (the spill ingestion
/// path) therefore never hold more than the shard currently being built.
pub struct BankWriter<W: Write + Seek> {
    w: W,
    rows: usize,
    cols: usize,
    num_shards: usize,
    per: usize,
    next_shard: usize,
    nnz: u64,
    /// (offset, rows, nnz) per written shard.
    dir: Vec<(u64, u64, u64)>,
    offset: u64,
}

impl<W: Write + Seek> BankWriter<W> {
    /// Start a bank for a `rows × cols` matrix in `num_shards` uniform
    /// row-range shards. Writes a placeholder header immediately.
    pub fn create(mut w: W, rows: usize, cols: usize, num_shards: usize) -> Result<Self> {
        if num_shards == 0 {
            return Err(io::bad("bank needs at least one shard"));
        }
        if cols as u64 > u32::MAX as u64 + 1 || rows as u64 > u32::MAX as u64 {
            return Err(io::bad("matrix dimensions exceed the u32 index space"));
        }
        let mut header = vec![0u8; HEADER_BYTES + num_shards * DIR_ENTRY_BYTES];
        header[..ALXBANK01_MAGIC.len()].copy_from_slice(ALXBANK01_MAGIC);
        // rows/cols are final; nnz and the directory are backpatched.
        header[MAGIC_BYTES..MAGIC_BYTES + 8].copy_from_slice(&(rows as u64).to_le_bytes());
        header[MAGIC_BYTES + 8..MAGIC_BYTES + 16].copy_from_slice(&(cols as u64).to_le_bytes());
        header[MAGIC_BYTES + 24..MAGIC_BYTES + 32]
            .copy_from_slice(&(num_shards as u64).to_le_bytes());
        w.write_all(&header)?;
        Ok(BankWriter {
            w,
            rows,
            cols,
            num_shards,
            per: per_for(rows, num_shards),
            next_shard: 0,
            nnz: 0,
            dir: Vec::with_capacity(num_shards),
            offset: header.len() as u64,
        })
    }

    /// Shards written so far.
    pub fn shards_written(&self) -> usize {
        self.next_shard
    }

    /// Append the next shard (shard-local row ids). Its row count must
    /// match the uniform partition's range for that shard.
    pub fn write_shard(&mut self, shard: &Csr) -> Result<()> {
        if self.next_shard >= self.num_shards {
            return Err(io::bad(format!(
                "bank already holds the declared {} shards",
                self.num_shards
            )));
        }
        let (start, end) = shard_range(self.rows, self.per, self.next_shard);
        if shard.rows != end - start {
            return Err(io::bad(format!(
                "shard {} has {} rows, the uniform partition wants {}",
                self.next_shard,
                shard.rows,
                end - start
            )));
        }
        if shard.cols != self.cols {
            return Err(io::bad(format!(
                "shard {} has {} cols, the bank is {}-wide",
                self.next_shard, shard.cols, self.cols
            )));
        }
        // Failpoint `bank.write_shard`: one hit per shard segment, byte
        // counter advanced by the segment's on-disk size.
        fault::failpoint_bytes("bank.write_shard", segment_bytes(shard.rows, shard.nnz()) as u64)?;
        io::write_u64s(&mut self.w, shard.indptr.iter().map(|&p| p as u64))?;
        io::write_u32s(&mut self.w, &shard.indices)?;
        io::write_f32s(&mut self.w, &shard.values)?;
        let nnz = shard.nnz() as u64;
        self.dir.push((self.offset, shard.rows as u64, nnz));
        self.offset += segment_bytes(shard.rows, shard.nnz()) as u64;
        self.nnz += nnz;
        self.next_shard += 1;
        Ok(())
    }

    /// Verify every shard arrived, backpatch the totals and the
    /// directory, flush, and return the inner writer.
    pub fn finish(mut self) -> Result<W> {
        if self.next_shard != self.num_shards {
            return Err(io::bad(format!(
                "bank got {} of the declared {} shards",
                self.next_shard, self.num_shards
            )));
        }
        fault::failpoint("bank.finish")?;
        self.w.flush()?;
        self.w.seek(SeekFrom::Start(MAGIC_BYTES as u64 + 16))?;
        self.w.write_all(&self.nnz.to_le_bytes())?;
        self.w.seek(SeekFrom::Start(HEADER_BYTES as u64))?;
        let mut dir = Vec::with_capacity(self.dir.len() * DIR_ENTRY_BYTES);
        for &(off, rows, nnz) in &self.dir {
            dir.extend_from_slice(&off.to_le_bytes());
            dir.extend_from_slice(&rows.to_le_bytes());
            dir.extend_from_slice(&nnz.to_le_bytes());
        }
        self.w.write_all(&dir)?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// One directory entry of an opened bank.
#[derive(Clone, Copy, Debug)]
struct Segment {
    offset: usize,
    rows: usize,
    nnz: usize,
}

/// A validated, memory-mapped `ALXBANK01` file. Shards decode into owned
/// [`Csr`]s on demand ([`CsrBank::load_shard`]); the map itself stays
/// page-cache-resident only where touched.
#[derive(Debug)]
pub struct CsrBank {
    map: Mmap,
    pub rows: usize,
    pub cols: usize,
    nnz: u64,
    per: usize,
    dir: Vec<Segment>,
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

impl CsrBank {
    /// Open and fully validate a bank file. Every structural invariant is
    /// checked here (exact file size, canonical segment offsets, `indptr`
    /// monotonicity, column ranges), so later decodes cannot fail.
    pub fn open(path: impl AsRef<Path>) -> Result<CsrBank> {
        fault::failpoint("bank.open")?;
        let path = path.as_ref();
        let f = durable::retry("bank open", || std::fs::File::open(path))
            .map_err(|e| durable::annotate(e, &format!("bank {}", path.display())))?;
        let map = Mmap::map(&f)?;
        Self::from_map(map)
    }

    fn from_map(map: Mmap) -> Result<CsrBank> {
        let b = map.bytes();
        if b.len() < HEADER_BYTES {
            return Err(io::bad("file too short for an ALXBANK01 header"));
        }
        if &b[..ALXBANK01_MAGIC.len()] != ALXBANK01_MAGIC
            || b[ALXBANK01_MAGIC.len()..MAGIC_BYTES].iter().any(|&x| x != 0)
        {
            return Err(io::bad("bad magic (expected ALXBANK01)"));
        }
        let rows64 = u64_at(b, MAGIC_BYTES);
        let cols64 = u64_at(b, MAGIC_BYTES + 8);
        let nnz = u64_at(b, MAGIC_BYTES + 16);
        let shards64 = u64_at(b, MAGIC_BYTES + 24);
        if rows64 > u32::MAX as u64 {
            return Err(io::bad(format!("rows {rows64} exceeds the u32 index space")));
        }
        if cols64 > u32::MAX as u64 + 1 {
            return Err(io::bad(format!("cols {cols64} exceeds the u32 index space")));
        }
        if shards64 == 0 {
            return Err(io::bad("bank declares zero shards"));
        }
        // The directory must fit in the file before it is allocated, so a
        // lying shard count cannot force an oversized allocation.
        let dir_end = HEADER_BYTES as u128 + shards64 as u128 * DIR_ENTRY_BYTES as u128;
        if dir_end > b.len() as u128 {
            return Err(io::bad(format!(
                "directory for {shards64} shards does not fit the {}-byte file",
                b.len()
            )));
        }
        let rows = rows64 as usize;
        let cols = cols64 as usize;
        let num_shards = shards64 as usize;
        let per = per_for(rows, num_shards);

        // Directory: offsets must follow the canonical back-to-back layout
        // and the per-shard rows must match the uniform partition.
        let mut dir = Vec::with_capacity(num_shards);
        let mut expect_off = dir_end;
        let mut total_nnz = 0u64;
        for p in 0..num_shards {
            let e = HEADER_BYTES + p * DIR_ENTRY_BYTES;
            let off = u64_at(b, e);
            let seg_rows = u64_at(b, e + 8);
            let seg_nnz = u64_at(b, e + 16);
            let (start, end) = shard_range(rows, per, p);
            if seg_rows != (end - start) as u64 {
                return Err(io::bad(format!(
                    "shard {p} directory claims {seg_rows} rows, the uniform \
                     partition of {rows} rows over {num_shards} shards wants {}",
                    end - start
                )));
            }
            if off as u128 != expect_off {
                return Err(io::bad(format!(
                    "shard {p} offset {off} breaks the canonical layout (expected {expect_off})"
                )));
            }
            total_nnz = total_nnz
                .checked_add(seg_nnz)
                .ok_or_else(|| io::bad("shard nnz totals overflow"))?;
            // u128 arithmetic: a lying nnz must fail the bound below, not
            // wrap a narrower integer first.
            expect_off += (seg_rows as u128 + 1) * 8 + seg_nnz as u128 * 8;
            if expect_off > b.len() as u128 {
                return Err(io::bad(format!(
                    "shard {p} segment runs past the end of the {}-byte file",
                    b.len()
                )));
            }
            dir.push(Segment {
                offset: off as usize,
                rows: seg_rows as usize,
                nnz: seg_nnz as usize,
            });
        }
        if total_nnz != nnz {
            return Err(io::bad(format!(
                "directory shards hold {total_nnz} entries, header claims {nnz}"
            )));
        }
        if expect_off != b.len() as u128 {
            return Err(io::bad(format!(
                "bank should be {expect_off} bytes, file is {}",
                b.len()
            )));
        }

        // Content validation: indptr monotone and exact, every column in
        // range — the same bar as `Csr::read_from`, paid once at open.
        for (p, seg) in dir.iter().enumerate() {
            let mut prev = 0u64;
            for i in 0..=seg.rows {
                let v = u64_at(b, seg.offset + i * 8);
                if (i == 0 && v != 0) || v < prev || v > seg.nnz as u64 {
                    return Err(io::bad(format!("shard {p}: corrupt indptr at row {i}")));
                }
                prev = v;
            }
            if prev != seg.nnz as u64 {
                return Err(io::bad(format!(
                    "shard {p}: indptr ends at {prev}, directory claims {} entries",
                    seg.nnz
                )));
            }
            let idx_off = seg.offset + (seg.rows + 1) * 8;
            for (i, c) in b[idx_off..idx_off + seg.nnz * 4].chunks_exact(4).enumerate() {
                let c = u32::from_le_bytes(c.try_into().unwrap());
                if c as u64 >= cols as u64 {
                    return Err(io::bad(format!(
                        "shard {p}: column index {c} out of range at entry {i} (cols = {cols})"
                    )));
                }
            }
        }
        Ok(CsrBank { map, rows, cols, nnz, per, dir })
    }

    pub fn num_shards(&self) -> usize {
        self.dir.len()
    }

    pub fn nnz(&self) -> u64 {
        self.nnz
    }

    /// Bytes of the on-disk bank file.
    pub fn file_bytes(&self) -> u64 {
        self.map.len() as u64
    }

    /// Global row range `[start, end)` of shard `p`.
    pub fn shard_range(&self, p: usize) -> (usize, usize) {
        shard_range(self.rows, self.per, p)
    }

    pub(crate) fn per(&self) -> usize {
        self.per
    }

    /// Decode shard `p` into an owned shard-local [`Csr`]. Infallible
    /// after the full validation [`CsrBank::open`] performed — this is
    /// the "shard fault" cost of the demand-paged path.
    pub fn load_shard(&self, p: usize) -> Csr {
        let seg = self.dir[p];
        let b = self.map.bytes();
        let mut indptr = Vec::with_capacity(seg.rows + 1);
        for i in 0..=seg.rows {
            indptr.push(u64_at(b, seg.offset + i * 8) as usize);
        }
        let idx_off = seg.offset + (seg.rows + 1) * 8;
        let indices: Vec<u32> = b[idx_off..idx_off + seg.nnz * 4]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let val_off = idx_off + seg.nnz * 4;
        let values: Vec<f32> = b[val_off..val_off + seg.nnz * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Csr { rows: seg.rows, cols: self.cols, indptr, indices, values }
    }

    /// Raw little-endian bytes of shard `p`'s column-index array — lets
    /// the transpose derivation count entries per column straight off the
    /// map, without decoding indptr/values into owned vectors.
    fn shard_index_bytes(&self, p: usize) -> &[u8] {
        let seg = self.dir[p];
        let idx_off = seg.offset + (seg.rows + 1) * 8;
        &self.map.bytes()[idx_off..idx_off + seg.nnz * 4]
    }

    /// Write this bank's transpose as another bank of `num_pieces`
    /// column-range shards (unbounded scratch: every transpose shard is
    /// built in one scatter scan). See
    /// [`CsrBank::write_transpose_bank_budgeted`].
    pub fn write_transpose_bank(&self, path: impl AsRef<Path>, num_pieces: usize) -> Result<()> {
        self.write_transpose_bank_budgeted(path, num_pieces, 0)
    }

    /// Write this bank's transpose as another bank of `num_pieces`
    /// column-range shards, as a counting pass plus a **single-scan
    /// multi-writer scatter**: transpose shards are built in consecutive
    /// groups whose combined scratch fits `budget_bytes` (0 = unbounded →
    /// all shards in one group), each group filled by one scan of the
    /// mapped source bank with one open segment per shard in the group. A
    /// tight budget degrades toward the old shard-at-a-time derivation —
    /// never below one shard per scan — so peak memory stays O(cols)
    /// counts + one source shard + the budgeted group scratch.
    ///
    /// Entries scatter in ascending global source-row order, so each
    /// transpose row is sorted by source row; the output bytes are
    /// identical for every budget, and identical to spilling
    /// [`super::ShardedCsr::transpose`] of the same matrix.
    pub fn write_transpose_bank_budgeted(
        &self,
        path: impl AsRef<Path>,
        num_pieces: usize,
        budget_bytes: u64,
    ) -> Result<()> {
        let t_rows = self.cols;
        let num_pieces = num_pieces.max(1);
        let t_per = per_for(t_rows, num_pieces);

        // Counting pass: entries per transpose row (= per source column),
        // read straight off the mapped index arrays.
        let mut counts = vec![0u64; t_rows];
        for p in 0..self.num_shards() {
            for c in self.shard_index_bytes(p).chunks_exact(4) {
                counts[u32::from_le_bytes(c.try_into().unwrap()) as usize] += 1;
            }
        }

        // Staged through `{path}.tmp.{pid}` + fsync + rename: a crash or
        // ENOSPC mid-derivation never leaves a half-written bank at the
        // destination path.
        let path = path.as_ref();
        let artifact = format!("transpose bank {}", path.display());
        durable::write_atomic(path, &artifact, |f| {
            self.scatter_transpose(&mut *f, num_pieces, budget_bytes, t_per, &counts)
        })
    }

    /// The counting-pass-fed scatter behind
    /// [`CsrBank::write_transpose_bank_budgeted`], writing into an already
    /// staged writer.
    fn scatter_transpose<W: Write + Seek>(
        &self,
        w: W,
        num_pieces: usize,
        budget_bytes: u64,
        t_per: usize,
        counts: &[u64],
    ) -> Result<()> {
        let t_rows = self.cols;
        let mut w = BankWriter::create(w, t_rows, self.rows, num_pieces)?;
        let mut group_start = 0usize;
        while group_start < num_pieces {
            // Grow the group while its build scratch fits the budget
            // (indptr + indices + values + cursors per shard).
            let mut group_end = group_start;
            let mut scratch = 0u128;
            while group_end < num_pieces {
                let (c0, c1) = shard_range(t_rows, t_per, group_end);
                let nnz: u128 = counts[c0..c1].iter().map(|&c| c as u128).sum();
                let piece_scratch = (c1 - c0 + 1) as u128 * 8 + nnz * 8 + (c1 - c0) as u128 * 8;
                if group_end > group_start
                    && budget_bytes > 0
                    && scratch + piece_scratch > budget_bytes as u128
                {
                    break;
                }
                scratch += piece_scratch;
                group_end += 1;
            }
            let g0 = shard_range(t_rows, t_per, group_start).0;
            let g1 = shard_range(t_rows, t_per, group_end - 1).1;

            // Open one segment per transpose shard in the group: exact
            // local indptr from the counts, exactly-sized payloads.
            let mut pieces: Vec<Csr> = Vec::with_capacity(group_end - group_start);
            for tp in group_start..group_end {
                let (c0, c1) = shard_range(t_rows, t_per, tp);
                let mut indptr = Vec::with_capacity(c1 - c0 + 1);
                indptr.push(0usize);
                let mut total = 0usize;
                for c in c0..c1 {
                    total += counts[c] as usize;
                    indptr.push(total);
                }
                pieces.push(Csr {
                    rows: c1 - c0,
                    cols: self.rows,
                    indptr,
                    indices: vec![0u32; total],
                    values: vec![0.0f32; total],
                });
            }

            // The group's single scatter scan over the source shards.
            let mut cursor = vec![0usize; g1 - g0];
            for p in 0..self.num_shards() {
                let s = self.load_shard(p);
                let base = self.shard_range(p).0;
                for r in 0..s.rows {
                    for (&c, &v) in s.row_indices(r).iter().zip(s.row_values(r)) {
                        let c = c as usize;
                        if c < g0 || c >= g1 {
                            continue;
                        }
                        let tp = (c / t_per).min(num_pieces - 1);
                        let piece = &mut pieces[tp - group_start];
                        let local = c - (tp * t_per).min(t_rows);
                        let off = piece.indptr[local] + cursor[c - g0];
                        piece.indices[off] = (base + r) as u32;
                        piece.values[off] = v;
                        cursor[c - g0] += 1;
                    }
                }
            }
            for piece in &pieces {
                w.write_shard(piece)?;
            }
            group_start = group_end;
        }
        w.finish()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::ShardedCsr;
    use crate::util::Pcg64;

    fn sample(rows: usize, cols: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = rng.range(0, 7);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r, c, (r + 2 * c) as f32 * 0.25));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("alx_bank_{}_{}.alxbank", tag, std::process::id()))
    }

    fn write_bank(m: &Csr, shards: usize, tag: &str) -> std::path::PathBuf {
        let path = tmp(tag);
        let s = ShardedCsr::from_csr(m, shards);
        s.spill_to_bank(&path).unwrap();
        path
    }

    #[test]
    fn bank_roundtrips_every_shard() {
        let m = sample(41, 17, 1);
        for shards in [1usize, 2, 3, 8, 41, 64] {
            let path = write_bank(&m, shards, &format!("rt{shards}"));
            let bank = CsrBank::open(&path).unwrap();
            assert_eq!(bank.rows, m.rows);
            assert_eq!(bank.cols, m.cols);
            assert_eq!(bank.nnz(), m.nnz() as u64);
            assert_eq!(bank.num_shards(), shards);
            let reference = ShardedCsr::from_csr(&m, shards);
            for p in 0..shards {
                assert_eq!(bank.shard_range(p), reference.piece_range(p));
                let loaded = bank.load_shard(p);
                assert_eq!(&loaded, reference.piece(p).as_ref(), "shard {p}/{shards}");
            }
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn transpose_bank_matches_in_memory_transpose() {
        let m = sample(29, 13, 2);
        for shards in [1usize, 2, 5, 13] {
            let path = write_bank(&m, shards, &format!("t{shards}"));
            let tpath = tmp(&format!("tt{shards}"));
            let bank = CsrBank::open(&path).unwrap();
            bank.write_transpose_bank(&tpath, shards).unwrap();
            let tbank = CsrBank::open(&tpath).unwrap();
            let t_ref = ShardedCsr::from_csr(&m, shards).transpose(shards);
            assert_eq!(tbank.rows, t_ref.rows);
            assert_eq!(tbank.nnz(), t_ref.nnz() as u64);
            for p in 0..shards {
                assert_eq!(
                    &tbank.load_shard(p),
                    t_ref.piece(p).as_ref(),
                    "transpose shard {p}/{shards}"
                );
            }
            let _ = std::fs::remove_file(&path);
            let _ = std::fs::remove_file(&tpath);
        }
    }

    #[test]
    fn budgeted_transpose_is_byte_identical_for_every_budget() {
        // The multi-writer scatter must produce exactly the bytes the
        // old shard-at-a-time derivation did — which are exactly the
        // bytes of spilling the in-memory transpose.
        let m = sample(33, 19, 9);
        for shards in [1usize, 3, 7] {
            let path = write_bank(&m, shards, &format!("bt{shards}"));
            let bank = CsrBank::open(&path).unwrap();
            let ref_path = tmp(&format!("btref{shards}"));
            ShardedCsr::from_csr(&m, shards).transpose(shards).spill_to_bank(&ref_path).unwrap();
            let want = std::fs::read(&ref_path).unwrap();
            // budget 0 = unbounded (single scan); 1 byte forces one shard
            // per scan (the old behaviour); the middle sizes hit partial
            // groupings.
            for budget in [0u64, 1, 256, 1024, 1 << 20] {
                let tpath = tmp(&format!("btout{shards}_{budget}"));
                bank.write_transpose_bank_budgeted(&tpath, shards, budget).unwrap();
                let got = std::fs::read(&tpath).unwrap();
                assert_eq!(got, want, "shards={shards} budget={budget}");
                let _ = std::fs::remove_file(&tpath);
            }
            let _ = std::fs::remove_file(&ref_path);
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn writer_rejects_wrong_shard_shapes() {
        let m = sample(10, 6, 3);
        let s = ShardedCsr::from_csr(&m, 2);
        // Too few shards at finish.
        let mut w =
            BankWriter::create(std::io::Cursor::new(Vec::new()), m.rows, m.cols, 2).unwrap();
        w.write_shard(s.piece(0).as_ref()).unwrap();
        assert!(w.finish().is_err());
        // Wrong row count for the partition.
        let mut w =
            BankWriter::create(std::io::Cursor::new(Vec::new()), m.rows, m.cols, 2).unwrap();
        assert!(w.write_shard(s.piece(1).as_ref()).is_err());
        // Too many shards.
        let mut w =
            BankWriter::create(std::io::Cursor::new(Vec::new()), m.rows, m.cols, 1).unwrap();
        w.write_shard(&m).unwrap();
        assert!(w.write_shard(&m).is_err());
    }

    #[test]
    fn empty_matrix_banks() {
        let m = Csr::from_coo(3, 3, &[]);
        let path = write_bank(&m, 2, "empty");
        let bank = CsrBank::open(&path).unwrap();
        assert_eq!(bank.nnz(), 0);
        assert_eq!(bank.load_shard(0).nnz(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_rejects_bad_magic_and_short_files() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOTABANKXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXXX").unwrap();
        assert!(CsrBank::open(&path).is_err());
        std::fs::write(&path, b"ALXBANK01").unwrap();
        assert!(CsrBank::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
