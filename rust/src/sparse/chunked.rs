//! `ALXCSR02` — the chunked, streamable on-disk CSR format.
//!
//! `ALXCSR01` stores the whole matrix as three monolithic arrays, so a
//! reader must materialize all of it before the first shard can exist —
//! which caps dataset size at a multiple of host RAM. `ALXCSR02` instead
//! stores contiguous **row-range chunks**, each self-describing, so a
//! bounded-memory cursor ([`ChunkedReader`]) can hand rows to the
//! shard-as-you-read ingestion pipeline one chunk at a time.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "ALXCSR02"                         8 bytes
//! rows u64 | cols u64 | nnz u64 | num_chunks u64
//! per chunk:
//!   "CH02"                           4 bytes
//!   row_start u64 | row_count u64 | chunk_nnz u64
//!   row_lens  u32 × row_count
//!   indices   u32 × chunk_nnz       (sorted strictly ascending per row)
//!   values    f32 × chunk_nnz
//! ```
//!
//! Chunks cover `[0, rows)` contiguously in order. Every field is
//! validated on read: the header against the exact stream length, chunk
//! headers against the running row/nnz totals, `row_lens` against
//! `chunk_nnz`, and every column index against `cols` — so a corrupt or
//! hostile file fails with `InvalidData` before any allocation larger
//! than one chunk.

use super::csr::{io, Csr};
use crate::util::fault;
use std::io::{BufReader, Read, Result, Write};
use std::path::Path;

/// File magic of the chunked format.
pub const ALXCSR02_MAGIC: &[u8; 8] = b"ALXCSR02";
const CHUNK_MAGIC: &[u8; 4] = b"CH02";
/// Fixed bytes: file header, and per-chunk header.
const HEADER_BYTES: u64 = 8 + 4 * 8;
const CHUNK_HEADER_BYTES: u64 = 4 + 3 * 8;

/// Default rows per chunk for writers (`data.chunk_rows`).
pub const DEFAULT_CHUNK_ROWS: usize = 1 << 16;

/// Validated `ALXCSR02` file header.
#[derive(Clone, Copy, Debug)]
pub struct ChunkedHeader {
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub num_chunks: u64,
}

/// One decoded row-range chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrChunk {
    /// Global id of the first row in this chunk.
    pub row_start: usize,
    /// Chunk-local row pointers, length `row_count + 1`.
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrChunk {
    pub fn row_count(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Row `i` of the chunk as `(global_row_id, indices, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (usize, &[u32], &[f32]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (self.row_start + i, &self.indices[lo..hi], &self.values[lo..hi])
    }
}

/// Bounded-memory cursor over an `ALXCSR02` stream: holds at most one
/// chunk's arrays at a time and enforces an optional ingest budget on the
/// per-chunk allocation.
pub struct ChunkedReader<R: Read> {
    r: R,
    header: ChunkedHeader,
    next_row: usize,
    nnz_seen: u64,
    chunks_seen: u64,
    /// Max bytes one chunk's arrays may need; 0 = unbounded.
    budget_bytes: u64,
    peak_chunk_bytes: u64,
}

impl ChunkedReader<BufReader<std::fs::File>> {
    /// Open a chunked file; the header is validated against the exact
    /// file length before this returns.
    pub fn open(path: impl AsRef<Path>, budget_bytes: u64) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len();
        Self::new(BufReader::new(f), len, budget_bytes)
    }
}

impl<R: Read> ChunkedReader<R> {
    /// Wrap a raw stream of exactly `stream_len` bytes (counting the
    /// magic). Reads and validates the header.
    pub fn new(mut r: R, stream_len: u64, budget_bytes: u64) -> Result<Self> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != ALXCSR02_MAGIC {
            return Err(io::bad("bad magic (expected ALXCSR02)"));
        }
        let mut b8 = [0u8; 8];
        let mut read_u64 = |r: &mut R| -> Result<u64> {
            r.read_exact(&mut b8)?;
            Ok(u64::from_le_bytes(b8))
        };
        let rows64 = read_u64(&mut r)?;
        let cols64 = read_u64(&mut r)?;
        let nnz = read_u64(&mut r)?;
        let num_chunks = read_u64(&mut r)?;
        if cols64 > u32::MAX as u64 + 1 {
            return Err(io::bad(format!("cols {cols64} exceeds the u32 index space")));
        }
        if rows64 > u32::MAX as u64 {
            return Err(io::bad(format!("rows {rows64} exceeds the u32 index space")));
        }
        if (rows64 == 0) != (num_chunks == 0) {
            return Err(io::bad("empty matrix must have zero chunks (and vice versa)"));
        }
        if num_chunks > rows64 {
            return Err(io::bad(format!(
                "{num_chunks} chunks for {rows64} rows (chunks cannot be empty)"
            )));
        }
        // Exact size: header + per-chunk headers + one u32 per row +
        // (u32 + f32) per stored entry.
        let expect = HEADER_BYTES as u128
            + num_chunks as u128 * CHUNK_HEADER_BYTES as u128
            + rows64 as u128 * 4
            + nnz as u128 * 8;
        if expect != stream_len as u128 {
            return Err(io::bad(format!(
                "header claims {rows64} rows / {nnz} nnz / {num_chunks} chunks \
                 ({expect} bytes) but the stream is {stream_len} bytes"
            )));
        }
        let rows = usize::try_from(rows64).map_err(|_| io::bad("rows exceeds usize"))?;
        let cols = usize::try_from(cols64).map_err(|_| io::bad("cols exceeds usize"))?;
        usize::try_from(nnz).map_err(|_| io::bad("nnz exceeds usize"))?;
        Ok(ChunkedReader {
            r,
            header: ChunkedHeader { rows, cols, nnz, num_chunks },
            next_row: 0,
            nnz_seen: 0,
            chunks_seen: 0,
            budget_bytes,
            peak_chunk_bytes: 0,
        })
    }

    pub fn header(&self) -> &ChunkedHeader {
        &self.header
    }

    /// Chunks decoded so far.
    pub fn chunks_read(&self) -> u64 {
        self.chunks_seen
    }

    /// Largest per-chunk array allocation seen so far, in bytes — the
    /// ingestion working set this cursor actually needed.
    pub fn peak_chunk_bytes(&self) -> u64 {
        self.peak_chunk_bytes
    }

    /// Decode the next chunk, or `None` after the last one (at which
    /// point the row and nnz totals are checked against the header).
    pub fn next_chunk(&mut self) -> Result<Option<CsrChunk>> {
        if self.chunks_seen == self.header.num_chunks {
            if self.next_row != self.header.rows {
                return Err(io::bad(format!(
                    "chunks cover {} of {} rows",
                    self.next_row, self.header.rows
                )));
            }
            if self.nnz_seen != self.header.nnz {
                return Err(io::bad(format!(
                    "chunks hold {} of {} stored entries",
                    self.nnz_seen, self.header.nnz
                )));
            }
            return Ok(None);
        }
        let mut magic = [0u8; 4];
        self.r.read_exact(&mut magic)?;
        if &magic != CHUNK_MAGIC {
            return Err(io::bad(format!("bad chunk magic at row {}", self.next_row)));
        }
        let mut b8 = [0u8; 8];
        let mut read_u64 = |r: &mut R| -> Result<u64> {
            r.read_exact(&mut b8)?;
            Ok(u64::from_le_bytes(b8))
        };
        let row_start = read_u64(&mut self.r)?;
        let row_count = read_u64(&mut self.r)?;
        let chunk_nnz = read_u64(&mut self.r)?;
        if row_start != self.next_row as u64 {
            return Err(io::bad(format!(
                "chunk starts at row {row_start}, expected {}",
                self.next_row
            )));
        }
        let in_range = match row_start.checked_add(row_count) {
            Some(end) => row_count > 0 && end <= self.header.rows as u64,
            None => false,
        };
        if !in_range {
            return Err(io::bad(format!(
                "chunk row range [{row_start}, +{row_count}) outside [0, {})",
                self.header.rows
            )));
        }
        if chunk_nnz > self.header.nnz - self.nnz_seen {
            return Err(io::bad(format!(
                "chunk claims {chunk_nnz} entries but only {} remain of the header total",
                self.header.nnz - self.nnz_seen
            )));
        }
        // Both counts are now bounded by the length-validated header, so
        // these allocations are safe; the budget additionally caps them.
        // Decoded working set: `indptr` is usize (8 B per row + 1), plus
        // u32 indices and f32 values per stored entry.
        let chunk_bytes = (row_count + 1) * 8 + chunk_nnz * 8;
        if self.budget_bytes > 0 && chunk_bytes > self.budget_bytes {
            return Err(io::bad(format!(
                "chunk at row {row_start} needs {chunk_bytes} bytes but the ingest \
                 budget is {} — rewrite the file with smaller chunks (alx convert \
                 --chunk-rows) or raise data.ingest_budget_mb",
                self.budget_bytes
            )));
        }
        self.peak_chunk_bytes = self.peak_chunk_bytes.max(chunk_bytes);
        // Failpoint `chunked.read`: one hit per chunk, byte counter
        // advanced by the chunk's on-stream size (for `after:BYTES`).
        fault::failpoint_bytes(
            "chunked.read",
            CHUNK_HEADER_BYTES + row_count * 4 + chunk_nnz * 8,
        )?;
        let row_count = row_count as usize;
        let chunk_nnz = chunk_nnz as usize;

        let mut indptr: Vec<usize> = Vec::with_capacity(row_count + 1);
        indptr.push(0);
        let mut total = 0usize;
        io::read_u32s(&mut self.r, row_count, |len| {
            total += len as usize;
            if total > chunk_nnz {
                return Err(io::bad("row lengths exceed the chunk's nnz"));
            }
            indptr.push(total);
            Ok(())
        })?;
        if total != chunk_nnz {
            return Err(io::bad(format!(
                "row lengths sum to {total}, chunk header claims {chunk_nnz}"
            )));
        }
        let cols = self.header.cols as u64;
        let mut indices: Vec<u32> = Vec::with_capacity(chunk_nnz);
        io::read_u32s(&mut self.r, chunk_nnz, |i| {
            if i as u64 >= cols {
                return Err(io::bad(format!(
                    "column index {i} out of range (cols = {cols})"
                )));
            }
            indices.push(i);
            Ok(())
        })?;
        // Per-row strict ordering — the Csr invariant the trainer assumes.
        for w in indptr.windows(2) {
            let row = &indices[w[0]..w[1]];
            if row.windows(2).any(|p| p[0] >= p[1]) {
                return Err(io::bad("row indices not strictly ascending"));
            }
        }
        let mut values: Vec<f32> = Vec::with_capacity(chunk_nnz);
        io::read_f32s(&mut self.r, chunk_nnz, |v| {
            values.push(v);
            Ok(())
        })?;

        let row_start = self.next_row;
        self.next_row += row_count;
        self.nnz_seen += chunk_nnz as u64;
        self.chunks_seen += 1;
        Ok(Some(CsrChunk { row_start, indptr, indices, values }))
    }

    /// Materialize the whole stream as one [`Csr`] (the non-streaming
    /// compat path used by [`crate::data::EdgeListSource`]).
    pub fn read_all(mut self) -> Result<Csr> {
        let (rows, cols, nnz) = (self.header.rows, self.header.cols, self.header.nnz);
        let nnz = usize::try_from(nnz).map_err(|_| io::bad("nnz exceeds usize"))?;
        let mut indptr: Vec<usize> = Vec::with_capacity(rows + 1);
        indptr.push(0);
        let mut indices: Vec<u32> = Vec::with_capacity(nnz);
        let mut values: Vec<f32> = Vec::with_capacity(nnz);
        while let Some(chunk) = self.next_chunk()? {
            let base = indices.len();
            indptr.extend(chunk.indptr[1..].iter().map(|&p| base + p));
            indices.extend_from_slice(&chunk.indices);
            values.extend_from_slice(&chunk.values);
        }
        Ok(Csr { rows, cols, indptr, indices, values })
    }
}

/// Streaming `ALXCSR02` writer: rows are pushed in order and flushed as
/// row-range chunks of `chunk_rows` rows, so the writer never holds more
/// than one chunk.
pub struct ChunkedWriter<W: Write> {
    w: W,
    rows: usize,
    cols: usize,
    nnz: u64,
    chunk_rows: usize,
    next_row: usize,
    written_nnz: u64,
    chunks_written: u64,
    expected_chunks: u64,
    buf_lens: Vec<u32>,
    buf_indices: Vec<u32>,
    buf_values: Vec<f32>,
}

impl<W: Write> ChunkedWriter<W> {
    /// Start a file for a `rows × cols` matrix holding exactly `nnz`
    /// stored entries (the totals are part of the header and verified at
    /// [`ChunkedWriter::finish`]).
    pub fn new(mut w: W, rows: usize, cols: usize, nnz: u64, chunk_rows: usize) -> Result<Self> {
        if chunk_rows == 0 {
            return Err(io::bad("chunk_rows must be >= 1"));
        }
        if cols as u64 > u32::MAX as u64 + 1 || rows as u64 > u32::MAX as u64 {
            return Err(io::bad("matrix dimensions exceed the u32 index space"));
        }
        let expected_chunks = (rows as u64).div_ceil(chunk_rows as u64);
        w.write_all(ALXCSR02_MAGIC)?;
        for v in [rows as u64, cols as u64, nnz, expected_chunks] {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(ChunkedWriter {
            w,
            rows,
            cols,
            nnz,
            chunk_rows,
            next_row: 0,
            written_nnz: 0,
            chunks_written: 0,
            expected_chunks,
            buf_lens: Vec::with_capacity(chunk_rows),
            buf_indices: Vec::new(),
            buf_values: Vec::new(),
        })
    }

    /// Append the next row (rows must arrive in order, exactly `rows` of
    /// them). Indices must be strictly ascending and `< cols`.
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) -> Result<()> {
        if self.next_row >= self.rows {
            return Err(io::bad(format!("push_row beyond the declared {} rows", self.rows)));
        }
        if indices.len() != values.len() {
            return Err(io::bad("indices/values length mismatch"));
        }
        let mut prev: Option<u32> = None;
        for &c in indices {
            if c as u64 >= self.cols as u64 {
                return Err(io::bad(format!(
                    "column index {c} out of range (cols = {})",
                    self.cols
                )));
            }
            if let Some(p) = prev {
                if c <= p {
                    return Err(io::bad("row indices must be strictly ascending"));
                }
            }
            prev = Some(c);
        }
        self.buf_lens.push(indices.len() as u32);
        self.buf_indices.extend_from_slice(indices);
        self.buf_values.extend_from_slice(values);
        self.next_row += 1;
        if self.buf_lens.len() == self.chunk_rows {
            self.flush_chunk()?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<()> {
        let row_count = self.buf_lens.len();
        if row_count == 0 {
            return Ok(());
        }
        let chunk_nnz = self.buf_indices.len() as u64;
        let row_start = (self.next_row - row_count) as u64;
        // Failpoint `chunked.write`: one hit per chunk flushed.
        fault::failpoint_bytes(
            "chunked.write",
            CHUNK_HEADER_BYTES + row_count as u64 * 4 + chunk_nnz * 8,
        )?;
        self.w.write_all(CHUNK_MAGIC)?;
        for v in [row_start, row_count as u64, chunk_nnz] {
            self.w.write_all(&v.to_le_bytes())?;
        }
        io::write_u32s(&mut self.w, &self.buf_lens)?;
        io::write_u32s(&mut self.w, &self.buf_indices)?;
        io::write_f32s(&mut self.w, &self.buf_values)?;
        self.written_nnz += chunk_nnz;
        self.chunks_written += 1;
        self.buf_lens.clear();
        self.buf_indices.clear();
        self.buf_values.clear();
        Ok(())
    }

    /// Flush the tail chunk and verify the declared totals; returns the
    /// inner writer.
    pub fn finish(mut self) -> Result<W> {
        self.flush_chunk()?;
        if self.next_row != self.rows {
            return Err(io::bad(format!(
                "wrote {} of the declared {} rows",
                self.next_row, self.rows
            )));
        }
        if self.written_nnz != self.nnz {
            return Err(io::bad(format!(
                "wrote {} of the declared {} entries",
                self.written_nnz, self.nnz
            )));
        }
        if self.chunks_written != self.expected_chunks {
            return Err(io::bad(format!(
                "wrote {} chunks, header declared {}",
                self.chunks_written, self.expected_chunks
            )));
        }
        fault::failpoint("chunked.finish")?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Write a whole [`Csr`] in the chunked format.
pub fn write_chunked(m: &Csr, w: impl Write, chunk_rows: usize) -> Result<()> {
    let mut cw = ChunkedWriter::new(w, m.rows, m.cols, m.nnz() as u64, chunk_rows)?;
    for r in 0..m.rows {
        cw.push_row(m.row_indices(r), m.row_values(r))?;
    }
    cw.finish()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn sample(rows: usize, cols: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = rng.range(0, 7); // empty rows included
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, cols) as u32);
            }
            for c in seen {
                t.push((r, c, (r + c) as f32 * 0.5 + 0.25));
            }
        }
        Csr::from_coo(rows, cols, &t)
    }

    fn encode(m: &Csr, chunk_rows: usize) -> Vec<u8> {
        let mut buf = Vec::new();
        write_chunked(m, &mut buf, chunk_rows).unwrap();
        buf
    }

    fn decode(buf: &[u8], budget: u64) -> std::io::Result<Csr> {
        ChunkedReader::new(buf, buf.len() as u64, budget)?.read_all()
    }

    #[test]
    fn roundtrips_across_chunk_sizes() {
        let m = sample(57, 23, 1);
        for chunk_rows in [1usize, 2, 7, 13, 57, 64, 1000] {
            let buf = encode(&m, chunk_rows);
            let m2 = decode(&buf, 0).unwrap();
            assert_eq!(m, m2, "chunk_rows = {chunk_rows}");
        }
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let m = Csr::from_coo(0, 0, &[]);
        let buf = encode(&m, 8);
        let m2 = decode(&buf, 0).unwrap();
        assert_eq!(m2.rows, 0);
        assert_eq!(m2.nnz(), 0);
    }

    #[test]
    fn header_is_validated_against_stream_length() {
        let m = sample(20, 10, 2);
        let mut buf = encode(&m, 8);
        // Inflate the declared nnz: exact-size check must fail.
        let nnz_off = 8 + 16;
        let bad = (m.nnz() as u64 + 1).to_le_bytes();
        buf[nnz_off..nnz_off + 8].copy_from_slice(&bad);
        assert!(decode(&buf, 0).is_err());
    }

    #[test]
    fn truncation_at_any_byte_errors() {
        let m = sample(19, 11, 3);
        let buf = encode(&m, 5);
        for cut in 0..buf.len() {
            assert!(
                ChunkedReader::new(&buf[..cut], cut as u64, 0)
                    .and_then(|r| r.read_all())
                    .is_err(),
                "truncation at byte {cut}/{} accepted",
                buf.len()
            );
        }
    }

    #[test]
    fn budget_bounds_chunk_allocation() {
        let m = sample(64, 16, 4);
        // One big chunk: needs (rows+1)*8 indptr + nnz*8 bytes at once.
        let buf = encode(&m, 1024);
        let need = (64 + 1) * 8 + m.nnz() as u64 * 8;
        assert!(decode(&buf, need).is_ok());
        let err = decode(&buf, need / 2).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        // Small chunks fit the same budget.
        let buf = encode(&m, 4);
        assert!(decode(&buf, need / 2).is_ok());
    }

    #[test]
    fn reader_tracks_peak_chunk_bytes() {
        let m = sample(40, 12, 5);
        let buf = encode(&m, 10);
        let mut r = ChunkedReader::new(&buf[..], buf.len() as u64, 0).unwrap();
        let mut max_seen = 0u64;
        while let Some(c) = r.next_chunk().unwrap() {
            max_seen = max_seen.max(((c.row_count() + 1) * 8 + c.nnz() * 8) as u64);
        }
        assert_eq!(r.peak_chunk_bytes(), max_seen);
        assert!(r.peak_chunk_bytes() < m.memory_bytes());
    }

    #[test]
    fn writer_rejects_inconsistent_totals() {
        // Fewer rows than declared.
        let mut cw = ChunkedWriter::new(Vec::new(), 3, 4, 2, 2).unwrap();
        cw.push_row(&[1, 2], &[1.0, 1.0]).unwrap();
        assert!(cw.finish().is_err());
        // Unsorted row.
        let mut cw = ChunkedWriter::new(Vec::new(), 1, 4, 2, 2).unwrap();
        assert!(cw.push_row(&[2, 1], &[1.0, 1.0]).is_err());
        // Out-of-range column.
        let mut cw = ChunkedWriter::new(Vec::new(), 1, 4, 1, 2).unwrap();
        assert!(cw.push_row(&[9], &[1.0]).is_err());
    }

    #[test]
    fn chunk_rows_iterate_globally() {
        let m = sample(23, 9, 6);
        let buf = encode(&m, 4);
        let mut r = ChunkedReader::new(&buf[..], buf.len() as u64, 0).unwrap();
        let mut next = 0usize;
        while let Some(chunk) = r.next_chunk().unwrap() {
            for i in 0..chunk.row_count() {
                let (g, idx, val) = chunk.row(i);
                assert_eq!(g, next);
                assert_eq!(idx, m.row_indices(g));
                assert_eq!(val, m.row_values(g));
                next += 1;
            }
        }
        assert_eq!(next, m.rows);
    }
}
