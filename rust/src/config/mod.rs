//! Configuration: a TOML-subset file format plus CLI-style overrides.
//!
//! The launcher (`alx` binary) reads an optional config file and applies
//! `--key value` overrides, so every experiment in EXPERIMENTS.md is a
//! config + command line. Supported file syntax: `key = value` lines,
//! `[section]` headers (flattened to `section.key`), `#` comments, quoted
//! or bare strings, ints, floats, booleans.

use crate::als::{EngineKind, PrecisionPolicy, TrainConfig};
use crate::dist::{DistCompute, DistConfig, DistMode};
use crate::linalg::SolverKind;
use crate::serving::ServeConfig;
use crate::webgraph::Variant;
use std::collections::BTreeMap;

/// Flat key-value config store.
#[derive(Clone, Debug, Default)]
pub struct KvConfig {
    values: BTreeMap<String, String>,
}

impl KvConfig {
    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> anyhow::Result<KvConfig> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim().trim_matches('"').to_string();
            values.insert(key, v);
        }
        Ok(KvConfig { values })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<KvConfig> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.parse_as(key)
    }

    pub fn get_f32(&self, key: &str) -> anyhow::Result<Option<f32>> {
        self.parse_as(key)
    }

    pub fn get_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        self.parse_as(key)
    }

    pub fn get_u64(&self, key: &str) -> anyhow::Result<Option<u64>> {
        self.parse_as(key)
    }

    pub fn get_bool(&self, key: &str) -> anyhow::Result<Option<bool>> {
        self.parse_as(key)
    }

    fn parse_as<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("config key '{key}' = '{v}': {e}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Fully resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct AlxConfig {
    /// Dataset variant preset.
    pub variant: Variant,
    /// Scale factor vs. the paper's Table 1 sizes.
    pub scale: f64,
    /// Dataset seed.
    pub data_seed: u64,
    /// Dataset acquisition: "webgraph" (synthetic generator) or
    /// "edge-list" (file loader; see `data.path`).
    pub data_source: String,
    /// File path for file-backed data sources.
    pub data_path: String,
    /// Stream `data.path` (an `ALXCSR02` file) through the out-of-core
    /// ingestion path instead of materializing the full matrix.
    pub data_streaming: bool,
    /// Max bytes (in MiB) one chunk may need during streaming ingestion
    /// (0 = unbounded).
    pub ingest_budget_mb: usize,
    /// Rows per chunk for `ALXCSR02` writers (`alx generate --out`,
    /// `alx convert`).
    pub chunk_rows: usize,
    /// Spill the resident train/transpose shards into `ALXBANK01` banks
    /// and train demand-paged, so steady-state memory is bounded by
    /// `resident_shards` instead of the matrix.
    pub data_spill: bool,
    /// Base directory for the spill banks (empty = the system temp dir);
    /// every session writes into its own unique subdirectory and removes
    /// it on drop.
    pub spill_dir: String,
    /// Decoded shards the residency cache keeps per bank in spill mode
    /// (the train matrix and its transpose each hold this many).
    pub resident_shards: usize,
    /// Spill the embedding tables (W and H) into `ALXTAB01` banks and
    /// train demand-paged, so *model* size — rows × dim × precision —
    /// escapes host RAM too (bitwise identical to resident training).
    pub model_spill: bool,
    /// Base directory for the model banks (empty = the session's spill
    /// scratch dir when matrix spill is on, else the system temp dir);
    /// every session writes into its own unique subdirectory and removes
    /// it on drop.
    pub model_spill_dir: String,
    /// Decoded shards the residency cache keeps per embedding table in
    /// spilled-model mode (W and H each hold this many).
    pub resident_table_shards: usize,
    /// Simulated TPU cores.
    pub cores: usize,
    /// Training hyper-parameters.
    pub train: TrainConfig,
    /// Engine: "native" or "xla".
    pub engine: String,
    /// Artifact directory for the XLA engine.
    pub artifacts_dir: String,
    /// Eval: approximate MIPS instead of exact top-k.
    pub approximate_eval: bool,
    /// Session hook: checkpoint to `checkpoint_path` every k epochs
    /// (0 = off).
    pub checkpoint_every: usize,
    /// Session hook: evaluate Recall@K every k epochs (0 = off).
    pub eval_every: usize,
    /// Session hook: early-stop after this many plateau epochs (0 = off).
    pub early_stop_patience: usize,
    /// Session hook: early-stop on a Recall@K plateau, keyed to this K
    /// (0 = off).
    pub early_stop_recall_k: usize,
    /// Evals without Recall@K improvement before the recall early stop
    /// fires.
    pub early_stop_recall_patience: usize,
    /// Evaluate for the recall early stop every k epochs.
    pub early_stop_recall_every: usize,
    /// Where periodic/final checkpoints are written.
    pub checkpoint_path: String,
    /// Fault-injection spec (`name=trigger[:action];...`), forwarded to
    /// [`crate::util::fault::configure`] at tool startup. Empty = off.
    /// Non-empty specs require a binary built with `--features failpoints`.
    pub fault_points: String,
    /// `alx serve` knobs (`[serve]` section).
    pub serve: ServeConfig,
    /// Distributed-training transport (`[dist]` section): local
    /// in-process collectives (default) or TCP workers.
    pub dist: DistConfig,
}

impl Default for AlxConfig {
    fn default() -> Self {
        AlxConfig {
            variant: Variant::InDense,
            scale: 0.01,
            data_seed: 7,
            data_source: "webgraph".to_string(),
            data_path: String::new(),
            data_streaming: false,
            ingest_budget_mb: 0,
            chunk_rows: crate::sparse::DEFAULT_CHUNK_ROWS,
            data_spill: false,
            spill_dir: String::new(),
            resident_shards: 2,
            model_spill: false,
            model_spill_dir: String::new(),
            resident_table_shards: 2,
            cores: 8,
            train: TrainConfig::default(),
            engine: "native".to_string(),
            artifacts_dir: "artifacts".to_string(),
            approximate_eval: false,
            checkpoint_every: 0,
            eval_every: 0,
            early_stop_patience: 0,
            early_stop_recall_k: 0,
            early_stop_recall_patience: 2,
            early_stop_recall_every: 1,
            checkpoint_path: "alx.ckpt".to_string(),
            fault_points: String::new(),
            serve: ServeConfig::default(),
            dist: DistConfig::default(),
        }
    }
}

impl AlxConfig {
    /// Build from a parsed [`KvConfig`] (missing keys keep defaults).
    pub fn from_kv(kv: &KvConfig) -> anyhow::Result<AlxConfig> {
        let mut cfg = AlxConfig::default();
        if let Some(v) = kv.get("dataset.variant") {
            cfg.variant = Variant::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown variant '{v}'"))?;
        }
        if let Some(v) = kv.get_f64("dataset.scale")? {
            anyhow::ensure!(v > 0.0 && v <= 1.0, "dataset.scale must be in (0,1]");
            cfg.scale = v;
        }
        if let Some(v) = kv.get_u64("dataset.seed")? {
            cfg.data_seed = v;
        }
        if let Some(v) = kv.get("data.source") {
            // Early validation only; data::source_from_config is the single
            // dispatch point and must accept exactly this list.
            anyhow::ensure!(
                matches!(v, "webgraph" | "edge-list"),
                "data.source must be webgraph|edge-list"
            );
            cfg.data_source = v.to_string();
        }
        if let Some(v) = kv.get("data.path") {
            cfg.data_path = v.to_string();
        }
        if let Some(v) = kv.get_bool("data.streaming")? {
            cfg.data_streaming = v;
        }
        if let Some(v) = kv.get_usize("data.ingest_budget_mb")? {
            cfg.ingest_budget_mb = v; // 0 = unbounded
        }
        if let Some(v) = kv.get_usize("data.chunk_rows")? {
            anyhow::ensure!(v >= 1, "data.chunk_rows must be >= 1");
            cfg.chunk_rows = v;
        }
        if let Some(v) = kv.get_bool("data.spill")? {
            cfg.data_spill = v;
        }
        if let Some(v) = kv.get("data.spill_dir") {
            cfg.spill_dir = v.to_string();
        }
        if let Some(v) = kv.get_usize("data.resident_shards")? {
            anyhow::ensure!(v >= 1, "data.resident_shards must be >= 1");
            cfg.resident_shards = v;
        }
        if let Some(v) = kv.get_bool("model.spill")? {
            cfg.model_spill = v;
        }
        if let Some(v) = kv.get("model.spill_dir") {
            cfg.model_spill_dir = v.to_string();
        }
        if let Some(v) = kv.get_usize("model.resident_table_shards")? {
            anyhow::ensure!(v >= 1, "model.resident_table_shards must be >= 1");
            cfg.resident_table_shards = v;
        }
        if let Some(v) = kv.get_usize("topology.cores")? {
            anyhow::ensure!(v >= 1, "topology.cores must be >= 1");
            cfg.cores = v;
        }
        if let Some(v) = kv.get_usize("train.dim")? {
            cfg.train.dim = v;
        }
        if let Some(v) = kv.get_usize("train.epochs")? {
            cfg.train.epochs = v;
        }
        if let Some(v) = kv.get_f32("train.lambda")? {
            cfg.train.lambda = v;
        }
        if let Some(v) = kv.get_f32("train.alpha")? {
            cfg.train.alpha = v;
        }
        if let Some(v) = kv.get("train.solver") {
            cfg.train.solver = SolverKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown solver '{v}'"))?;
        }
        if let Some(v) = kv.get("train.precision") {
            cfg.train.precision = PrecisionPolicy::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown precision '{v}'"))?;
        }
        if let Some(v) = kv.get_usize("train.batch_rows")? {
            cfg.train.batch_rows = v;
        }
        if let Some(v) = kv.get_usize("train.batch_width")? {
            cfg.train.batch_width = v;
        }
        if let Some(v) = kv.get_usize("train.cg_iters")? {
            cfg.train.cg_iters = v;
        }
        if let Some(v) = kv.get_u64("train.seed")? {
            cfg.train.seed = v;
        }
        if let Some(v) = kv.get_bool("train.compute_objective")? {
            cfg.train.compute_objective = v;
        }
        if let Some(v) = kv.get_usize("train.threads")? {
            cfg.train.threads = v; // 0 = auto (ALX_THREADS env, else all cores)
        }
        if let Some(v) = kv.get_usize("train.feed_depth")? {
            anyhow::ensure!(v >= 1, "train.feed_depth must be >= 1");
            cfg.train.feed_depth = v;
        }
        if let Some(v) = kv.get("solver.engine") {
            cfg.train.engine = EngineKind::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown solver.engine '{v}' (valid: qr, ialspp)")
            })?;
        }
        if let Some(v) = kv.get_usize("solver.block_dim")? {
            anyhow::ensure!(v >= 1, "solver.block_dim must be >= 1");
            cfg.train.block_dim = v;
        }
        if cfg.train.engine == EngineKind::IalsPp {
            // Surface bad subspace shapes at config time, not mid-epoch.
            anyhow::ensure!(
                cfg.train.block_dim <= cfg.train.dim
                    && cfg.train.dim % cfg.train.block_dim == 0,
                "solver.block_dim must be a divisor of train.dim in 1..=train.dim \
                 (got block_dim={} dim={})",
                cfg.train.block_dim,
                cfg.train.dim
            );
        }
        if let Some(v) = kv.get("engine.kind") {
            anyhow::ensure!(v == "native" || v == "xla", "engine.kind must be native|xla");
            cfg.engine = v.to_string();
        }
        if let Some(v) = kv.get("engine.artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = kv.get_bool("eval.approximate")? {
            cfg.approximate_eval = v;
        }
        if let Some(v) = kv.get_usize("session.checkpoint_every")? {
            cfg.checkpoint_every = v; // 0 = off
        }
        if let Some(v) = kv.get_usize("session.eval_every")? {
            cfg.eval_every = v; // 0 = off
        }
        if let Some(v) = kv.get_usize("session.early_stop_patience")? {
            cfg.early_stop_patience = v; // 0 = off
        }
        if let Some(v) = kv.get_usize("session.early_stop_recall_k")? {
            cfg.early_stop_recall_k = v; // 0 = off
        }
        if let Some(v) = kv.get_usize("session.early_stop_recall_patience")? {
            anyhow::ensure!(v >= 1, "session.early_stop_recall_patience must be >= 1");
            cfg.early_stop_recall_patience = v;
        }
        if let Some(v) = kv.get_usize("session.early_stop_recall_every")? {
            anyhow::ensure!(v >= 1, "session.early_stop_recall_every must be >= 1");
            cfg.early_stop_recall_every = v;
        }
        if let Some(v) = kv.get("session.checkpoint_path") {
            anyhow::ensure!(!v.is_empty(), "session.checkpoint_path must be non-empty");
            cfg.checkpoint_path = v.to_string();
        }
        if let Some(v) = kv.get("fault.points") {
            cfg.fault_points = v.to_string();
        }
        if let Some(v) = kv.get_u64("serve.port")? {
            anyhow::ensure!(v <= u64::from(u16::MAX), "serve.port must fit in u16");
            cfg.serve.port = v as u16;
        }
        if let Some(v) = kv.get_usize("serve.threads")? {
            cfg.serve.threads = v; // 0 = auto (ALX_THREADS env, else all cores)
        }
        if let Some(v) = kv.get_u64("serve.batch_window_us")? {
            cfg.serve.batch_window_us = v; // 0 = flush immediately
        }
        if let Some(v) = kv.get_usize("serve.batch_max")? {
            anyhow::ensure!(v >= 1, "serve.batch_max must be >= 1");
            cfg.serve.batch_max = v;
        }
        if let Some(v) = kv.get_usize("serve.queue_depth")? {
            anyhow::ensure!(v >= 1, "serve.queue_depth must be >= 1");
            cfg.serve.queue_depth = v;
        }
        if let Some(v) = kv.get_usize("serve.cache_entries")? {
            cfg.serve.cache_entries = v; // 0 = cache off
        }
        if let Some(v) = kv.get_u64("serve.cache_ttl_ms")? {
            cfg.serve.cache_ttl_ms = v; // 0 = no expiry
        }
        if let Some(v) = kv.get_usize("serve.mips_clusters")? {
            cfg.serve.mips_clusters = v; // 0 = sqrt(n) heuristic
        }
        if let Some(v) = kv.get_usize("serve.mips_probes")? {
            cfg.serve.mips_probes = v; // 0 = index default
        }
        if let Some(v) = kv.get_u64("serve.seed")? {
            cfg.serve.seed = v;
        }
        if let Some(v) = kv.get("dist.mode") {
            cfg.dist.mode = DistMode::parse(v)
                .ok_or_else(|| anyhow::anyhow!("dist.mode must be local|tcp, got '{v}'"))?;
        }
        if let Some(v) = kv.get("dist.topology") {
            anyhow::ensure!(
                matches!(v, "parameter-server" | "all-reduce"),
                "dist.topology must be parameter-server|all-reduce"
            );
            cfg.dist.topology = v.to_string();
        }
        if let Some(v) = kv.get("dist.workers") {
            // Comma-separated `host:port` list, in worker-index order.
            cfg.dist.workers =
                v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
        }
        if let Some(v) = kv.get_u64("dist.heartbeat_ms")? {
            cfg.dist.heartbeat_ms = v; // 0 = heartbeats off
        }
        if let Some(v) = kv.get("dist.compute") {
            cfg.dist.compute = DistCompute::parse(v).ok_or_else(|| {
                anyhow::anyhow!("dist.compute must be coordinator|worker, got '{v}'")
            })?;
        }
        if cfg.dist.mode == DistMode::Tcp {
            // Surface bad topologies at config time, not at connect time.
            cfg.dist.resolve_topology()?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[dataset]
variant = "in-dense"
scale = 0.005

[train]
dim = 32
lambda = 0.001
solver = "cg"
precision = "mixed"

[topology]
cores = 16
"#;

    #[test]
    fn parse_sections_and_types() {
        let kv = KvConfig::parse(SAMPLE).unwrap();
        assert_eq!(kv.get("dataset.variant"), Some("in-dense"));
        assert_eq!(kv.get_usize("train.dim").unwrap(), Some(32));
        assert_eq!(kv.get_f32("train.lambda").unwrap(), Some(0.001));
        assert_eq!(kv.get("missing.key"), None);
    }

    #[test]
    fn alx_config_from_kv() {
        let kv = KvConfig::parse(SAMPLE).unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.variant, Variant::InDense);
        assert_eq!(cfg.scale, 0.005);
        assert_eq!(cfg.cores, 16);
        assert_eq!(cfg.train.dim, 32);
        assert_eq!(cfg.train.solver, SolverKind::Cg);
        assert_eq!(cfg.train.precision, PrecisionPolicy::Mixed);
    }

    #[test]
    fn pipeline_knobs_parse() {
        let mut kv = KvConfig::default();
        kv.set("train.threads", "3");
        kv.set("train.feed_depth", "2");
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.train.threads, 3);
        assert_eq!(cfg.train.feed_depth, 2);
        let mut bad = KvConfig::default();
        bad.set("train.feed_depth", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
    }

    #[test]
    fn data_and_session_sections_parse() {
        let kv = KvConfig::parse(
            r#"
[data]
source = "edge-list"
path = "edges.txt"
streaming = true
ingest_budget_mb = 64
chunk_rows = 4096
spill = true
spill_dir = "/tmp/banks"
resident_shards = 3

[model]
spill = true
spill_dir = "/tmp/tabs"
resident_table_shards = 4

[session]
checkpoint_every = 2
eval_every = 4
early_stop_patience = 3
early_stop_recall_k = 20
early_stop_recall_patience = 4
early_stop_recall_every = 2
checkpoint_path = "run.ckpt"
"#,
        )
        .unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.data_source, "edge-list");
        assert_eq!(cfg.data_path, "edges.txt");
        assert!(cfg.data_streaming);
        assert_eq!(cfg.ingest_budget_mb, 64);
        assert_eq!(cfg.chunk_rows, 4096);
        assert!(cfg.data_spill);
        assert_eq!(cfg.spill_dir, "/tmp/banks");
        assert_eq!(cfg.resident_shards, 3);
        assert!(cfg.model_spill);
        assert_eq!(cfg.model_spill_dir, "/tmp/tabs");
        assert_eq!(cfg.resident_table_shards, 4);
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.eval_every, 4);
        assert_eq!(cfg.early_stop_patience, 3);
        assert_eq!(cfg.early_stop_recall_k, 20);
        assert_eq!(cfg.early_stop_recall_patience, 4);
        assert_eq!(cfg.early_stop_recall_every, 2);
        assert_eq!(cfg.checkpoint_path, "run.ckpt");
    }

    #[test]
    fn session_defaults_are_off() {
        let cfg = AlxConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(cfg.data_source, "webgraph");
        assert_eq!(cfg.checkpoint_every, 0);
        assert_eq!(cfg.eval_every, 0);
        assert_eq!(cfg.early_stop_patience, 0);
        assert!(!cfg.data_streaming);
        assert_eq!(cfg.ingest_budget_mb, 0);
        assert_eq!(cfg.chunk_rows, crate::sparse::DEFAULT_CHUNK_ROWS);
        assert!(!cfg.data_spill);
        assert!(cfg.spill_dir.is_empty());
        assert_eq!(cfg.resident_shards, 2);
        assert!(!cfg.model_spill);
        assert!(cfg.model_spill_dir.is_empty());
        assert_eq!(cfg.resident_table_shards, 2);
        assert_eq!(cfg.early_stop_recall_k, 0);
        let mut bad = KvConfig::default();
        bad.set("data.chunk_rows", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("data.resident_shards", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("model.resident_table_shards", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("session.early_stop_recall_every", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
    }

    #[test]
    fn fault_points_parse() {
        let kv = KvConfig::parse("[fault]\npoints = \"ckpt.write=once\"\n").unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.fault_points, "ckpt.write=once");
        assert!(AlxConfig::from_kv(&KvConfig::default()).unwrap().fault_points.is_empty());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let kv = KvConfig::parse(
            r#"
[serve]
port = 7878
threads = 4
batch_window_us = 200
batch_max = 32
queue_depth = 256
cache_entries = 1024
cache_ttl_ms = 5000
mips_clusters = 64
mips_probes = 8
seed = 42
"#,
        )
        .unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.serve.port, 7878);
        assert_eq!(cfg.serve.threads, 4);
        assert_eq!(cfg.serve.batch_window_us, 200);
        assert_eq!(cfg.serve.batch_max, 32);
        assert_eq!(cfg.serve.queue_depth, 256);
        assert_eq!(cfg.serve.cache_entries, 1024);
        assert_eq!(cfg.serve.cache_ttl_ms, 5000);
        assert_eq!(cfg.serve.mips_clusters, 64);
        assert_eq!(cfg.serve.mips_probes, 8);
        assert_eq!(cfg.serve.seed, 42);

        let defaults = AlxConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(defaults.serve, ServeConfig::default());

        let mut bad = KvConfig::default();
        bad.set("serve.port", "70000");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("serve.batch_max", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("serve.queue_depth", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());
    }

    #[test]
    fn dist_section_parses_and_validates() {
        let kv = KvConfig::parse(
            r#"
[dist]
mode = "tcp"
topology = "all-reduce"
workers = "127.0.0.1:7001, 127.0.0.1:7002"
heartbeat_ms = 250
compute = "worker"
"#,
        )
        .unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.dist.mode, DistMode::Tcp);
        assert_eq!(cfg.dist.topology, "all-reduce");
        assert_eq!(cfg.dist.workers, vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!(cfg.dist.heartbeat_ms, 250);
        assert_eq!(cfg.dist.compute, DistCompute::Worker);

        let defaults = AlxConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(defaults.dist, DistConfig::default());

        let mut bad = KvConfig::default();
        bad.set("dist.mode", "rdma");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("dist.topology", "ring");
        assert!(AlxConfig::from_kv(&bad).is_err());
        // tcp mode with no workers is a config-time error.
        let mut bad = KvConfig::default();
        bad.set("dist.mode", "tcp");
        assert!(AlxConfig::from_kv(&bad).is_err());
        let mut bad = KvConfig::default();
        bad.set("dist.compute", "gpu");
        assert!(AlxConfig::from_kv(&bad).is_err());
    }

    #[test]
    fn solver_section_parses_and_validates() {
        let kv = KvConfig::parse(
            r#"
[train]
dim = 64

[solver]
engine = "ialspp"
block_dim = 16
"#,
        )
        .unwrap();
        let cfg = AlxConfig::from_kv(&kv).unwrap();
        assert_eq!(cfg.train.engine, EngineKind::IalsPp);
        assert_eq!(cfg.train.block_dim, 16);

        let defaults = AlxConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(defaults.train.engine, EngineKind::Qr);
        assert_eq!(defaults.train.block_dim, TrainConfig::default().block_dim);

        // Unknown engine names fail fast and name the valid options.
        let mut bad = KvConfig::default();
        bad.set("solver.engine", "sgd");
        let err = AlxConfig::from_kv(&bad).unwrap_err().to_string();
        assert!(err.contains("valid: qr, ialspp"), "{err}");

        // block_dim = 0 is rejected regardless of engine.
        let mut bad = KvConfig::default();
        bad.set("solver.block_dim", "0");
        assert!(AlxConfig::from_kv(&bad).is_err());

        // Under ialspp the block must divide the embedding dimension...
        let mut bad = KvConfig::default();
        bad.set("train.dim", "64");
        bad.set("solver.engine", "ialspp");
        bad.set("solver.block_dim", "24");
        assert!(AlxConfig::from_kv(&bad).is_err());
        // ...and cannot exceed it.
        let mut bad = KvConfig::default();
        bad.set("train.dim", "16");
        bad.set("solver.engine", "ialspp");
        bad.set("solver.block_dim", "32");
        assert!(AlxConfig::from_kv(&bad).is_err());
        // The same shapes are fine under the default direct engine.
        let mut ok = KvConfig::default();
        ok.set("train.dim", "64");
        ok.set("solver.block_dim", "24");
        assert!(AlxConfig::from_kv(&ok).is_ok());
    }

    #[test]
    fn bad_data_source_rejected() {
        let mut kv = KvConfig::default();
        kv.set("data.source", "parquet");
        assert!(AlxConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn defaults_survive_empty_config() {
        let cfg = AlxConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(cfg.train.dim, TrainConfig::default().dim);
        assert_eq!(cfg.engine, "native");
    }

    #[test]
    fn bad_values_rejected() {
        let mut kv = KvConfig::default();
        kv.set("train.solver", "gaussian");
        assert!(AlxConfig::from_kv(&kv).is_err());
        let mut kv = KvConfig::default();
        kv.set("dataset.scale", "2.0");
        assert!(AlxConfig::from_kv(&kv).is_err());
        let mut kv = KvConfig::default();
        kv.set("train.dim", "not-a-number");
        assert!(AlxConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let kv = KvConfig::parse("# only comments\n\n  \n").unwrap();
        assert_eq!(kv.keys().count(), 0);
    }

    #[test]
    fn malformed_line_errors() {
        assert!(KvConfig::parse("no equals sign here").is_err());
    }
}
