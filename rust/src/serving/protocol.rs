//! Wire protocol of `alx serve`: length-prefixed little-endian frames
//! over TCP.
//!
//! Every message — request or response — is one frame:
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]          len ≤ 1 MiB
//! ```
//!
//! Request payloads start with a one-byte opcode:
//!
//! ```text
//! TOPK (1):      user u64 · k u32 · probes u32 · deadline_us u32
//!                · n_exclude u32 · n_exclude × item u32
//! PING (2):      (empty)
//! SHUTDOWN (3):  (empty — asks the server to drain and exit)
//! ```
//!
//! Response payloads start with a one-byte status:
//!
//! ```text
//! OK (0):   TOPK → n u32 · n × (item u32 · score f32-bits u32)
//!           PING/SHUTDOWN → (empty)
//! ERR (1):  msg_len u32 · msg_len bytes of UTF-8
//! ```
//!
//! Scores travel as raw f32 bit patterns, so a response is comparable
//! bitwise against the exact scorer — the serving equivalence contract is
//! checked on the wire, not on some lossy formatted view. A frame that
//! fails to decode is answered with `ERR` and the connection is closed;
//! the server itself stays up.

use crate::util::net::{read_frame_capped, write_frame_capped, Cursor};
use std::io::{self, Read, Write};
use std::net::TcpStream;

/// Hard cap on a frame's payload size. Large enough for a Top-K response
/// at any sane `k` and an exclusion list of ~130k items; small enough
/// that a hostile length prefix cannot drive a large allocation.
pub const MAX_FRAME: u32 = 1 << 20;

/// Request opcodes.
pub const OP_TOPK: u8 = 1;
pub const OP_PING: u8 = 2;
pub const OP_SHUTDOWN: u8 = 3;

/// Response status bytes.
pub const STATUS_OK: u8 = 0;
pub const STATUS_ERR: u8 = 1;

/// One Top-K query.
#[derive(Clone, Debug, PartialEq)]
pub struct TopKRequest {
    /// Row into the user table `W`.
    pub user: u64,
    /// How many items to return.
    pub k: u32,
    /// Clusters to probe (0 → the server's configured default).
    pub probes: u32,
    /// Give up if not scored within this budget (0 → no deadline).
    pub deadline_us: u32,
    /// Item ids to exclude (the user's history; any order — the server
    /// sorts).
    pub exclude: Vec<u32>,
}

/// A decoded request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    TopK(TopKRequest),
    Ping,
    Shutdown,
}

/// A decoded response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Ranked `(item, score)` pairs, best first.
    TopK(Vec<(u32, f32)>),
    /// PING / SHUTDOWN acknowledged.
    Ok,
    Err(String),
}

/// Read one frame's payload under the serving cap (see
/// [`crate::util::net`] for the shared framing layer). `Ok(None)` on a
/// clean EOF at a frame boundary (peer closed); an EOF mid-frame is an
/// error.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    read_frame_capped(r, MAX_FRAME)
}

/// Write one frame under the serving cap.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    write_frame_capped(w, payload, MAX_FRAME)
}

/// Encode a request into a frame payload.
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Ping => vec![OP_PING],
        Request::Shutdown => vec![OP_SHUTDOWN],
        Request::TopK(q) => {
            let mut out = Vec::with_capacity(25 + 4 * q.exclude.len());
            out.push(OP_TOPK);
            out.extend_from_slice(&q.user.to_le_bytes());
            out.extend_from_slice(&q.k.to_le_bytes());
            out.extend_from_slice(&q.probes.to_le_bytes());
            out.extend_from_slice(&q.deadline_us.to_le_bytes());
            out.extend_from_slice(&(q.exclude.len() as u32).to_le_bytes());
            for &id in &q.exclude {
                out.extend_from_slice(&id.to_le_bytes());
            }
            out
        }
    }
}

/// Decode a request payload. Errors are protocol violations: the server
/// answers them with `ERR` and closes the connection.
pub fn decode_request(buf: &[u8]) -> Result<Request, String> {
    let mut c = Cursor { buf, pos: 0 };
    let op = c.u8()?;
    match op {
        OP_PING => {
            c.done()?;
            Ok(Request::Ping)
        }
        OP_SHUTDOWN => {
            c.done()?;
            Ok(Request::Shutdown)
        }
        OP_TOPK => {
            let user = c.u64()?;
            let k = c.u32()?;
            let probes = c.u32()?;
            let deadline_us = c.u32()?;
            let n = c.u32()? as usize;
            // The length prefix already bounds the payload, but check the
            // claimed count against the remaining bytes before allocating.
            if c.buf.len() - c.pos != n * 4 {
                return Err(format!(
                    "exclusion count {n} disagrees with {} remaining payload bytes",
                    c.buf.len() - c.pos
                ));
            }
            let mut exclude = Vec::with_capacity(n);
            for _ in 0..n {
                exclude.push(c.u32()?);
            }
            Ok(Request::TopK(TopKRequest { user, k, probes, deadline_us, exclude }))
        }
        other => Err(format!("unknown opcode {other}")),
    }
}

/// Encode a response into a frame payload.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::Ok => vec![STATUS_OK],
        Response::TopK(items) => {
            let mut out = Vec::with_capacity(5 + 8 * items.len());
            out.push(STATUS_OK);
            out.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for &(id, score) in items {
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&score.to_bits().to_le_bytes());
            }
            out
        }
        Response::Err(msg) => {
            let bytes = msg.as_bytes();
            let mut out = Vec::with_capacity(5 + bytes.len());
            out.push(STATUS_ERR);
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(bytes);
            out
        }
    }
}

/// Decode a response payload. `with_items` distinguishes a Top-K reply
/// (carries a result list) from a bare acknowledgement.
pub fn decode_response(buf: &[u8], with_items: bool) -> Result<Response, String> {
    let mut c = Cursor { buf, pos: 0 };
    match c.u8()? {
        STATUS_OK if with_items => {
            let n = c.u32()? as usize;
            if c.buf.len() - c.pos != n * 8 {
                return Err(format!(
                    "result count {n} disagrees with {} remaining payload bytes",
                    c.buf.len() - c.pos
                ));
            }
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let id = c.u32()?;
                let score = f32::from_bits(c.u32()?);
                items.push((id, score));
            }
            Ok(Response::TopK(items))
        }
        STATUS_OK => {
            c.done()?;
            Ok(Response::Ok)
        }
        STATUS_ERR => {
            let n = c.u32()? as usize;
            let bytes = c.take(n)?;
            c.done()?;
            Ok(Response::Err(String::from_utf8_lossy(bytes).into_owned()))
        }
        other => Err(format!("unknown status {other}")),
    }
}

/// Minimal blocking client (the `alx query` CLI, tests, and the latency
/// bench all speak through this).
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> io::Result<Client> {
        Ok(Client { stream: TcpStream::connect(addr)? })
    }

    fn roundtrip(&mut self, req: &Request, with_items: bool) -> io::Result<Response> {
        write_frame(&mut self.stream, &encode_request(req))?;
        let payload = read_frame(&mut self.stream)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "server closed before replying")
        })?;
        decode_response(&payload, with_items)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }

    /// Ranked `(item, score)` pairs for `user`, or the server's error.
    pub fn topk(&mut self, req: &TopKRequest) -> io::Result<Response> {
        self.roundtrip(&Request::TopK(req.clone()), true)
    }

    pub fn ping(&mut self) -> io::Result<Response> {
        self.roundtrip(&Request::Ping, false)
    }

    /// Ask the server to drain in-flight requests and exit.
    pub fn shutdown(&mut self) -> io::Result<Response> {
        self.roundtrip(&Request::Shutdown, false)
    }

    /// Send raw bytes as a frame payload (malformed-input testing) and
    /// read back whatever the server answers.
    pub fn send_raw(&mut self, payload: &[u8]) -> io::Result<Option<Response>> {
        write_frame(&mut self.stream, payload)?;
        match read_frame(&mut self.stream)? {
            Some(p) => decode_response(&p, false)
                .map(Some)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Ping,
            Request::Shutdown,
            Request::TopK(TopKRequest {
                user: 123456789,
                k: 10,
                probes: 4,
                deadline_us: 2500,
                exclude: vec![1, 5, 9],
            }),
            Request::TopK(TopKRequest {
                user: 0,
                k: 0,
                probes: 0,
                deadline_us: 0,
                exclude: vec![],
            }),
        ];
        for req in &reqs {
            let enc = encode_request(req);
            assert_eq!(&decode_request(&enc).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn response_roundtrip_preserves_score_bits() {
        let resp = Response::TopK(vec![(7, 1.25), (3, -0.0), (9, f32::MIN_POSITIVE)]);
        let enc = encode_response(&resp);
        let dec = decode_response(&enc, true).unwrap();
        let (Response::TopK(a), Response::TopK(b)) = (&resp, &dec) else {
            panic!("wrong variant");
        };
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
    }

    #[test]
    fn err_response_roundtrip() {
        let enc = encode_response(&Response::Err("bad frame".into()));
        assert_eq!(decode_response(&enc, false).unwrap(), Response::Err("bad frame".into()));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[99]).is_err(), "unknown opcode");
        assert!(decode_request(&[OP_PING, 0]).is_err(), "trailing bytes");
        // TOPK with a lying exclusion count.
        let mut buf = encode_request(&Request::TopK(TopKRequest {
            user: 1,
            k: 5,
            probes: 1,
            deadline_us: 0,
            exclude: vec![2, 3],
        }));
        let n_off = 1 + 8 + 4 + 4 + 4;
        buf[n_off..n_off + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_request(&buf).is_err());
        // Truncated TOPK header.
        assert!(decode_request(&buf[..9]).is_err());
    }

    #[test]
    fn frames_roundtrip_and_cap_is_enforced() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello").unwrap();
        write_frame(&mut wire, b"").unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");

        // A hostile length prefix is rejected without allocating.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());
        // EOF mid-frame is an error, not a silent None.
        let truncated = [5u8, 0, 0, 0, b'x'];
        assert!(read_frame(&mut &truncated[..]).is_err());
    }
}
