//! The `alx serve` request loop: listener, per-connection threads,
//! scoring workers, graceful shutdown.
//!
//! Thread layout (all plain `std::thread`, no new deps):
//!
//! ```text
//! accept thread ──spawns──► connection threads (one per client)
//!                               │  decode frame → cache lookup
//!                               │  miss: submit to the Batcher, block on
//!                               ▼        a reply channel (with timeout)
//!                           Batcher (bounded queue, batch window)
//!                               │
//!                           scoring workers (cfg.threads)
//!                               │  one shard-grouped search_batch pass
//!                               ▼
//!                           reply channels → connection threads → wire
//! ```
//!
//! Failure behavior: a malformed frame is answered with `ERR` and closes
//! that connection only. A scoring worker that dies (e.g. an injected
//! `serve.index` panic) drops its reply senders, so waiting connections
//! get an `ERR` instead of hanging, and every table lock recovers from
//! poisoning ([`lock_or_recover`]) — the server is never wedged by one
//! bad request or one dead thread. Shutdown (a `SHUTDOWN` frame or
//! [`ServerHandle::stop`]) drains queued requests before workers exit.
//!
//! Failpoints `serve.accept`, `serve.read` and `serve.index` are threaded
//! through the three stages for crash-torture-style testing.

use super::batcher::{Batcher, Pending};
use super::cache::{CacheKey, ResultCache};
use super::protocol::{
    decode_request, encode_response, read_frame, write_frame, Request, Response, TopKRequest,
};
use super::{ServeConfig, ServeModel};
use crate::util::fault;
use crate::util::threads::{lock_or_recover, resolve_workers, stall_timeout_ms};
use crate::{log_info, log_warn};
use std::io;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Monotonic serving counters (lock-free; read via
/// [`ServerHandle::stats`]).
#[derive(Debug, Default)]
struct ServeStats {
    requests: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    largest_batch: AtomicU64,
    deadline_expired: AtomicU64,
    rejected: AtomicU64,
    malformed: AtomicU64,
    connections: AtomicU64,
}

/// A point-in-time copy of the serving counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStatsSnapshot {
    /// Top-K requests received (hit + miss).
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Scoring passes executed.
    pub batches: u64,
    /// Requests scored across all batches.
    pub batched_requests: u64,
    /// Largest single scoring batch.
    pub largest_batch: u64,
    /// Requests dropped for missing their deadline.
    pub deadline_expired: u64,
    /// Requests rejected because the queue was full or shutting down.
    pub rejected: u64,
    /// Frames that failed to decode.
    pub malformed: u64,
    /// Connections accepted.
    pub connections: u64,
}

/// State shared by every server thread.
struct Shared {
    model: Arc<ServeModel>,
    cfg: ServeConfig,
    batcher: Batcher,
    cache: ResultCache,
    shutdown: AtomicBool,
    stats: ServeStats,
    port: u16,
}

impl Shared {
    /// Flip into shutdown exactly once: reject new work, flush the
    /// batcher, and self-connect to unblock the accept loop.
    fn initiate_shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.batcher.shutdown();
        // The accept thread blocks in `accept()`; a throwaway connection
        // wakes it so it can observe the flag and exit.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
    }
}

/// Handle to a running server. Dropping it stops the server (graceful:
/// queued requests drain first).
pub struct ServerHandle {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServerHandle {
    /// The bound TCP port (useful with `port = 0`).
    pub fn port(&self) -> u16 {
        self.shared.port
    }

    /// `host:port` string clients can connect to.
    pub fn addr(&self) -> String {
        format!("127.0.0.1:{}", self.shared.port)
    }

    /// Current counters.
    pub fn stats(&self) -> ServeStatsSnapshot {
        let s = &self.shared.stats;
        ServeStatsSnapshot {
            requests: s.requests.load(Ordering::Relaxed),
            cache_hits: s.cache_hits.load(Ordering::Relaxed),
            cache_misses: s.cache_misses.load(Ordering::Relaxed),
            batches: s.batches.load(Ordering::Relaxed),
            batched_requests: s.batched_requests.load(Ordering::Relaxed),
            largest_batch: s.largest_batch.load(Ordering::Relaxed),
            deadline_expired: s.deadline_expired.load(Ordering::Relaxed),
            rejected: s.rejected.load(Ordering::Relaxed),
            malformed: s.malformed.load(Ordering::Relaxed),
            connections: s.connections.load(Ordering::Relaxed),
        }
    }

    /// Initiate shutdown and join every thread (idempotent).
    pub fn stop(&mut self) {
        self.shared.initiate_shutdown();
        self.join_all();
    }

    /// Block until the server shuts down (via a client `SHUTDOWN` frame
    /// or [`ServerHandle::stop`] from another handle) and join every
    /// thread.
    pub fn wait(&mut self) {
        self.join_all();
    }

    fn join_all(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Connection threads observe the flag within their read timeout.
        let handles: Vec<JoinHandle<()>> = lock_or_recover(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start serving `model` per `cfg` on `127.0.0.1:{cfg.port}` (port 0 =
/// OS-assigned; read it back from [`ServerHandle::port`]). Returns once
/// the listener is bound and all workers are up — queries can be sent
/// immediately.
pub fn serve(model: Arc<ServeModel>, cfg: &ServeConfig) -> io::Result<ServerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
    let port = listener.local_addr()?.port();
    let shared = Arc::new(Shared {
        model,
        cfg: cfg.clone(),
        batcher: Batcher::new(cfg.batch_window_us, cfg.batch_max, cfg.queue_depth),
        cache: ResultCache::new(cfg.cache_entries, cfg.cache_ttl_ms),
        shutdown: AtomicBool::new(false),
        stats: ServeStats::default(),
        port,
    });

    let workers: Vec<JoinHandle<()>> = (0..resolve_workers(cfg.threads))
        .map(|_| {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || worker_loop(&sh))
        })
        .collect();

    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let accept = {
        let sh = Arc::clone(&shared);
        let conns = Arc::clone(&conns);
        std::thread::spawn(move || accept_loop(&sh, &listener, &conns))
    };

    log_info!(
        "serving on 127.0.0.1:{port} ({} workers, window {}us, batch_max {}, cache {})",
        resolve_workers(cfg.threads),
        cfg.batch_window_us,
        cfg.batch_max,
        cfg.cache_entries,
    );
    Ok(ServerHandle { shared, accept: Some(accept), workers, conns })
}

fn accept_loop(sh: &Arc<Shared>, listener: &TcpListener, conns: &Arc<Mutex<Vec<JoinHandle<()>>>>) {
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Err(e) = fault::failpoint("serve.accept") {
            log_warn!("accept failpoint: {e}");
            continue;
        }
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) => {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                log_warn!("accept failed: {e}");
                continue;
            }
        };
        if sh.shutdown.load(Ordering::SeqCst) {
            // The wake-up connection itself, or a straggler.
            return;
        }
        sh.stats.connections.fetch_add(1, Ordering::Relaxed);
        let sh2 = Arc::clone(sh);
        let handle = std::thread::spawn(move || handle_conn(&sh2, stream));
        lock_or_recover(conns).push(handle);
    }
}

/// Per-connection loop: poll for a frame (checking the shutdown flag
/// between timeouts), decode, answer. Returns (closing the connection)
/// on EOF, malformed input, IO errors, or shutdown.
fn handle_conn(sh: &Arc<Shared>, mut stream: TcpStream) {
    // Small frames, latency-sensitive: disable Nagle.
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    loop {
        if sh.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Wait for data without consuming it, so a poll timeout never
        // strands half a length prefix.
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return, // EOF
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                continue;
            }
            Err(_) => return,
        }
        if let Err(e) = fault::failpoint("serve.read") {
            let _ = write_frame(&mut stream, &encode_response(&Response::Err(e.to_string())));
            return;
        }
        // Data is pending; a client that stalls mid-frame past the read
        // timeout is disconnected (its failure, not the server's).
        let payload = match read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => return,
            Err(e) => {
                sh.stats.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(&mut stream, &encode_response(&Response::Err(e.to_string())));
                return;
            }
        };
        let req = match decode_request(&payload) {
            Ok(r) => r,
            Err(msg) => {
                sh.stats.malformed.fetch_add(1, Ordering::Relaxed);
                let resp = Response::Err(format!("malformed request: {msg}"));
                let _ = write_frame(&mut stream, &encode_response(&resp));
                return;
            }
        };
        let resp = match req {
            Request::Ping => Response::Ok,
            Request::Shutdown => {
                let _ = write_frame(&mut stream, &encode_response(&Response::Ok));
                sh.initiate_shutdown();
                return;
            }
            Request::TopK(q) => handle_topk(sh, q),
        };
        if write_frame(&mut stream, &encode_response(&resp)).is_err() {
            return;
        }
    }
}

/// Answer one Top-K request: cache, or batch-submit and wait.
fn handle_topk(sh: &Arc<Shared>, mut q: TopKRequest) -> Response {
    sh.stats.requests.fetch_add(1, Ordering::Relaxed);
    q.exclude.sort_unstable();
    // Resolve the effective probe count once, so the cache key cannot
    // alias two different server defaults.
    if q.probes == 0 {
        q.probes = sh.cfg.mips_probes as u32;
    }
    let key = CacheKey { user: q.user, k: q.k, probes: q.probes, exclude: q.exclude.clone() };
    if let Some(hit) = sh.cache.get(&key) {
        sh.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        return Response::TopK(hit);
    }
    sh.stats.cache_misses.fetch_add(1, Ordering::Relaxed);

    let enqueued = Instant::now();
    let deadline = (q.deadline_us > 0)
        .then(|| enqueued + Duration::from_micros(u64::from(q.deadline_us)));
    let (tx, rx) = mpsc::channel();
    let pending = Pending { req: q, enqueued, deadline, reply: tx };
    if sh.batcher.submit(pending).is_err() {
        sh.stats.rejected.fetch_add(1, Ordering::Relaxed);
        let why = if sh.batcher.is_shutdown() { "shutting down" } else { "overloaded" };
        return Response::Err(why.to_string());
    }
    // Workers always reply unless they died; bound the wait so a dead
    // worker degrades to an error, never a wedged connection.
    let wait = Duration::from_millis(stall_timeout_ms().saturating_mul(5));
    match rx.recv_timeout(wait) {
        Ok(resp) => {
            if let Response::TopK(items) = &resp {
                sh.cache.put(key, items.clone());
            }
            resp
        }
        Err(_) => Response::Err("scoring worker did not reply (timed out or died)".to_string()),
    }
}

/// Scoring worker: drain batches until shutdown, score each in one
/// shard-grouped pass, reply per request.
fn worker_loop(sh: &Arc<Shared>) {
    while let Some(batch) = sh.batcher.next_batch() {
        sh.stats.batches.fetch_add(1, Ordering::Relaxed);
        sh.stats.batched_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
        sh.stats.largest_batch.fetch_max(batch.len() as u64, Ordering::Relaxed);

        // Deadline check happens at scoring time: a request that waited
        // out its budget in the queue is answered with an error instead
        // of burning a scoring slot on a reply nobody wants.
        let now = Instant::now();
        let mut live: Vec<Pending> = Vec::with_capacity(batch.len());
        for p in batch {
            if p.deadline.is_some_and(|d| now > d) {
                sh.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
                let _ = p.reply.send(Response::Err("deadline exceeded".to_string()));
            } else {
                live.push(p);
            }
        }
        if live.is_empty() {
            continue;
        }
        if let Err(e) = fault::failpoint("serve.index") {
            for p in &live {
                let _ = p.reply.send(Response::Err(e.to_string()));
            }
            continue;
        }
        let reqs: Vec<(usize, usize, usize, &[u32])> = live
            .iter()
            .map(|p| {
                // A user id beyond the address space can't be a row; map it
                // to an always-out-of-range row instead of truncating.
                let user = usize::try_from(p.req.user).unwrap_or(usize::MAX);
                (user, p.req.k as usize, p.req.probes as usize, p.req.exclude.as_slice())
            })
            .collect();
        let results = sh.model.topk_batch(&reqs);
        for (p, r) in live.iter().zip(results) {
            let resp = match r {
                Ok(items) => Response::TopK(items),
                Err(msg) => Response::Err(msg),
            };
            // A send error just means the connection gave up (deadline,
            // disconnect); nothing to do.
            let _ = p.reply.send(resp);
        }
    }
}
