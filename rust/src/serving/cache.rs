//! Hot-user result cache: bounded LRU with an optional TTL.
//!
//! Serving traffic is zipfian — a small set of hot users generates most
//! queries — so memoizing full Top-K responses removes those queries from
//! the scoring path entirely. Correctness notes:
//!
//! * The key is the **entire** request identity `(user, k, probes,
//!   sorted exclusions)`, not a hash of it: two requests collide only if
//!   they would provably produce the same response, so a hit is bitwise
//!   identical to a recompute (the model is immutable while serving).
//! * `capacity == 0` disables the cache (every `get` misses, `put` is a
//!   no-op), which the equivalence tests and the latency bench use to
//!   force the scoring path.
//! * TTL exists for operational hygiene (bounded staleness once model
//!   hot-swap lands), not correctness.

use crate::util::threads::lock_or_recover;
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Full request identity — see the module docs for why every field is in
/// the key.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
pub struct CacheKey {
    pub user: u64,
    pub k: u32,
    pub probes: u32,
    /// Sorted exclusion list.
    pub exclude: Vec<u32>,
}

struct Entry {
    value: Vec<(u32, f32)>,
    /// Recency stamp; also the key into `order`.
    tick: u64,
    inserted: Instant,
}

struct Inner {
    map: HashMap<CacheKey, Entry>,
    /// tick → key, ascending = least recently used first.
    order: BTreeMap<u64, CacheKey>,
    tick: u64,
    hits: u64,
    misses: u64,
}

/// Bounded LRU response cache (thread-safe; one lock, O(log n) ops).
pub struct ResultCache {
    capacity: usize,
    ttl: Option<Duration>,
    inner: Mutex<Inner>,
}

impl ResultCache {
    /// `capacity` entries (0 disables), `ttl_ms` milliseconds of
    /// freshness (0 = entries never expire).
    pub fn new(capacity: usize, ttl_ms: u64) -> ResultCache {
        ResultCache {
            capacity,
            ttl: (ttl_ms > 0).then(|| Duration::from_millis(ttl_ms)),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Look up a response, refreshing its recency. Expired entries are
    /// dropped on access.
    pub fn get(&self, key: &CacheKey) -> Option<Vec<(u32, f32)>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = lock_or_recover(&self.inner);
        let Some(entry) = inner.map.get(key) else {
            inner.misses += 1;
            return None;
        };
        if let Some(ttl) = self.ttl {
            if entry.inserted.elapsed() > ttl {
                let tick = entry.tick;
                inner.map.remove(key);
                inner.order.remove(&tick);
                inner.misses += 1;
                return None;
            }
        }
        let old_tick = entry.tick;
        let value = entry.value.clone();
        inner.tick += 1;
        let tick = inner.tick;
        inner.order.remove(&old_tick);
        inner.order.insert(tick, key.clone());
        if let Some(e) = inner.map.get_mut(key) {
            e.tick = tick;
        }
        inner.hits += 1;
        Some(value)
    }

    /// Insert (or refresh) a response, evicting the least recently used
    /// entry when full.
    pub fn put(&self, key: CacheKey, value: Vec<(u32, f32)>) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = lock_or_recover(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.remove(&key) {
            inner.order.remove(&old.tick);
        }
        while inner.map.len() >= self.capacity {
            let Some((&oldest, _)) = inner.order.iter().next() else { break };
            if let Some(victim) = inner.order.remove(&oldest) {
                inner.map.remove(&victim);
            }
        }
        inner.order.insert(tick, key.clone());
        inner.map.insert(key, Entry { value, tick, inserted: Instant::now() });
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        lock_or_recover(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let inner = lock_or_recover(&self.inner);
        (inner.hits, inner.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(user: u64) -> CacheKey {
        CacheKey { user, k: 10, probes: 2, exclude: vec![] }
    }

    #[test]
    fn get_put_roundtrip_and_stats() {
        let c = ResultCache::new(4, 0);
        assert!(c.get(&key(1)).is_none());
        c.put(key(1), vec![(7, 0.5)]);
        assert_eq!(c.get(&key(1)).unwrap(), vec![(7, 0.5)]);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn differing_request_fields_do_not_collide() {
        let c = ResultCache::new(8, 0);
        c.put(key(1), vec![(1, 1.0)]);
        let k5 = CacheKey { k: 5, ..key(1) };
        let probed = CacheKey { probes: 3, ..key(1) };
        let excl = CacheKey { exclude: vec![2], ..key(1) };
        assert!(c.get(&k5).is_none());
        assert!(c.get(&probed).is_none());
        assert!(c.get(&excl).is_none());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = ResultCache::new(2, 0);
        c.put(key(1), vec![(1, 1.0)]);
        c.put(key(2), vec![(2, 2.0)]);
        assert!(c.get(&key(1)).is_some()); // 1 is now most recent
        c.put(key(3), vec![(3, 3.0)]); // evicts 2
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ResultCache::new(0, 0);
        c.put(key(1), vec![(1, 1.0)]);
        assert!(c.get(&key(1)).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn ttl_expires_entries() {
        let c = ResultCache::new(4, 1); // 1ms TTL
        c.put(key(1), vec![(1, 1.0)]);
        std::thread::sleep(Duration::from_millis(10));
        assert!(c.get(&key(1)).is_none());
        assert!(c.is_empty(), "expired entry is dropped on access");
    }

    #[test]
    fn reinsert_refreshes_value() {
        let c = ResultCache::new(2, 0);
        c.put(key(1), vec![(1, 1.0)]);
        c.put(key(1), vec![(9, 9.0)]);
        assert_eq!(c.get(&key(1)).unwrap(), vec![(9, 9.0)]);
        assert_eq!(c.len(), 1);
    }
}
