//! Request coalescing: the bounded queue between connection threads and
//! scoring workers.
//!
//! Concurrent Top-K queries that arrive within one **batch window** are
//! drained as a single batch and scored in one shard-grouped pass
//! ([`crate::eval::MipsIndex::search_batch`]) — the serving analogue of
//! the trainer's fused gather: a demand-paged item table decodes each
//! touched shard once per *batch* instead of once per *query*, and even
//! resident tables amortize the per-probe bookkeeping. The window is the
//! latency/throughput dial: 0 keeps latency minimal (a worker grabs
//! whatever is queued the moment it is free — natural batching under
//! load), while 100µs–1ms trades a bounded wait for larger batches.
//!
//! The queue is bounded: a submit beyond `depth` is rejected immediately
//! (the connection answers `ERR overloaded`) rather than queueing into
//! unbounded memory and blown deadlines. Shutdown is graceful — already
//! queued requests are still handed to workers; only then do workers see
//! `None` and exit.

use super::protocol::{Response, TopKRequest};
use crate::util::threads::lock_or_recover;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued query: the request plus everything needed to answer it.
pub struct Pending {
    pub req: TopKRequest,
    /// When the request entered the queue (starts the batch window).
    pub enqueued: Instant,
    /// Absolute scoring deadline (`None` = no deadline).
    pub deadline: Option<Instant>,
    /// Where the scoring worker sends the response.
    pub reply: mpsc::Sender<Response>,
}

struct State {
    queue: VecDeque<Pending>,
    shutdown: bool,
}

/// Bounded coalescing queue (see module docs).
pub struct Batcher {
    state: Mutex<State>,
    /// Signals workers on submit and everyone on shutdown.
    arrived: Condvar,
    window: Duration,
    batch_max: usize,
    depth: usize,
}

impl Batcher {
    /// `window_us` coalescing window, `batch_max` requests per batch
    /// (flushes the window early when reached), `depth` queue bound.
    pub fn new(window_us: u64, batch_max: usize, depth: usize) -> Batcher {
        Batcher {
            state: Mutex::new(State { queue: VecDeque::new(), shutdown: false }),
            arrived: Condvar::new(),
            window: Duration::from_micros(window_us),
            batch_max: batch_max.max(1),
            depth: depth.max(1),
        }
    }

    /// Enqueue a request. `Err` returns the request untouched when the
    /// queue is full or the batcher is shutting down — the caller answers
    /// the client itself.
    pub fn submit(&self, p: Pending) -> Result<(), Pending> {
        let mut st = lock_or_recover(&self.state);
        if st.shutdown || st.queue.len() >= self.depth {
            return Err(p);
        }
        st.queue.push_back(p);
        drop(st);
        self.arrived.notify_one();
        Ok(())
    }

    /// Block until a batch is ready and drain it (≤ `batch_max`
    /// requests). After the first request arrives the call waits out the
    /// remaining batch window — more arrivals coalesce in — unless the
    /// batch fills or shutdown flushes it early. Returns `None` only at
    /// shutdown with an empty queue: workers exit then, and not before
    /// every queued request has been handed out.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut st = lock_or_recover(&self.state);
        loop {
            if let Some(first) = st.queue.front() {
                // Window accounting is anchored to the *oldest* queued
                // request, so a request never waits more than one window
                // regardless of what arrives after it.
                let anchor = first.enqueued;
                while st.queue.len() < self.batch_max && !st.shutdown {
                    let elapsed = anchor.elapsed();
                    if elapsed >= self.window {
                        break;
                    }
                    let (guard, _timeout) = self
                        .arrived
                        .wait_timeout(st, self.window - elapsed)
                        .unwrap_or_else(|p| p.into_inner());
                    st = guard;
                    if st.queue.is_empty() {
                        // The batch was stolen by another worker while we
                        // waited; go back to sleeping for a new arrival.
                        break;
                    }
                }
                if st.queue.is_empty() {
                    continue;
                }
                let take = st.queue.len().min(self.batch_max);
                let batch: Vec<Pending> = st.queue.drain(..take).collect();
                if !st.queue.is_empty() {
                    // Leftovers form the next batch; wake another worker.
                    self.arrived.notify_one();
                }
                return Some(batch);
            }
            if st.shutdown {
                return None;
            }
            st = self
                .arrived
                .wait_timeout(st, Duration::from_millis(100))
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    /// Begin graceful shutdown: reject new submissions, flush queued
    /// requests to workers immediately (no further window waits matter —
    /// the loop in [`Batcher::next_batch`] checks the flag), and wake
    /// everyone.
    pub fn shutdown(&self) {
        lock_or_recover(&self.state).shutdown = true;
        self.arrived.notify_all();
    }

    /// Whether shutdown has begun.
    pub fn is_shutdown(&self) -> bool {
        lock_or_recover(&self.state).shutdown
    }

    /// Requests currently queued (observability).
    pub fn queued(&self) -> usize {
        lock_or_recover(&self.state).queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending(user: u64) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: TopKRequest { user, k: 1, probes: 1, deadline_us: 0, exclude: vec![] },
                enqueued: Instant::now(),
                deadline: None,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn single_request_zero_window_flushes_immediately() {
        let b = Batcher::new(0, 8, 16);
        let (p, _rx) = pending(1);
        b.submit(p).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].req.user, 1);
    }

    #[test]
    fn window_coalesces_concurrent_requests() {
        let b = Arc::new(Batcher::new(50_000, 8, 64)); // 50ms window
        for u in 0..5 {
            let (p, _rx) = pending(u);
            b.submit(p).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 5, "all five arrivals coalesce into one batch");
    }

    #[test]
    fn batch_max_flushes_early_and_splits() {
        let b = Batcher::new(1_000_000, 3, 64); // 1s window: only the cap flushes
        for u in 0..7 {
            let (p, _rx) = pending(u);
            b.submit(p).unwrap();
        }
        assert_eq!(b.next_batch().unwrap().len(), 3);
        assert_eq!(b.next_batch().unwrap().len(), 3);
        // The final partial batch would wait out the window; shutdown
        // flushes it instead of stalling the test for a second.
        b.shutdown();
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let b = Batcher::new(0, 4, 2);
        let (p1, _r1) = pending(1);
        let (p2, _r2) = pending(2);
        let (p3, _r3) = pending(3);
        assert!(b.submit(p1).is_ok());
        assert!(b.submit(p2).is_ok());
        let rejected = b.submit(p3).unwrap_err();
        assert_eq!(rejected.req.user, 3, "rejected request comes back to the caller");
    }

    #[test]
    fn shutdown_drains_queue_before_none() {
        let b = Batcher::new(0, 8, 16);
        let (p, _rx) = pending(1);
        b.submit(p).unwrap();
        b.shutdown();
        let (p2, _rx2) = pending(2);
        assert!(b.submit(p2).is_err(), "no new work after shutdown");
        assert_eq!(b.next_batch().unwrap().len(), 1, "queued work still drains");
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn blocked_worker_wakes_on_shutdown() {
        let b = Arc::new(Batcher::new(0, 8, 16));
        let b2 = Arc::clone(&b);
        let worker = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.shutdown();
        assert!(worker.join().unwrap().is_none());
    }
}
