//! `alx serve`: a batched, bank-backed Top-K recommendation server.
//!
//! The paper's downstream task is Recall@K retrieval; this subsystem is
//! the piece that actually answers "top-K items for user *u*" under load,
//! completing the train → checkpoint → **serve** lifecycle:
//!
//! * [`ServeModel`] loads `W`/`H` from an `ALXCKPT2` checkpoint or
//!   directly from `ALXTAB01` table banks. Bank-backed tables stay behind
//!   the demand-paged [`crate::sharding::PagedTable`] LRU, so a model
//!   larger than host RAM serves out of core; the cluster-pruned
//!   [`MipsIndex`] builds shard-streamed at startup (never materializing
//!   the item table).
//! * [`server`] runs the request loop: a listener + per-connection
//!   threads speaking the length-prefixed [`protocol`], a bounded
//!   [`batcher`] that coalesces concurrent queries into one shard-grouped
//!   scoring pass per batch, an LRU [`cache`] for hot users, per-request
//!   deadlines and graceful shutdown.
//!
//! Everything is plain `std` + the crate's own [`crate::util::threads`]
//! primitives — no new dependencies — and the scoring path is bitwise
//! identical to offline [`crate::eval`] scoring (`tests/serve_equivalence.rs`
//! holds the proof obligation).

pub mod batcher;
pub mod cache;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, Pending};
pub use cache::{CacheKey, ResultCache};
pub use protocol::{Client, Request, Response, TopKRequest};
pub use server::{serve, ServeStatsSnapshot, ServerHandle};

use crate::als::checkpoint;
use crate::eval::mips::{BatchQuery, MipsIndex};
use crate::sharding::ShardedTable;
use std::io;
use std::path::Path;

/// Serving knobs (the `[serve]` config section / `alx serve` flags).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// TCP port (0 = OS-assigned, printed at startup).
    pub port: u16,
    /// Scoring worker threads (0 = auto from `ALX_THREADS` / CPU count).
    pub threads: usize,
    /// Batch coalescing window in µs (0 = flush immediately).
    pub batch_window_us: u64,
    /// Max requests per scoring batch.
    pub batch_max: usize,
    /// Bound on queued requests (beyond it, requests are rejected with
    /// `ERR overloaded`).
    pub queue_depth: usize,
    /// Result-cache capacity in entries (0 disables the cache).
    pub cache_entries: usize,
    /// Result-cache TTL in ms (0 = no expiry).
    pub cache_ttl_ms: u64,
    /// MIPS clusters for the startup index build (0 = `√n`).
    pub mips_clusters: usize,
    /// Default clusters probed per query when a request asks for 0.
    pub mips_probes: usize,
    /// Seed for the k-means index build.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            port: 0,
            threads: 0,
            batch_window_us: 0,
            batch_max: 64,
            queue_depth: 1024,
            cache_entries: 0,
            cache_ttl_ms: 0,
            mips_clusters: 0,
            mips_probes: 0,
            seed: 0x5eed,
        }
    }
}

/// An immutable model ready to serve: both tables plus the item-side
/// MIPS index. Shared across every server thread behind an `Arc` — all
/// access is read-only ([`ShardedTable`] reads are `&self` and
/// thread-safe on both resident and paged backends).
#[derive(Debug)]
pub struct ServeModel {
    /// User table `W` (`|U| × d`).
    pub users: ShardedTable,
    /// Item table `H` (`|I| × d`).
    pub items: ShardedTable,
    /// Cluster-pruned index over `items`, built shard-streamed.
    pub index: MipsIndex,
}

impl ServeModel {
    /// Load from an `ALXCKPT2` checkpoint file. With `spill` set to
    /// `(dir, resident_table_shards)`, both tables stream into `ALXTAB01`
    /// banks under `dir` and serve demand-paged; otherwise they are
    /// resident. `num_shards` controls the serving shard layout (also the
    /// paging granularity when spilled).
    pub fn from_checkpoint(
        path: &Path,
        num_shards: usize,
        spill: Option<(&Path, usize)>,
        mips_clusters: usize,
        seed: u64,
    ) -> io::Result<ServeModel> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        let mut r = std::io::BufReader::new(file);
        let (_meta, users, items) = checkpoint::load_tables(&mut r, num_shards, Some(len), spill)?;
        Ok(Self::from_tables(users, items, mips_clusters, seed))
    }

    /// Attach to existing `ALXTAB01` banks (the artifacts `--spill-model`
    /// training leaves behind), demand-paged with `resident_table_shards`
    /// decoded shards per table. No copy of the model is made: this is
    /// the zero-RAM-headroom path.
    pub fn from_banks(
        w_bank: &Path,
        h_bank: &Path,
        resident_table_shards: usize,
        mips_clusters: usize,
        seed: u64,
    ) -> io::Result<ServeModel> {
        let users = ShardedTable::open_bank(w_bank, resident_table_shards)?;
        let items = ShardedTable::open_bank(h_bank, resident_table_shards)?;
        if users.dim != items.dim {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bank dim mismatch: W has d={}, H has d={}", users.dim, items.dim),
            ));
        }
        Ok(Self::from_tables(users, items, mips_clusters, seed))
    }

    /// Wrap already-loaded tables (tests, in-process serving). Builds the
    /// shard-streamed MIPS index — the only startup cost.
    pub fn from_tables(
        users: ShardedTable,
        items: ShardedTable,
        mips_clusters: usize,
        seed: u64,
    ) -> ServeModel {
        let index = MipsIndex::build_table(&items, mips_clusters, seed);
        ServeModel { users, items, index }
    }

    /// Embedding dimension.
    pub fn dim(&self) -> usize {
        self.items.dim
    }

    /// Score one user's Top-K (the reference path: what a cache hit or a
    /// batched response must be bitwise identical to). `exclude` must be
    /// sorted. Returns ranked `(item, score)` pairs.
    pub fn topk(
        &self,
        user: usize,
        k: usize,
        probes: usize,
        exclude: &[u32],
    ) -> Result<Vec<(u32, f32)>, String> {
        if user >= self.users.rows {
            return Err(format!("user {user} out of range (table has {} rows)", self.users.rows));
        }
        let mut query = vec![0.0f32; self.users.dim];
        self.users.read_row(user, &mut query);
        let ranked = self.index.search_table(&self.items, &query, k, probes, exclude);
        Ok(ranked.into_iter().map(|(s, id)| (id, s)).collect())
    }

    /// Score a batch of user queries in one shard-grouped pass. Each
    /// element of `reqs` is `(user, k, probes, sorted-exclude)`; each
    /// result is `Ok(ranked pairs)` or a per-request error (out-of-range
    /// user ids fail individually, not the whole batch).
    pub fn topk_batch(
        &self,
        reqs: &[(usize, usize, usize, &[u32])],
    ) -> Vec<Result<Vec<(u32, f32)>, String>> {
        let d = self.users.dim;
        // Gather the valid users' query rows (request order).
        let mut queries: Vec<Option<Vec<f32>>> = Vec::with_capacity(reqs.len());
        for &(user, _, _, _) in reqs {
            if user >= self.users.rows {
                queries.push(None);
                continue;
            }
            let mut q = vec![0.0f32; d];
            self.users.read_row(user, &mut q);
            queries.push(Some(q));
        }
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(reqs)
            .filter_map(|(q, &(_, k, probes, exclude))| {
                q.as_ref().map(|query| BatchQuery { query, k, probes, exclude })
            })
            .collect();
        let mut scored = self.index.search_batch(&self.items, &batch).into_iter();
        queries
            .iter()
            .zip(reqs)
            .map(|(q, &(user, _, _, _))| match q {
                None => Err(format!(
                    "user {user} out of range (table has {} rows)",
                    self.users.rows
                )),
                Some(_) => Ok(scored
                    .next()
                    .expect("one scored result per valid query")
                    .into_iter()
                    .map(|(s, id)| (id, s))
                    .collect()),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharding::Storage;
    use crate::util::Pcg64;

    fn model(seed: u64) -> ServeModel {
        let mut rng = Pcg64::new(seed);
        let users = ShardedTable::randn(12, 6, 2, Storage::F32, &mut rng);
        let items = ShardedTable::randn(40, 6, 4, Storage::F32, &mut rng);
        ServeModel::from_tables(users, items, 8, 99)
    }

    #[test]
    fn topk_batch_matches_serial_topk() {
        let m = model(7);
        let excl = [3u32, 9];
        let reqs: Vec<(usize, usize, usize, &[u32])> =
            (0..8).map(|u| (u, 5, 3, &excl[..])).collect();
        let batched = m.topk_batch(&reqs);
        for (i, r) in batched.iter().enumerate() {
            let serial = m.topk(i, 5, 3, &excl).unwrap();
            let got = r.as_ref().unwrap();
            assert_eq!(got.len(), serial.len());
            for (a, b) in got.iter().zip(&serial) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1.to_bits(), b.1.to_bits());
            }
        }
    }

    #[test]
    fn out_of_range_user_fails_individually() {
        let m = model(8);
        let reqs: Vec<(usize, usize, usize, &[u32])> =
            vec![(1, 3, 2, &[]), (999, 3, 2, &[]), (2, 3, 2, &[])];
        let res = m.topk_batch(&reqs);
        assert!(res[0].is_ok());
        assert!(res[1].is_err());
        assert!(res[2].is_ok());
        assert!(m.topk(999, 3, 2, &[]).is_err());
    }

    #[test]
    fn checkpoint_and_bank_loads_serve_identically() {
        use crate::als::checkpoint::{save, CheckpointMeta};
        let m = model(9);
        let dir = std::env::temp_dir().join(format!("alx_servemodel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Persist as a checkpoint...
        let meta = CheckpointMeta {
            epoch: 1,
            dim: 6,
            users: m.users.rows as u64,
            items: m.items.rows as u64,
            storage_bf16: false,
        };
        let ckpt = dir.join("m.alxckpt");
        let mut f = std::fs::File::create(&ckpt).unwrap();
        save(&mut f, &meta, &m.users, &m.items, &[], &[]).unwrap();
        drop(f);
        // ...and as table banks.
        let wb = dir.join("w.alxtab");
        let hb = dir.join("h.alxtab");
        m.users.spill_to_bank(&wb).unwrap();
        m.items.spill_to_bank(&hb).unwrap();

        let from_ckpt = ServeModel::from_checkpoint(&ckpt, 2, None, 8, 99).unwrap();
        let spill_dir = dir.join("spill");
        let from_ckpt_spilled =
            ServeModel::from_checkpoint(&ckpt, 2, Some((&spill_dir, 1)), 8, 99).unwrap();
        let from_banks = ServeModel::from_banks(&wb, &hb, 1, 8, 99).unwrap();
        assert!(from_ckpt_spilled.users.is_spilled());
        assert!(from_banks.items.is_spilled());

        for srv in [&from_ckpt, &from_ckpt_spilled, &from_banks] {
            for u in 0..4 {
                let want = m.topk(u, 6, 4, &[]).unwrap();
                let got = srv.topk(u, 6, 4, &[]).unwrap();
                assert_eq!(want.len(), got.len());
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(a.0, b.0);
                    assert_eq!(a.1.to_bits(), b.1.to_bits());
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
