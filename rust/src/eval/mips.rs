//! Approximate Maximum Inner Product Search (paper §4.6).
//!
//! Exact Top-K over hundreds of millions of items is too slow, so the paper
//! evaluates its two largest variants with an approximate MIPS method and
//! reports the recall numbers as high-probability lower bounds. We
//! implement the classic cluster-pruning strategy (the core of ScaNN-style
//! systems): k-means over the item embeddings, score the query against the
//! `c` centroids, and run exact search only inside the best `p` clusters —
//! expected cost `O(c·d + p·(n/c)·d)`, sublinear in n for `c ≈ √n`.
//!
//! The index builds directly off a [`ShardedTable`], streaming one shard
//! at a time (so a spilled, larger-than-RAM item table never has to be
//! materialized densely) with the Lloyd assignment loop parallelized
//! across rows. Search comes in three shapes that all produce bitwise
//! identical rankings: the dense-matrix path (tests / tiny problems), a
//! table-streamed single query, and [`MipsIndex::search_batch`] — the
//! serving path that groups an entire batch's candidate lookups by owning
//! shard so a paged backend faults each shard at most once per batch.

use crate::linalg::{mat::dot, Mat};
use crate::sharding::ShardedTable;
use crate::util::threads::parallel_map_indexed;
use crate::util::Pcg64;

/// Cluster-pruned MIPS index over a fixed item table.
#[derive(Clone, Debug)]
pub struct MipsIndex {
    /// `c × d` centroid matrix.
    pub centroids: Mat,
    /// Item ids per cluster.
    pub clusters: Vec<Vec<u32>>,
}

impl MipsIndex {
    /// Build with `num_clusters` k-means clusters (0 → `√n` heuristic).
    /// A few Lloyd iterations suffice — the index only prunes.
    ///
    /// Dense entry point: wraps `items` in a single-shard resident table
    /// and delegates to [`MipsIndex::build_table`], so the dense and
    /// streamed builds are the same code and provably produce the same
    /// index.
    pub fn build(items: &Mat, num_clusters: usize, seed: u64) -> MipsIndex {
        Self::build_table(&dense_as_table(items), num_clusters, seed)
    }

    /// Build the index off a sharded table, streaming shard-by-shard: at
    /// no point is more than one shard's worth of item rows resident
    /// (plus the `c × d` centroids), so index construction works on a
    /// demand-paged model that never fits in RAM. The Lloyd assignment
    /// loop is parallelized across rows; assignments are collected in
    /// row order and the centroid sums accumulate serially in global row
    /// order, so the result is bitwise identical for every worker count
    /// and identical to the historical serial dense build.
    pub fn build_table(table: &ShardedTable, num_clusters: usize, seed: u64) -> MipsIndex {
        let n = table.rows;
        let d = table.dim;
        let c = if num_clusters == 0 {
            ((n as f64).sqrt().ceil() as usize).clamp(1, n.max(1))
        } else {
            num_clusters.clamp(1, n.max(1))
        };
        let mut rng = Pcg64::new(seed);

        // Init: random distinct items as centroids.
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        let mut centroids = Mat::zeros(c, d);
        for k in 0..c {
            table.read_row(ids[k % n.max(1)] as usize, centroids.row_mut(k));
        }

        let mut assign = vec![0usize; n];
        for _iter in 0..8 {
            // Assign to nearest centroid (L2 — standard k-means; the probe
            // step scores by inner product which is what MIPS needs).
            // One decoded shard at a time; rows within the shard are
            // assigned in parallel (each row is independent, and
            // `parallel_map_indexed` returns results in row order).
            let mut changed = 0usize;
            for s in 0..table.num_shards() {
                let range = table.range(s);
                if range.is_empty() {
                    continue;
                }
                let rows = table.shard_f32(s);
                let shard_assign = parallel_map_indexed(range.len(), |r| {
                    let x = &rows[r * d..(r + 1) * d];
                    let mut best = 0usize;
                    let mut best_d = f32::INFINITY;
                    for k in 0..c {
                        let cent = centroids.row(k);
                        let mut dist = 0.0f32;
                        for j in 0..d {
                            let t = x[j] - cent[j];
                            dist += t * t;
                        }
                        if dist < best_d {
                            best_d = dist;
                            best = k;
                        }
                    }
                    best
                });
                for (r, best) in shard_assign.into_iter().enumerate() {
                    let i = range.start + r;
                    if assign[i] != best {
                        assign[i] = best;
                        changed += 1;
                    }
                }
            }
            // Update: serial accumulation in global row order (bitwise
            // determinism), streamed over the same one-shard window.
            let mut counts = vec![0usize; c];
            let mut sums = Mat::zeros(c, d);
            for s in 0..table.num_shards() {
                let range = table.range(s);
                let mut row = vec![0.0f32; d];
                table.with_shard_data(s, |data| {
                    for r in 0..range.len() {
                        data.read_row_f32(r * d, &mut row);
                        let i = range.start + r;
                        counts[assign[i]] += 1;
                        let srow = sums.row_mut(assign[i]);
                        for j in 0..d {
                            srow[j] += row[j];
                        }
                    }
                });
            }
            for k in 0..c {
                if counts[k] > 0 {
                    let inv = 1.0 / counts[k] as f32;
                    let crow = centroids.row_mut(k);
                    let srow = sums.row(k);
                    for j in 0..d {
                        crow[j] = srow[j] * inv;
                    }
                }
            }
            if changed == 0 {
                break;
            }
        }

        let mut clusters = vec![Vec::new(); c];
        for (i, &k) in assign.iter().enumerate() {
            clusters[k].push(i as u32);
        }
        MipsIndex { centroids, clusters }
    }

    /// Resolve the probe count (0 → `√c` heuristic, min 1) and rank all
    /// clusters by centroid inner product, best first. Every search shape
    /// goes through this one ranking so batched and serial probes visit
    /// clusters in the identical order.
    pub fn ranked_clusters(&self, query: &[f32], probes: usize) -> Vec<usize> {
        let c = self.centroids.rows;
        let probes = if probes == 0 {
            ((c as f64).sqrt().ceil() as usize).clamp(1, c)
        } else {
            probes.clamp(1, c)
        };
        let mut ranked: Vec<(f32, usize)> =
            (0..c).map(|i| (dot(self.centroids.row(i), query), i)).collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        ranked.truncate(probes);
        ranked.into_iter().map(|(_, cl)| cl).collect()
    }

    /// The candidate item ids a probe of `query` visits, in the exact
    /// enumeration order every search shape scores them in: ranked
    /// cluster order, ids in cluster order, exclusions dropped. The order
    /// matters because ties are broken by a stable sort over this
    /// sequence.
    fn candidates(&self, query: &[f32], probes: usize, exclude: &[u32]) -> Vec<u32> {
        let mut out = Vec::new();
        for cl in self.ranked_clusters(query, probes) {
            for &id in &self.clusters[cl] {
                if exclude.binary_search(&id).is_ok() {
                    continue;
                }
                out.push(id);
            }
        }
        out
    }

    /// Rank already-scored candidates: stable sort by score descending
    /// over the enumeration order, truncate to k. Shared by every search
    /// shape — this is where bitwise-identical tie-breaking lives.
    fn rank(mut scored: Vec<(f32, u32)>, k: usize) -> Vec<(f32, u32)> {
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored.truncate(k);
        scored
    }

    /// Approximate top-k by probing the `probes` best clusters
    /// (0 → `√c` heuristic, min 1).
    pub fn search(
        &self,
        items: &Mat,
        query: &[f32],
        k: usize,
        probes: usize,
        exclude: &[u32],
    ) -> Vec<u32> {
        self.search_scored(items, query, k, probes, exclude).into_iter().map(|(_, id)| id).collect()
    }

    /// [`MipsIndex::search`] that also returns the inner-product scores
    /// (what a serving response carries).
    pub fn search_scored(
        &self,
        items: &Mat,
        query: &[f32],
        k: usize,
        probes: usize,
        exclude: &[u32],
    ) -> Vec<(f32, u32)> {
        let scored = self
            .candidates(query, probes, exclude)
            .into_iter()
            .map(|id| (dot(items.row(id as usize), query), id))
            .collect();
        Self::rank(scored, k)
    }

    /// Single-query probe against a sharded table. Scores with the same
    /// `dot` in the same candidate order as the dense path, so results
    /// are bitwise identical to [`MipsIndex::search_scored`] over
    /// `table.to_dense()` — without ever materializing the table.
    pub fn search_table(
        &self,
        table: &ShardedTable,
        query: &[f32],
        k: usize,
        probes: usize,
        exclude: &[u32],
    ) -> Vec<(f32, u32)> {
        self.search_batch(table, &[BatchQuery { query, k, probes, exclude }])
            .pop()
            .expect("one query in, one result out")
    }

    /// Batched probe: the serving hot path. All queries' candidate
    /// lookups are grouped by the shard that owns each item row, so a
    /// demand-paged table decodes every touched shard exactly once per
    /// batch instead of once per (query, cluster) — the `[B×d]·[d×n]`
    /// amortization, organized around the bank's actual unit of IO.
    /// Scoring order over shards is free because each candidate slot is
    /// written exactly once; the final per-query ranking re-reads slots
    /// in candidate-enumeration order, making each result bitwise
    /// identical to a serial [`MipsIndex::search_table`] of that query.
    pub fn search_batch(
        &self,
        table: &ShardedTable,
        queries: &[BatchQuery],
    ) -> Vec<Vec<(f32, u32)>> {
        let d = table.dim;
        // Per-query candidate lists in enumeration order; scores filled
        // shard-by-shard below.
        let cands: Vec<Vec<u32>> =
            queries.iter().map(|q| self.candidates(q.query, q.probes, q.exclude)).collect();
        let mut scores: Vec<Vec<f32>> = cands.iter().map(|c| vec![0.0f32; c.len()]).collect();

        // Group (query, slot) work by owning shard.
        let mut by_shard: Vec<Vec<(u32, u32, u32)>> = vec![Vec::new(); table.num_shards()];
        for (qi, c) in cands.iter().enumerate() {
            for (slot, &id) in c.iter().enumerate() {
                by_shard[table.shard_of(id as usize)].push((qi as u32, slot as u32, id));
            }
        }

        let mut row = vec![0.0f32; d];
        for (s, work) in by_shard.iter().enumerate() {
            if work.is_empty() {
                continue;
            }
            let start = table.range(s).start;
            table.with_shard_data(s, |data| {
                for &(qi, slot, id) in work {
                    data.read_row_f32((id as usize - start) * d, &mut row);
                    scores[qi as usize][slot as usize] = dot(&row, queries[qi as usize].query);
                }
            });
        }

        cands
            .into_iter()
            .zip(scores)
            .zip(queries)
            .map(|((c, sc), q)| {
                Self::rank(sc.into_iter().zip(c).collect(), q.k)
            })
            .collect()
    }

    /// Expected fraction of items scored per query (search cost model).
    pub fn probe_fraction(&self, probes: usize) -> f64 {
        let total: usize = self.clusters.iter().map(|c| c.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let mut sizes: Vec<usize> = self.clusters.iter().map(|c| c.len()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let probes = probes.max(1).min(sizes.len());
        sizes[..probes].iter().sum::<usize>() as f64 / total as f64
    }
}

/// One query in a [`MipsIndex::search_batch`] call.
#[derive(Clone, Copy, Debug)]
pub struct BatchQuery<'a> {
    /// The `d`-dimensional query embedding.
    pub query: &'a [f32],
    /// How many results to return.
    pub k: usize,
    /// Clusters to probe (0 → `√c` heuristic).
    pub probes: usize,
    /// Sorted item ids to exclude (a user's training history).
    pub exclude: &'a [u32],
}

/// Wrap a dense matrix as a single-shard resident f32 table (zero
/// rounding, so values — and therefore every distance and score — are
/// exactly the matrix's own).
fn dense_as_table(items: &Mat) -> ShardedTable {
    let mut t = ShardedTable::zeros(items.rows, items.cols, 1, crate::sharding::Storage::F32);
    if items.rows > 0 {
        t.update_shard(0, |data| {
            if let crate::sharding::ShardData::F32(v) = data {
                v.copy_from_slice(&items.data);
            }
        });
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::topk_exact;
    use crate::sharding::Storage;

    /// Items in two well-separated blobs.
    fn blobs(n_per: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(2 * n_per, d);
        for i in 0..2 * n_per {
            let center = if i < n_per { 3.0 } else { -3.0 };
            for j in 0..d {
                m[(i, j)] = center + rng.next_normal() as f32 * 0.3;
            }
        }
        m
    }

    /// The same blob items scattered into a multi-shard f32 table.
    fn blobs_table(items: &Mat, shards: usize) -> ShardedTable {
        let mut t = ShardedTable::zeros(items.rows, items.cols, shards, Storage::F32);
        let ids: Vec<u32> = (0..items.rows as u32).collect();
        t.scatter(&ids, items);
        t
    }

    #[test]
    fn clusters_partition_items() {
        let items = blobs(50, 4, 1);
        let idx = MipsIndex::build(&items, 4, 2);
        let mut all: Vec<u32> = idx.clusters.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn separated_blobs_end_up_in_distinct_clusters() {
        let items = blobs(50, 4, 3);
        let idx = MipsIndex::build(&items, 2, 4);
        // Each cluster should be (almost) pure.
        for cl in &idx.clusters {
            if cl.is_empty() {
                continue;
            }
            let first_blob = cl.iter().filter(|&&i| i < 50).count();
            let purity = first_blob.max(cl.len() - first_blob) as f64 / cl.len() as f64;
            assert!(purity > 0.95, "purity={purity}");
        }
    }

    #[test]
    fn approximate_search_recovers_exact_topk_with_full_probes() {
        let items = blobs(40, 6, 5);
        let idx = MipsIndex::build(&items, 8, 6);
        let query = vec![1.0f32; 6];
        let exact = topk_exact(&items, &query, 10, &[]);
        let approx = idx.search(&items, &query, 10, 8, &[]); // probe all
        assert_eq!(approx, exact);
    }

    #[test]
    fn pruned_search_has_high_recall_on_clustered_data() {
        let items = blobs(100, 8, 7);
        let idx = MipsIndex::build(&items, 16, 8);
        let query = vec![1.0f32; 8]; // points at the +3 blob
        let exact = topk_exact(&items, &query, 20, &[]);
        let approx = idx.search(&items, &query, 20, 6, &[]);
        let exact_set: std::collections::HashSet<u32> = exact.iter().copied().collect();
        let hits = approx.iter().filter(|i| exact_set.contains(i)).count();
        assert!(hits >= 15, "recall {hits}/20 too low for clustered data");
    }

    #[test]
    fn pruning_actually_prunes() {
        let items = blobs(100, 4, 9);
        let idx = MipsIndex::build(&items, 16, 10);
        assert!(idx.probe_fraction(4) < 0.8);
    }

    #[test]
    fn exclusions_respected() {
        let items = blobs(20, 4, 11);
        let idx = MipsIndex::build(&items, 4, 12);
        let query = vec![1.0f32; 4];
        let full = idx.search(&items, &query, 5, 4, &[]);
        let excluded = full[0];
        let pruned = idx.search(&items, &query, 5, 4, &[excluded]);
        assert!(!pruned.contains(&excluded));
    }

    #[test]
    fn streamed_build_matches_dense_build_bitwise() {
        // The same items, dense vs. scattered over 5 shards: identical
        // centroid bits and identical cluster membership.
        let items = blobs(40, 6, 21);
        let table = blobs_table(&items, 5);
        let dense = MipsIndex::build(&items, 8, 22);
        let streamed = MipsIndex::build_table(&table, 8, 22);
        assert_eq!(dense.centroids.data, streamed.centroids.data);
        assert_eq!(dense.clusters, streamed.clusters);
    }

    #[test]
    fn streamed_build_is_threadcount_invariant() {
        // parallel_map_indexed collects per-row assignments in row order,
        // so Lloyd iterations cannot depend on the worker count.
        let items = blobs(30, 4, 31);
        let table = blobs_table(&items, 3);
        let base = MipsIndex::build_table(&table, 6, 32);
        std::env::set_var("ALX_THREADS", "1");
        let single = MipsIndex::build_table(&table, 6, 32);
        std::env::remove_var("ALX_THREADS");
        assert_eq!(base.centroids.data, single.centroids.data);
        assert_eq!(base.clusters, single.clusters);
    }

    #[test]
    fn table_search_matches_dense_search_bitwise() {
        let items = blobs(35, 5, 41);
        let table = blobs_table(&items, 4);
        let idx = MipsIndex::build(&items, 8, 42);
        let mut rng = Pcg64::new(43);
        for _ in 0..10 {
            let query: Vec<f32> = (0..5).map(|_| rng.next_normal() as f32).collect();
            let exclude = [3u32, 17, 40];
            let dense = idx.search_scored(&items, &query, 7, 3, &exclude);
            let table_r = idx.search_table(&table, &query, 7, 3, &exclude);
            assert_eq!(dense.len(), table_r.len());
            for (a, b) in dense.iter().zip(&table_r) {
                assert_eq!(a.1, b.1);
                assert_eq!(a.0.to_bits(), b.0.to_bits());
            }
        }
    }

    #[test]
    fn batched_search_matches_serial_searches_bitwise() {
        let items = blobs(45, 6, 51);
        let table = blobs_table(&items, 6);
        let idx = MipsIndex::build(&items, 9, 52);
        let mut rng = Pcg64::new(53);
        let queries: Vec<Vec<f32>> =
            (0..8).map(|_| (0..6).map(|_| rng.next_normal() as f32).collect()).collect();
        let excludes: Vec<Vec<u32>> = (0..8).map(|i| vec![i as u32, 50 + i as u32]).collect();
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(&excludes)
            .map(|(q, e)| BatchQuery { query: q, k: 5, probes: 4, exclude: e })
            .collect();
        let batched = idx.search_batch(&table, &batch);
        for (bq, got) in batch.iter().zip(&batched) {
            let serial = idx.search_scored(&items, bq.query, bq.k, bq.probes, bq.exclude);
            assert_eq!(serial.len(), got.len());
            for (a, b) in serial.iter().zip(got) {
                assert_eq!(a.1, b.1);
                assert_eq!(a.0.to_bits(), b.0.to_bits());
            }
        }
    }
}
