//! Approximate Maximum Inner Product Search (paper §4.6).
//!
//! Exact Top-K over hundreds of millions of items is too slow, so the paper
//! evaluates its two largest variants with an approximate MIPS method and
//! reports the recall numbers as high-probability lower bounds. We
//! implement the classic cluster-pruning strategy (the core of ScaNN-style
//! systems): k-means over the item embeddings, score the query against the
//! `c` centroids, and run exact search only inside the best `p` clusters —
//! expected cost `O(c·d + p·(n/c)·d)`, sublinear in n for `c ≈ √n`.

use crate::linalg::{mat::dot, Mat};
use crate::util::Pcg64;

/// Cluster-pruned MIPS index over a fixed item matrix.
#[derive(Clone, Debug)]
pub struct MipsIndex {
    /// `c × d` centroid matrix.
    pub centroids: Mat,
    /// Item ids per cluster.
    pub clusters: Vec<Vec<u32>>,
}

impl MipsIndex {
    /// Build with `num_clusters` k-means clusters (0 → `√n` heuristic).
    /// A few Lloyd iterations suffice — the index only prunes.
    pub fn build(items: &Mat, num_clusters: usize, seed: u64) -> MipsIndex {
        let n = items.rows;
        let d = items.cols;
        let c = if num_clusters == 0 {
            ((n as f64).sqrt().ceil() as usize).clamp(1, n.max(1))
        } else {
            num_clusters.clamp(1, n.max(1))
        };
        let mut rng = Pcg64::new(seed);

        // Init: random distinct items as centroids.
        let mut ids: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut ids);
        let mut centroids = Mat::zeros(c, d);
        for k in 0..c {
            centroids.row_mut(k).copy_from_slice(items.row(ids[k % n.max(1)] as usize));
        }

        let mut assign = vec![0usize; n];
        for _iter in 0..8 {
            // Assign to nearest centroid (L2 — standard k-means; the probe
            // step scores by inner product which is what MIPS needs).
            let mut changed = 0usize;
            for i in 0..n {
                let x = items.row(i);
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for k in 0..c {
                    let cent = centroids.row(k);
                    let mut dist = 0.0f32;
                    for j in 0..d {
                        let t = x[j] - cent[j];
                        dist += t * t;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = k;
                    }
                }
                if assign[i] != best {
                    assign[i] = best;
                    changed += 1;
                }
            }
            // Update.
            let mut counts = vec![0usize; c];
            let mut sums = Mat::zeros(c, d);
            for i in 0..n {
                counts[assign[i]] += 1;
                let row = items.row(i);
                let srow = sums.row_mut(assign[i]);
                for j in 0..d {
                    srow[j] += row[j];
                }
            }
            for k in 0..c {
                if counts[k] > 0 {
                    let inv = 1.0 / counts[k] as f32;
                    let crow = centroids.row_mut(k);
                    let srow = sums.row(k);
                    for j in 0..d {
                        crow[j] = srow[j] * inv;
                    }
                }
            }
            if changed == 0 {
                break;
            }
        }

        let mut clusters = vec![Vec::new(); c];
        for (i, &k) in assign.iter().enumerate() {
            clusters[k].push(i as u32);
        }
        MipsIndex { centroids, clusters }
    }

    /// Approximate top-k by probing the `probes` best clusters
    /// (0 → `√c` heuristic, min 1).
    pub fn search(
        &self,
        items: &Mat,
        query: &[f32],
        k: usize,
        probes: usize,
        exclude: &[u32],
    ) -> Vec<u32> {
        let c = self.centroids.rows;
        let probes = if probes == 0 {
            ((c as f64).sqrt().ceil() as usize).clamp(1, c)
        } else {
            probes.clamp(1, c)
        };
        // Rank clusters by centroid inner product.
        let mut ranked: Vec<(f32, usize)> =
            (0..c).map(|i| (dot(self.centroids.row(i), query), i)).collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

        let mut scored: Vec<(f32, u32)> = Vec::new();
        for &(_, cl) in ranked.iter().take(probes) {
            for &id in &self.clusters[cl] {
                if exclude.binary_search(&id).is_ok() {
                    continue;
                }
                scored.push((dot(items.row(id as usize), query), id));
            }
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored.truncate(k);
        scored.into_iter().map(|(_, id)| id).collect()
    }

    /// Expected fraction of items scored per query (search cost model).
    pub fn probe_fraction(&self, probes: usize) -> f64 {
        let total: usize = self.clusters.iter().map(|c| c.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let mut sizes: Vec<usize> = self.clusters.iter().map(|c| c.len()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let probes = probes.max(1).min(sizes.len());
        sizes[..probes].iter().sum::<usize>() as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::topk_exact;

    /// Items in two well-separated blobs.
    fn blobs(n_per: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(2 * n_per, d);
        for i in 0..2 * n_per {
            let center = if i < n_per { 3.0 } else { -3.0 };
            for j in 0..d {
                m[(i, j)] = center + rng.next_normal() as f32 * 0.3;
            }
        }
        m
    }

    #[test]
    fn clusters_partition_items() {
        let items = blobs(50, 4, 1);
        let idx = MipsIndex::build(&items, 4, 2);
        let mut all: Vec<u32> = idx.clusters.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn separated_blobs_end_up_in_distinct_clusters() {
        let items = blobs(50, 4, 3);
        let idx = MipsIndex::build(&items, 2, 4);
        // Each cluster should be (almost) pure.
        for cl in &idx.clusters {
            if cl.is_empty() {
                continue;
            }
            let first_blob = cl.iter().filter(|&&i| i < 50).count();
            let purity = first_blob.max(cl.len() - first_blob) as f64 / cl.len() as f64;
            assert!(purity > 0.95, "purity={purity}");
        }
    }

    #[test]
    fn approximate_search_recovers_exact_topk_with_full_probes() {
        let items = blobs(40, 6, 5);
        let idx = MipsIndex::build(&items, 8, 6);
        let query = vec![1.0f32; 6];
        let exact = topk_exact(&items, &query, 10, &[]);
        let approx = idx.search(&items, &query, 10, 8, &[]); // probe all
        assert_eq!(approx, exact);
    }

    #[test]
    fn pruned_search_has_high_recall_on_clustered_data() {
        let items = blobs(100, 8, 7);
        let idx = MipsIndex::build(&items, 16, 8);
        let query = vec![1.0f32; 8]; // points at the +3 blob
        let exact = topk_exact(&items, &query, 20, &[]);
        let approx = idx.search(&items, &query, 20, 6, &[]);
        let exact_set: std::collections::HashSet<u32> = exact.iter().copied().collect();
        let hits = approx.iter().filter(|i| exact_set.contains(i)).count();
        assert!(hits >= 15, "recall {hits}/20 too low for clustered data");
    }

    #[test]
    fn pruning_actually_prunes() {
        let items = blobs(100, 4, 9);
        let idx = MipsIndex::build(&items, 16, 10);
        assert!(idx.probe_fraction(4) < 0.8);
    }

    #[test]
    fn exclusions_respected() {
        let items = blobs(20, 4, 11);
        let idx = MipsIndex::build(&items, 4, 12);
        let query = vec![1.0f32; 4];
        let full = idx.search(&items, &query, 5, 4, &[]);
        let excluded = full[0];
        let pruned = idx.search(&items, &query, 5, 4, &[excluded]);
        assert!(!pruned.contains(&excluded));
    }
}
