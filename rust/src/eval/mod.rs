//! Evaluation: Top-K retrieval and Recall@K under strong generalization
//! (paper §4.6 and §5).
//!
//! For every test row the held-in history is folded into the embedding
//! space via Eq. (4) and the resulting vector is scored against the whole
//! item table. The paper notes exact Top-K is slow at the largest scales
//! and recommends approximate MIPS; both paths are provided:
//!
//! * [`topk_exact`] — heap-based exact top-K over all items.
//! * [`MipsIndex`] — k-means cluster-pruned approximate search (the
//!   ScaNN-style "probe the best clusters" strategy). Table 2's two
//!   largest variants were evaluated this way, with recall a lower bound.

pub mod metrics;
pub mod mips;

pub use metrics::{average_precision_at_k, ndcg_at_k, reciprocal_rank};
pub use mips::MipsIndex;

use crate::als::Trainer;
use crate::linalg::{mat::dot, Mat};
use crate::sharding::ShardedTable;
use crate::sparse::TestRow;

/// Eval knobs.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Cutoffs to report (paper: 20 and 50).
    pub ks: Vec<usize>,
    /// Use approximate MIPS instead of exact top-K.
    pub approximate: bool,
    /// MIPS: number of clusters (0 = auto ~ sqrt(n)).
    pub mips_clusters: usize,
    /// MIPS: clusters probed per query (0 = auto ~ sqrt(clusters)).
    pub mips_probes: usize,
    /// Exclude the history items from the candidate set (standard
    /// protocol: do not "recommend" what the user already has).
    pub exclude_history: bool,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            ks: vec![20, 50],
            approximate: false,
            mips_clusters: 0,
            mips_probes: 0,
            exclude_history: true,
        }
    }
}

/// Result per cutoff K.
#[derive(Clone, Debug, PartialEq)]
pub struct RecallReport {
    pub k: usize,
    pub recall: f64,
    pub rows_evaluated: usize,
}

/// Bounded top-k accumulator: min-heap of (score, id) fed in id order.
/// One implementation behind [`topk_exact`] and [`topk_exact_table`], so
/// the dense and shard-streamed exact searches perform the identical
/// sequence of heap operations and return identical ids.
struct TopKHeap {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<(OrderedF32, u32)>>,
    k: usize,
}

impl TopKHeap {
    fn new(k: usize) -> TopKHeap {
        TopKHeap { heap: std::collections::BinaryHeap::with_capacity(k + 1), k }
    }

    #[inline]
    fn push(&mut self, score: f32, id: u32) {
        use std::cmp::Reverse;
        if self.heap.len() < self.k {
            self.heap.push(Reverse((ordered(score), id)));
        } else if let Some(&Reverse((min, _))) = self.heap.peek() {
            if ordered(score) > min {
                self.heap.pop();
                self.heap.push(Reverse((ordered(score), id)));
            }
        }
    }

    fn finish(self) -> Vec<u32> {
        let mut out: Vec<(OrderedF32, u32)> =
            self.heap.into_iter().map(|std::cmp::Reverse(x)| x).collect();
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out.into_iter().map(|(_, i)| i).collect()
    }
}

/// Exact top-k item indices by inner product with `query`, excluding ids in
/// `exclude` (sorted). O(n·d + n log k) via a bounded min-heap.
pub fn topk_exact(items: &Mat, query: &[f32], k: usize, exclude: &[u32]) -> Vec<u32> {
    let mut top = TopKHeap::new(k);
    for i in 0..items.rows {
        if exclude.binary_search(&(i as u32)).is_ok() {
            continue;
        }
        top.push(dot(items.row(i), query), i as u32);
    }
    top.finish()
}

/// [`topk_exact`] off a sharded table, streaming one shard at a time —
/// rows are visited in the same global order (shards are contiguous row
/// ranges) and scored with the same `dot`, so results are bitwise
/// identical to `topk_exact(&table.to_dense(), ...)` without the
/// full-table materialization.
pub fn topk_exact_table(
    table: &ShardedTable,
    query: &[f32],
    k: usize,
    exclude: &[u32],
) -> Vec<u32> {
    let d = table.dim;
    let mut top = TopKHeap::new(k);
    let mut row = vec![0.0f32; d];
    for s in 0..table.num_shards() {
        let range = table.range(s);
        table.with_shard_data(s, |data| {
            for r in 0..range.len() {
                let i = (range.start + r) as u32;
                if exclude.binary_search(&i).is_ok() {
                    continue;
                }
                data.read_row_f32(r * d, &mut row);
                top.push(dot(&row, query), i);
            }
        });
    }
    top.finish()
}

/// Total-order f32 wrapper (NaN-free scores assumed; the bit trick gives a
/// total order compatible with numeric order).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct OrderedF32(pub u32);

/// Map an f32 into its order-preserving integer form.
#[inline]
pub fn ordered(x: f32) -> OrderedF32 {
    let bits = x.to_bits();
    // Flip so that the integer order matches the float order.
    OrderedF32(if bits & 0x8000_0000 != 0 { !bits } else { bits | 0x8000_0000 })
}

/// Recall@K of one prediction list against a sorted holdout set.
pub fn recall_at_k(predictions: &[u32], holdout: &[u32], k: usize) -> f64 {
    if holdout.is_empty() {
        return 0.0;
    }
    let hits = predictions
        .iter()
        .take(k)
        .filter(|p| holdout.binary_search(p).is_ok())
        .count();
    hits as f64 / holdout.len().min(k) as f64
}

/// Fold a row's history into the embedding space (Eq. 4) given the
/// history's item rows pre-gathered into `hist_rows` (one row per history
/// entry, in history order) — the strong-generalization query builder's
/// core. Free-standing so the parallel eval loop only borrows `Sync`
/// data; row-gather based so a spilled item table feeds it through
/// [`ShardedTable::gather`] without a dense materialization.
pub fn fold_in_rows(
    hist_rows: &Mat,
    history: &[(u32, f32)],
    gramian: &Mat,
    lambda: f32,
    alpha: f32,
    solver: crate::linalg::SolverKind,
    opts: &crate::linalg::SolveOptions,
) -> Vec<f32> {
    assert_eq!(hist_rows.rows, history.len());
    let d = hist_rows.cols;
    let mut a = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            a[(i, j)] = alpha * gramian[(i, j)];
        }
        a[(i, i)] += lambda;
    }
    let mut b = vec![0.0f32; d];
    for (h, &(_, y)) in history.iter().enumerate() {
        let hrow = hist_rows.row(h);
        for i in 0..d {
            b[i] += y * hrow[i];
            for j in i..d {
                a[(i, j)] += hrow[i] * hrow[j];
            }
        }
    }
    crate::linalg::mat::symmetrize_upper(&mut a.data, d);
    crate::linalg::solvers::solve(solver, &a, &b, opts)
}

/// [`fold_in_rows`] against a dense item matrix (gathers the history rows
/// itself; same bits as the gather-based path).
pub fn fold_in_dense(
    items: &Mat,
    history: &[(u32, f32)],
    gramian: &Mat,
    lambda: f32,
    alpha: f32,
    solver: crate::linalg::SolverKind,
    opts: &crate::linalg::SolveOptions,
) -> Vec<f32> {
    let mut hist_rows = Mat::zeros(history.len(), items.cols);
    for (h, &(item, _)) in history.iter().enumerate() {
        hist_rows.row_mut(h).copy_from_slice(items.row(item as usize));
    }
    fold_in_rows(&hist_rows, history, gramian, lambda, alpha, solver, opts)
}

/// Evaluate a trained model on the strong-generalization test rows.
///
/// The item table is never materialized densely: fold-in gathers only
/// each row's history items, the MIPS index builds shard-streamed, and
/// both search paths score straight off the (possibly demand-paged)
/// table — so evaluating a spilled, larger-than-RAM model stays within
/// the paging budget.
pub fn evaluate(trainer: &Trainer, test: &[TestRow], cfg: &EvalConfig) -> Vec<RecallReport> {
    let items = &trainer.h;
    let gramian = trainer.item_gramian();
    let kmax = cfg.ks.iter().copied().max().unwrap_or(50);
    let (lambda, alpha) = (trainer.cfg.lambda, trainer.cfg.alpha);
    let solver = trainer.cfg.solver;
    let opts = trainer.cfg.solve_options();

    let index = if cfg.approximate {
        Some(MipsIndex::build_table(items, cfg.mips_clusters, trainer.cfg.seed ^ 0x5eed))
    } else {
        None
    };

    let per_row: Vec<Vec<f64>> = crate::util::threads::parallel_map_indexed(test.len(), |t| {
        let row = &test[t];
        let hist_ids: Vec<u32> = row.history.iter().map(|&(c, _)| c).collect();
        let hist_rows = items.gather(&hist_ids);
        let query = fold_in_rows(&hist_rows, &row.history, &gramian, lambda, alpha, solver, &opts);
        let mut exclude: Vec<u32> = if cfg.exclude_history { hist_ids } else { Vec::new() };
        exclude.sort_unstable();
        let preds = match &index {
            Some(idx) => idx
                .search_table(items, &query, kmax, cfg.mips_probes, &exclude)
                .into_iter()
                .map(|(_, id)| id)
                .collect(),
            None => topk_exact_table(items, &query, kmax, &exclude),
        };
        cfg.ks.iter().map(|&k| recall_at_k(&preds, &row.holdout, k)).collect()
    });

    cfg.ks
        .iter()
        .enumerate()
        .map(|(ki, &k)| RecallReport {
            k,
            recall: if per_row.is_empty() {
                0.0
            } else {
                per_row.iter().map(|r| r[ki]).sum::<f64>() / per_row.len() as f64
            },
            rows_evaluated: per_row.len(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_items() -> Mat {
        // 5 items along distinct directions with varying norms.
        Mat::from_rows(
            5,
            2,
            &[
                1.0, 0.0, // 0
                0.0, 1.0, // 1
                2.0, 0.0, // 2 (largest along x)
                0.0, 0.5, // 3
                0.7, 0.7, // 4
            ],
        )
    }

    #[test]
    fn topk_orders_by_inner_product() {
        let items = unit_items();
        let got = topk_exact(&items, &[1.0, 0.0], 3, &[]);
        assert_eq!(got, vec![2, 0, 4]);
    }

    #[test]
    fn topk_respects_exclusions() {
        let items = unit_items();
        let got = topk_exact(&items, &[1.0, 0.0], 2, &[2]);
        assert_eq!(got, vec![0, 4]);
    }

    #[test]
    fn topk_with_k_larger_than_n() {
        let items = unit_items();
        let got = topk_exact(&items, &[0.0, 1.0], 10, &[]);
        assert_eq!(got.len(), 5);
        assert_eq!(got[0], 1);
    }

    #[test]
    fn ordered_is_order_preserving() {
        let xs = [-10.0f32, -1.0, -0.0, 0.0, 0.5, 1.0, 100.0];
        for w in xs.windows(2) {
            assert!(ordered(w[0]) <= ordered(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn recall_counts_hits() {
        let preds = [1u32, 2, 3, 4];
        let holdout = [2u32, 9];
        assert_eq!(recall_at_k(&preds, &holdout, 4), 0.5);
        assert_eq!(recall_at_k(&preds, &holdout, 1), 0.0);
        assert_eq!(recall_at_k(&preds, &[], 4), 0.0);
    }

    #[test]
    fn recall_caps_denominator_at_k() {
        // 3 holdout items but K=2: a perfect K=2 list scores 1.0.
        let preds = [5u32, 6];
        let holdout = [5u32, 6, 7];
        assert_eq!(recall_at_k(&preds, &holdout, 2), 1.0);
    }
}
