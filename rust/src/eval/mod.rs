//! Evaluation: Top-K retrieval and Recall@K under strong generalization
//! (paper §4.6 and §5).
//!
//! For every test row the held-in history is folded into the embedding
//! space via Eq. (4) and the resulting vector is scored against the whole
//! item table. The paper notes exact Top-K is slow at the largest scales
//! and recommends approximate MIPS; both paths are provided:
//!
//! * [`topk_exact`] — heap-based exact top-K over all items.
//! * [`MipsIndex`] — k-means cluster-pruned approximate search (the
//!   ScaNN-style "probe the best clusters" strategy). Table 2's two
//!   largest variants were evaluated this way, with recall a lower bound.

pub mod metrics;
pub mod mips;

pub use metrics::{average_precision_at_k, ndcg_at_k, reciprocal_rank};
pub use mips::MipsIndex;

use crate::als::Trainer;
use crate::linalg::{mat::dot, Mat};
use crate::sparse::TestRow;

/// Eval knobs.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Cutoffs to report (paper: 20 and 50).
    pub ks: Vec<usize>,
    /// Use approximate MIPS instead of exact top-K.
    pub approximate: bool,
    /// MIPS: number of clusters (0 = auto ~ sqrt(n)).
    pub mips_clusters: usize,
    /// MIPS: clusters probed per query (0 = auto ~ sqrt(clusters)).
    pub mips_probes: usize,
    /// Exclude the history items from the candidate set (standard
    /// protocol: do not "recommend" what the user already has).
    pub exclude_history: bool,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            ks: vec![20, 50],
            approximate: false,
            mips_clusters: 0,
            mips_probes: 0,
            exclude_history: true,
        }
    }
}

/// Result per cutoff K.
#[derive(Clone, Debug, PartialEq)]
pub struct RecallReport {
    pub k: usize,
    pub recall: f64,
    pub rows_evaluated: usize,
}

/// Exact top-k item indices by inner product with `query`, excluding ids in
/// `exclude` (sorted). O(n·d + n log k) via a bounded min-heap.
pub fn topk_exact(items: &Mat, query: &[f32], k: usize, exclude: &[u32]) -> Vec<u32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(OrderedF32, u32)>> = BinaryHeap::with_capacity(k + 1);
    for i in 0..items.rows {
        if exclude.binary_search(&(i as u32)).is_ok() {
            continue;
        }
        let s = dot(items.row(i), query);
        if heap.len() < k {
            heap.push(Reverse((ordered(s), i as u32)));
        } else if let Some(&Reverse((min, _))) = heap.peek() {
            if ordered(s) > min {
                heap.pop();
                heap.push(Reverse((ordered(s), i as u32)));
            }
        }
    }
    let mut out: Vec<(OrderedF32, u32)> = heap.into_iter().map(|Reverse(x)| x).collect();
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out.into_iter().map(|(_, i)| i).collect()
}

/// Total-order f32 wrapper (NaN-free scores assumed; the bit trick gives a
/// total order compatible with numeric order).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct OrderedF32(pub u32);

/// Map an f32 into its order-preserving integer form.
#[inline]
pub fn ordered(x: f32) -> OrderedF32 {
    let bits = x.to_bits();
    // Flip so that the integer order matches the float order.
    OrderedF32(if bits & 0x8000_0000 != 0 { !bits } else { bits | 0x8000_0000 })
}

/// Recall@K of one prediction list against a sorted holdout set.
pub fn recall_at_k(predictions: &[u32], holdout: &[u32], k: usize) -> f64 {
    if holdout.is_empty() {
        return 0.0;
    }
    let hits = predictions
        .iter()
        .take(k)
        .filter(|p| holdout.binary_search(p).is_ok())
        .count();
    hits as f64 / holdout.len().min(k) as f64
}

/// Fold a row's history into the embedding space (Eq. 4) against a dense
/// item matrix — the strong-generalization query builder. Free-standing so
/// the parallel eval loop only borrows `Sync` data.
pub fn fold_in_dense(
    items: &Mat,
    history: &[(u32, f32)],
    gramian: &Mat,
    lambda: f32,
    alpha: f32,
    solver: crate::linalg::SolverKind,
    opts: &crate::linalg::SolveOptions,
) -> Vec<f32> {
    let d = items.cols;
    let mut a = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            a[(i, j)] = alpha * gramian[(i, j)];
        }
        a[(i, i)] += lambda;
    }
    let mut b = vec![0.0f32; d];
    for &(item, y) in history {
        let hrow = items.row(item as usize);
        for i in 0..d {
            b[i] += y * hrow[i];
            for j in i..d {
                a[(i, j)] += hrow[i] * hrow[j];
            }
        }
    }
    crate::linalg::mat::symmetrize_upper(&mut a.data, d);
    crate::linalg::solvers::solve(solver, &a, &b, opts)
}

/// Evaluate a trained model on the strong-generalization test rows.
pub fn evaluate(trainer: &Trainer, test: &[TestRow], cfg: &EvalConfig) -> Vec<RecallReport> {
    let items = trainer.h.to_dense();
    let gramian = trainer.item_gramian();
    let kmax = cfg.ks.iter().copied().max().unwrap_or(50);
    let (lambda, alpha) = (trainer.cfg.lambda, trainer.cfg.alpha);
    let solver = trainer.cfg.solver;
    let opts = trainer.cfg.solve_options();

    let index = if cfg.approximate {
        Some(MipsIndex::build(
            &items,
            cfg.mips_clusters,
            trainer.cfg.seed ^ 0x5eed,
        ))
    } else {
        None
    };

    let per_row: Vec<Vec<f64>> = crate::util::threads::parallel_map_indexed(test.len(), |t| {
        let row = &test[t];
        let query = fold_in_dense(&items, &row.history, &gramian, lambda, alpha, solver, &opts);
        let mut exclude: Vec<u32> = if cfg.exclude_history {
            row.history.iter().map(|&(c, _)| c).collect()
        } else {
            Vec::new()
        };
        exclude.sort_unstable();
        let preds = match &index {
            Some(idx) => idx.search(&items, &query, kmax, cfg.mips_probes, &exclude),
            None => topk_exact(&items, &query, kmax, &exclude),
        };
        cfg.ks.iter().map(|&k| recall_at_k(&preds, &row.holdout, k)).collect()
    });

    cfg.ks
        .iter()
        .enumerate()
        .map(|(ki, &k)| RecallReport {
            k,
            recall: if per_row.is_empty() {
                0.0
            } else {
                per_row.iter().map(|r| r[ki]).sum::<f64>() / per_row.len() as f64
            },
            rows_evaluated: per_row.len(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_items() -> Mat {
        // 5 items along distinct directions with varying norms.
        Mat::from_rows(
            5,
            2,
            &[
                1.0, 0.0, // 0
                0.0, 1.0, // 1
                2.0, 0.0, // 2 (largest along x)
                0.0, 0.5, // 3
                0.7, 0.7, // 4
            ],
        )
    }

    #[test]
    fn topk_orders_by_inner_product() {
        let items = unit_items();
        let got = topk_exact(&items, &[1.0, 0.0], 3, &[]);
        assert_eq!(got, vec![2, 0, 4]);
    }

    #[test]
    fn topk_respects_exclusions() {
        let items = unit_items();
        let got = topk_exact(&items, &[1.0, 0.0], 2, &[2]);
        assert_eq!(got, vec![0, 4]);
    }

    #[test]
    fn topk_with_k_larger_than_n() {
        let items = unit_items();
        let got = topk_exact(&items, &[0.0, 1.0], 10, &[]);
        assert_eq!(got.len(), 5);
        assert_eq!(got[0], 1);
    }

    #[test]
    fn ordered_is_order_preserving() {
        let xs = [-10.0f32, -1.0, -0.0, 0.0, 0.5, 1.0, 100.0];
        for w in xs.windows(2) {
            assert!(ordered(w[0]) <= ordered(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn recall_counts_hits() {
        let preds = [1u32, 2, 3, 4];
        let holdout = [2u32, 9];
        assert_eq!(recall_at_k(&preds, &holdout, 4), 0.5);
        assert_eq!(recall_at_k(&preds, &holdout, 1), 0.0);
        assert_eq!(recall_at_k(&preds, &[], 4), 0.0);
    }

    #[test]
    fn recall_caps_denominator_at_k() {
        // 3 holdout items but K=2: a perfect K=2 list scores 1.0.
        let preds = [5u32, 6];
        let holdout = [5u32, 6, 7];
        assert_eq!(recall_at_k(&preds, &holdout, 2), 1.0);
    }
}
