//! Additional ranking metrics beyond the paper's Recall@K.
//!
//! The paper reports Recall@{20,50}; downstream users of a MF framework
//! usually also want MRR and MAP@K, so they ship with the eval harness
//! (same inputs: a ranked prediction list + the sorted holdout set).

/// Mean reciprocal rank contribution of one ranked list: `1/rank` of the
/// first relevant prediction (0 if none within the list).
pub fn reciprocal_rank(predictions: &[u32], holdout: &[u32]) -> f64 {
    for (i, p) in predictions.iter().enumerate() {
        if holdout.binary_search(p).is_ok() {
            return 1.0 / (i + 1) as f64;
        }
    }
    0.0
}

/// Average precision at K for one ranked list.
pub fn average_precision_at_k(predictions: &[u32], holdout: &[u32], k: usize) -> f64 {
    if holdout.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    let mut sum = 0.0f64;
    for (i, p) in predictions.iter().take(k).enumerate() {
        if holdout.binary_search(p).is_ok() {
            hits += 1;
            sum += hits as f64 / (i + 1) as f64;
        }
    }
    sum / holdout.len().min(k) as f64
}

/// Normalized DCG at K with binary relevance.
pub fn ndcg_at_k(predictions: &[u32], holdout: &[u32], k: usize) -> f64 {
    if holdout.is_empty() {
        return 0.0;
    }
    let dcg: f64 = predictions
        .iter()
        .take(k)
        .enumerate()
        .filter(|(_, p)| holdout.binary_search(p).is_ok())
        .map(|(i, _)| 1.0 / ((i + 2) as f64).log2())
        .sum();
    let ideal: f64 = (0..holdout.len().min(k)).map(|i| 1.0 / ((i + 2) as f64).log2()).sum();
    dcg / ideal
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rr_finds_first_hit() {
        assert_eq!(reciprocal_rank(&[9, 3, 7], &[3, 7]), 0.5);
        assert_eq!(reciprocal_rank(&[3, 9], &[3]), 1.0);
        assert_eq!(reciprocal_rank(&[9, 8], &[3]), 0.0);
    }

    #[test]
    fn ap_perfect_list_is_one() {
        let preds = [1u32, 2, 3];
        let holdout = [1u32, 2, 3];
        assert!((average_precision_at_k(&preds, &holdout, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ap_penalizes_late_hits() {
        let early = average_precision_at_k(&[1, 9, 8], &[1], 3);
        let late = average_precision_at_k(&[9, 8, 1], &[1], 3);
        assert!(early > late);
        assert_eq!(early, 1.0);
        assert!((late - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ndcg_bounds_and_order() {
        let perfect = ndcg_at_k(&[1, 2], &[1, 2], 2);
        assert!((perfect - 1.0).abs() < 1e-12);
        let partial = ndcg_at_k(&[9, 1], &[1, 2], 2);
        assert!(partial > 0.0 && partial < 1.0);
        assert_eq!(ndcg_at_k(&[9, 8], &[1], 2), 0.0);
    }

    #[test]
    fn empty_holdout_is_zero() {
        assert_eq!(average_precision_at_k(&[1], &[], 1), 0.0);
        assert_eq!(ndcg_at_k(&[1], &[], 1), 0.0);
    }
}
