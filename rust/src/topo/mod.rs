//! TPU v3 pod topology model (paper §4.1) — the hardware substrate we
//! cannot attach, simulated (DESIGN.md §3).
//!
//! A TPU v3 pod connects up to 2048 cores: 2 cores per chip, 4 chips per
//! host board, chips in a 2-D toroidal mesh with four dedicated
//! inter-chip-interconnect (ICI) links each. Every core has 16 GiB of HBM.
//! The model exposes:
//!
//! * **capacity** — minimum #cores needed just to hold the sharded
//!   embedding tables (reproduces Fig. 6's "WebGraph-sparse needs ≥32
//!   cores to even begin training"),
//! * **collective cost** — ring-style all-gather / all-reduce time over the
//!   torus, with per-hop latency (this is what bends Fig. 6's curves away
//!   from linear),
//! * **compute rate** — per-core MXU flops for the analytic epoch-time
//!   decomposition `T(M) = T_compute/M + T_comm(M)` of §4.2.

/// Hardware constants for one TPU v3 core and its ICI links.
#[derive(Clone, Copy, Debug)]
pub struct CoreSpec {
    /// HBM capacity per core in bytes (v3: 16 GiB).
    pub hbm_bytes: u64,
    /// Usable fraction of HBM after runtime/program reservations.
    pub hbm_usable: f64,
    /// Working-set multiplier over the raw table bytes (gathered batches,
    /// XLA temporaries, double buffers). Calibrated so the Fig. 6 floors
    /// reproduce: WebGraph-dense starts at 8 cores, -sparse at 32.
    pub working_set_overhead: f64,
    /// Peak bf16 MXU throughput per core, FLOP/s (v3: the paper's "100+
    /// PFLOPs over 2048 cores" ≈ 5e13 per core).
    pub peak_flops: f64,
    /// Achieved fraction of peak on the sparse-ALS workload. Calibrated
    /// (not peak-MXU): the ALS inner loop is gather-dominated small-matmul
    /// work with host input-pipeline overhead. The value is fit to the
    /// paper's two published wall-clock anchors — WebGraph-dense trains 16
    /// epochs on 8 cores "in less than a day" (§7) and WebGraph-sparse
    /// takes ~20 min/epoch on 256 cores (§7) — see DESIGN.md §Perf.
    pub workload_efficiency: f64,
    /// ICI bandwidth per link per direction, bytes/s (v3: ~70 GB/s).
    pub link_bandwidth: f64,
    /// Achieved fraction of peak link bandwidth for the gather/scatter
    /// collectives (same calibration as `workload_efficiency`).
    pub link_efficiency: f64,
    /// Number of torus links per chip (2-D torus: 4).
    pub links: usize,
    /// Per-hop message latency, seconds.
    pub hop_latency: f64,
}

impl Default for CoreSpec {
    fn default() -> Self {
        CoreSpec {
            hbm_bytes: 16 << 30,
            hbm_usable: 0.85,
            working_set_overhead: 1.35,
            peak_flops: 5.0e13,
            workload_efficiency: 1.0e-3,
            link_bandwidth: 70.0e9,
            link_efficiency: 0.06,
            links: 4,
            hop_latency: 1.5e-6,
        }
    }
}

/// A pod slice: `num_cores` cores arranged on a (near-square) 2-D torus.
#[derive(Clone, Debug)]
pub struct Topology {
    pub num_cores: usize,
    pub core: CoreSpec,
    /// Torus dimensions in chips (rows, cols); 2 cores share a chip.
    pub torus: (usize, usize),
}

impl Topology {
    /// Build a near-square torus of `num_cores` cores.
    pub fn new(num_cores: usize) -> Topology {
        assert!(num_cores >= 1);
        let chips = num_cores.div_ceil(2).max(1);
        let mut rows = (chips as f64).sqrt().floor() as usize;
        while rows > 1 && chips % rows != 0 {
            rows -= 1;
        }
        let rows = rows.max(1);
        Topology { num_cores, core: CoreSpec::default(), torus: (rows, chips / rows) }
    }

    pub fn with_core(mut self, core: CoreSpec) -> Topology {
        self.core = core;
        self
    }

    /// Usable HBM bytes across the slice.
    pub fn total_usable_hbm(&self) -> u64 {
        (self.num_cores as f64 * self.core.hbm_bytes as f64 * self.core.hbm_usable) as u64
    }

    /// Minimum number of cores whose HBM can hold `table_bytes` of sharded
    /// embedding tables (Fig. 6's per-variant floor).
    pub fn min_cores_for(table_bytes: u64, core: &CoreSpec) -> usize {
        let per_core = (core.hbm_bytes as f64 * core.hbm_usable) as u64;
        let need = (table_bytes as f64 * core.working_set_overhead) as u64;
        (need.div_ceil(per_core.max(1)) as usize).max(1)
    }

    /// Network diameter in hops on the torus (worst-case point-to-point).
    pub fn diameter_hops(&self) -> usize {
        let (r, c) = self.torus;
        r / 2 + c / 2
    }

    /// Time for a ring all-gather where every core contributes
    /// `bytes_per_core` and ends with all `M * bytes_per_core` bytes.
    ///
    /// Bidirectional-ring schedule over the torus: (M-1) steps, each moving
    /// `bytes_per_core` over `links` parallel directions.
    /// Achieved collective bandwidth out of one core (all links).
    pub fn effective_link_bw(&self) -> f64 {
        self.core.link_bandwidth * self.core.links as f64 * self.core.link_efficiency
    }

    pub fn all_gather_time(&self, bytes_per_core: u64) -> f64 {
        let m = self.num_cores as f64;
        if self.num_cores <= 1 {
            return 0.0;
        }
        (m - 1.0) * bytes_per_core as f64 / self.effective_link_bw()
            + (m - 1.0) * self.core.hop_latency
    }

    /// Time for a ring all-reduce(sum) over a buffer of `bytes` replicated
    /// on every core (reduce-scatter + all-gather: `2(M-1)/M · bytes`).
    pub fn all_reduce_time(&self, bytes: u64) -> f64 {
        let m = self.num_cores as f64;
        if self.num_cores <= 1 {
            return 0.0;
        }
        2.0 * (m - 1.0) / m * bytes as f64 / self.effective_link_bw()
            + 2.0 * (m - 1.0) * self.core.hop_latency
    }

    /// Effective per-core compute rate (FLOP/s) on the ALS workload.
    pub fn effective_flops(&self) -> f64 {
        self.core.peak_flops * self.core.workload_efficiency
    }
}

/// Analytic epoch-time decomposition of §4.2 for Figure 6.
///
/// One epoch (both passes) costs `2(|S|d² + n·d³)` FLOPs of statistics +
/// solve work distributed over M cores, plus the sharded gather/scatter
/// traffic: every core moves O(|S|·d/M · M) = O(|S|·d) bytes — constant
/// per core — but each batch pays collective latency that grows with M.
#[derive(Clone, Copy, Debug)]
pub struct EpochCost {
    pub compute_s: f64,
    pub comm_bandwidth_s: f64,
    pub comm_latency_s: f64,
}

impl EpochCost {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_bandwidth_s + self.comm_latency_s
    }
}

/// Workload description for the cost model.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Non-zeros in the training matrix |S|.
    pub nnz: u64,
    /// Rows + cols (|U| + |I|).
    pub rows_plus_cols: u64,
    /// Embedding dimension d.
    pub dim: usize,
    /// Bytes per stored element (2 for bf16 tables).
    pub elem_bytes: u64,
    /// Dense-batch rows per step (B) — sets the number of collectives.
    pub batch_rows: usize,
    /// Dense row width (L).
    pub batch_width: usize,
}

impl Workload {
    /// Total embedding-table bytes (W and H).
    pub fn table_bytes(&self) -> u64 {
        self.rows_plus_cols * self.dim as u64 * self.elem_bytes
    }

    /// FLOPs for one full epoch (user + item pass): statistics `|S|·d²`
    /// (the h⊗h accumulation counts d² MACs per non-zero, twice for the
    /// two passes) plus solves `(|U|+|I|)·d³`.
    pub fn epoch_flops(&self) -> f64 {
        let d = self.dim as f64;
        2.0 * self.nnz as f64 * d * d + self.rows_plus_cols as f64 * d * d * d
    }
}

/// Ideal per-epoch collective volume, split per collective the way
/// [`crate::collectives::CommStats`] accounts it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IdealComm {
    pub all_gather_bytes: u64,
    pub all_reduce_bytes: u64,
}

impl IdealComm {
    pub fn total_bytes(&self) -> u64 {
        self.all_gather_bytes + self.all_reduce_bytes
    }
}

/// Predict one epoch's collective bytes (both passes) under the trainer's
/// accounting, assuming **zero batch padding**: every non-zero occupies
/// exactly one dense slot and every embedding row is solved exactly once
/// per pass.
///
/// Per the trainer's call sites:
/// * gather id all-gather — 4 B per slot per shard, both passes;
/// * gathered-row all-reduce — `d · elem_bytes` per slot, both passes;
/// * scatter all-gather — each solved row broadcast to every shard;
/// * gramian all-reduce — one `d×d` f32 reduction per pass.
///
/// Measured [`crate::collectives::CommSnapshot`] bytes exceed this by the
/// batcher's padding factor (each row's slot count rounds up to the batch
/// width L), so conformance tests assert a ratio bound, not equality —
/// and the *same* measured number must come back from every transport.
pub fn ideal_epoch_comm(w: &Workload, num_shards: usize) -> IdealComm {
    let m = num_shards as u64;
    let d = w.dim as u64;
    let id_bytes = 2 * w.nnz * 4 * m;
    let scatter_bytes = w.rows_plus_cols * d * w.elem_bytes * m;
    let row_bytes = 2 * w.nnz * d * w.elem_bytes;
    let gramian_bytes = 2 * d * d * 4;
    IdealComm {
        all_gather_bytes: id_bytes + scatter_bytes,
        all_reduce_bytes: row_bytes + gramian_bytes,
    }
}

/// Ideal per-epoch *transport* volume when the solves run on the workers
/// (`dist.compute = "worker"`), assuming zero batch padding:
///
/// * batch ship — each dense slot crosses the coordinator→owner wire
///   once as `(item, value, mask)` = 12 B, plus 4 B of segment ids per
///   dense row and 4 B of target-row ids per solved row;
/// * peer gather — upper bound of one fixed-side request (4 B id) and
///   one f32 row (`d·4` B) per slot over the worker mesh; locally hosted
///   rows and request dedup only shrink this;
/// * gramians — per pass, each shard's `d×d` f32 partial comes back and
///   each worker receives the reduced gramian in the pass announcement;
/// * epoch-end sync — both tables stream back to the coordinator once
///   as f32 rows.
///
/// Solved rows never cross the coordinator wire at all (the owner writes
/// them in place) — that is the term worker-compute deletes relative to
/// coordinator-solve. This prices real frames, so it bounds
/// [`crate::collectives::WireSnapshot::total_bytes`], not the
/// [`ideal_epoch_comm`] collective oracle; framing, opcode and ack
/// overheads make the measured number exceed it by a modest ratio.
pub fn ideal_worker_compute_wire(w: &Workload, num_shards: usize, num_workers: usize) -> u64 {
    let d = w.dim as u64;
    let slots = 2 * w.nnz;
    let batch_bytes = slots * 12 + (slots / w.batch_width as u64) * 4 + w.rows_plus_cols * 4;
    let peer_bytes = slots * (4 + d * 4);
    let gramian_bytes = 2 * (num_shards as u64 + num_workers as u64) * d * d * 4;
    let sync_bytes = w.rows_plus_cols * d * 4;
    batch_bytes + peer_bytes + gramian_bytes + sync_bytes
}

/// Predict one epoch's runtime on `topo` (Fig. 6 generator).
pub fn epoch_time(topo: &Topology, w: &Workload) -> EpochCost {
    let m = topo.num_cores as f64;
    let compute_s = w.epoch_flops() / (topo.effective_flops() * m);

    // Sharded gather: both passes together move every observed embedding to
    // its consumer — 2·|S|·d·elem_bytes contributed across all cores. The
    // ring schedule costs each core (M-1)·(per-core contribution)/bw =
    // (M-1)/M · total/bw, which tends to a *constant* as M grows — exactly
    // the paper's "for a single core this step has a constant runtime, and
    // does not get worse with more machines" (§4.2).
    let gather_bytes = 2.0 * w.nnz as f64 * w.dim as f64 * w.elem_bytes as f64;
    // Sharded scatter: all-gather of the solved rows, (|U|+|I|)·d bytes.
    let scatter_bytes = w.rows_plus_cols as f64 * w.dim as f64 * w.elem_bytes as f64;
    let ring = (m - 1.0).max(0.0) / m;
    let comm_bandwidth_s = ring * (gather_bytes + scatter_bytes) / topo.effective_link_bw();

    // Collective launches: each dense batch triggers one all-gather and one
    // all-reduce; latency per launch grows with ring length (M-1 hops).
    // This is the term that eventually *bends the curve up* at very large M.
    let slots = (w.batch_rows * w.batch_width) as f64;
    let batches_per_core = (2.0 * w.nnz as f64 / slots / m).ceil();
    let comm_latency_s = batches_per_core * 2.0 * (m - 1.0).max(0.0) * topo.core.hop_latency;

    EpochCost { compute_s, comm_bandwidth_s, comm_latency_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn webgraph_dense_workload(d: usize) -> Workload {
        Workload {
            nnz: 22_158_000_000,
            rows_plus_cols: 2 * 136_500_000,
            dim: d,
            elem_bytes: 2,
            batch_rows: 65536,
            batch_width: 16,
        }
    }

    #[test]
    fn torus_is_near_square_and_covers_chips() {
        for m in [1usize, 2, 8, 32, 128, 2048] {
            let t = Topology::new(m);
            let (r, c) = t.torus;
            assert!(r * c * 2 >= m, "torus {r}x{c} too small for {m} cores");
            assert!(c <= 4 * r.max(1) || r == 1, "degenerate torus {r}x{c}");
        }
    }

    #[test]
    fn min_cores_matches_fig6_floors() {
        let core = CoreSpec::default();
        // WebGraph-dense: 2·136.5M rows × d=128 × 2B ≈ 70 GiB → ≥ 8 cores
        // at the paper's observed floor (tables + working set).
        let dense_tables = 2 * 136_500_000u64 * 128 * 2;
        let m = Topology::min_cores_for(dense_tables, &core);
        assert!((4..=8).contains(&m), "dense min cores = {m}");
        // WebGraph-sparse: 2·365.4M × 128 × 2 ≈ 187 GiB → tens of cores.
        let sparse_tables = 2 * 365_400_000u64 * 128 * 2;
        let m = Topology::min_cores_for(sparse_tables, &core);
        assert!((13..=32).contains(&m), "sparse min cores = {m}");
    }

    #[test]
    fn all_reduce_scales_with_bytes_and_is_zero_single_core() {
        let t = Topology::new(8);
        assert_eq!(t.all_reduce_time(0) > 0.0, true); // latency term only
        assert!(t.all_reduce_time(1 << 20) < t.all_reduce_time(1 << 24));
        let single = Topology::new(1);
        assert_eq!(single.all_reduce_time(1 << 20), 0.0);
    }

    #[test]
    fn epoch_time_decreases_then_flattens() {
        // Fig. 6's qualitative shape: near-linear speedup at small M,
        // diminishing returns at large M.
        let w = webgraph_dense_workload(128);
        let t8 = epoch_time(&Topology::new(8), &w).total();
        let t16 = epoch_time(&Topology::new(16), &w).total();
        let t32 = epoch_time(&Topology::new(32), &w).total();
        let t1024 = epoch_time(&Topology::new(1024), &w).total();
        let t2048 = epoch_time(&Topology::new(2048), &w).total();
        assert!(t16 < t8 && t32 < t16, "small-M speedup missing: {t8} {t16} {t32}");
        let early_speedup = t8 / t16;
        let late_speedup = t1024 / t2048;
        assert!(early_speedup > 1.5, "early speedup {early_speedup}");
        assert!(late_speedup < early_speedup, "late speedup should flatten");
    }

    #[test]
    fn ideal_comm_formula() {
        let w = Workload {
            nnz: 100,
            rows_plus_cols: 10,
            dim: 4,
            elem_bytes: 2,
            batch_rows: 8,
            batch_width: 4,
        };
        let c = ideal_epoch_comm(&w, 4);
        // ids: 2·100·4·4 = 3200; scatter: 10·4·2·4 = 320
        assert_eq!(c.all_gather_bytes, 3200 + 320);
        // rows: 2·100·4·2 = 1600; gramians: 2·16·4 = 128
        assert_eq!(c.all_reduce_bytes, 1600 + 128);
        assert_eq!(c.total_bytes(), 3200 + 320 + 1600 + 128);
        // More shards → strictly more broadcast traffic, same reduce.
        let c8 = ideal_epoch_comm(&w, 8);
        assert!(c8.all_gather_bytes > c.all_gather_bytes);
        assert_eq!(c8.all_reduce_bytes, c.all_reduce_bytes);
    }

    #[test]
    fn worker_compute_wire_formula() {
        let w = Workload {
            nnz: 100,
            rows_plus_cols: 10,
            dim: 4,
            elem_bytes: 2,
            batch_rows: 8,
            batch_width: 4,
        };
        let b = ideal_worker_compute_wire(&w, 4, 2);
        // batches: 200·12 + 50·4 + 10·4 = 2640; peer: 200·(4+16) = 4000;
        // gramians: 2·(4+2)·16·4 = 768; sync: 10·4·4 = 160
        assert_eq!(b, 2640 + 4000 + 768 + 160);
        // More shards/workers → more gramian frames, all else equal.
        assert!(ideal_worker_compute_wire(&w, 8, 4) > b);
    }

    #[test]
    fn epoch_flops_formula() {
        let w = Workload {
            nnz: 100,
            rows_plus_cols: 10,
            dim: 4,
            elem_bytes: 2,
            batch_rows: 8,
            batch_width: 4,
        };
        // 2·100·16 + 10·64 = 3200 + 640
        assert_eq!(w.epoch_flops(), 3840.0);
    }

    #[test]
    fn dense_epoch_time_magnitude_plausible() {
        // Paper: WebGraph-dense trains one epoch in well under an hour on
        // 8-64 cores (a full 16-epoch run < 1 day on 8 cores ≈ 90 min/epoch).
        let w = webgraph_dense_workload(128);
        let t8 = epoch_time(&Topology::new(8), &w).total();
        assert!(t8 > 60.0 && t8 < 7200.0, "t8={t8}s out of plausible range");
    }
}
