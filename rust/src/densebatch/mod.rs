//! Dense Batching (paper §4.3, Figure 3).
//!
//! XLA requires static tensor shapes, so variable-length sparse rows cannot
//! be fed to the TPU directly, and padding every row to the global maximum
//! wastes memory on a long-tailed length distribution. ALX instead breaks
//! each sparse row into multiple fixed-width *dense rows* of length `L`
//! (8 or 16 work well per the paper) and keeps a mapping from dense rows
//! back to their source (sparse) row.
//!
//! A [`DenseBatch`] is the unit fed to a TPU core: `B` dense rows of `L`
//! slots each, a validity mask, and a segment id per dense row. The solve
//! stage segment-sums the per-dense-row sufficient statistics back into
//! per-source-row statistics — in the XLA engine this is a one-hot matmul
//! so the shapes stay static.

use crate::sparse::{Csr, RowMatrix};

/// A fixed-shape batch of dense rows (one SPMD step's input).
#[derive(Clone, Debug, PartialEq)]
pub struct DenseBatch {
    /// Dense rows per batch (B).
    pub rows: usize,
    /// Slots per dense row (L).
    pub width: usize,
    /// Item ids, row-major `[B*L]`; padded slots hold 0.
    pub items: Vec<u32>,
    /// Labels y, `[B*L]`; padded slots hold 0.
    pub values: Vec<f32>,
    /// 1.0 for valid slots, 0.0 for padding, `[B*L]`.
    pub mask: Vec<f32>,
    /// Segment id of each dense row, `[B]` (in `0..num_segments`); padded
    /// dense rows point at segment 0 with an all-zero mask.
    pub segments: Vec<u32>,
    /// Source (sparse) row id of each segment, `[num_segments]`.
    pub segment_rows: Vec<u32>,
}

impl DenseBatch {
    /// Number of distinct source rows solved by this batch.
    pub fn num_segments(&self) -> usize {
        self.segment_rows.len()
    }

    /// Number of valid (unpadded) slots.
    pub fn valid_slots(&self) -> usize {
        self.mask.iter().filter(|&&m| m != 0.0).count()
    }

    /// Fraction of slots wasted on padding.
    pub fn padding_waste(&self) -> f64 {
        1.0 - self.valid_slots() as f64 / (self.rows * self.width) as f64
    }
}

/// Splits a sparse matrix into a stream of fixed-shape [`DenseBatch`]es.
#[derive(Clone, Debug)]
pub struct DenseBatcher {
    /// Dense rows per batch (B). Static at artifact-compile time.
    pub batch_rows: usize,
    /// Dense row width (L). Static at artifact-compile time.
    pub width: usize,
}

impl DenseBatcher {
    pub fn new(batch_rows: usize, width: usize) -> Self {
        assert!(batch_rows > 0 && width > 0);
        DenseBatcher { batch_rows, width }
    }

    /// Number of dense rows a sparse row of length `len` expands into.
    #[inline]
    pub fn dense_rows_for(&self, len: usize) -> usize {
        len.div_ceil(self.width).max(1)
    }

    /// Batch the given sparse rows (by id) of `matrix`. Rows longer than
    /// `batch_rows * width` are truncated to fit one batch (the artifact
    /// shape is the hard limit — pick B·L above the max row length, or
    /// accept truncation like any fixed-capacity system).
    ///
    /// A sparse row is never split across batches, so every batch's
    /// segment-sum is complete and the solve for that row is exact.
    ///
    /// Generic over [`RowMatrix`], so the same batching runs over a
    /// monolithic [`Csr`] or a [`crate::sparse::ShardedCsr`].
    pub fn batch_rows_of<M: RowMatrix + ?Sized>(
        &self,
        matrix: &M,
        row_ids: &[u32],
    ) -> Vec<DenseBatch> {
        let mut out = Vec::new();
        let mut cur = self.empty_batch();
        let mut next_dense = 0usize;
        for &row in row_ids {
            let len = matrix.row_len(row as usize);
            if len == 0 {
                continue; // nothing to solve for an empty row
            }
            let mut need = self.dense_rows_for(len);
            let capacity = self.batch_rows;
            if need > capacity {
                need = capacity; // truncate over-long rows
            }
            if next_dense + need > capacity {
                out.push(std::mem::replace(&mut cur, self.empty_batch()));
                next_dense = 0;
            }
            let seg = cur.segment_rows.len() as u32;
            cur.segment_rows.push(row);
            let idx = matrix.row_indices(row as usize);
            let val = matrix.row_values(row as usize);
            let take = len.min(need * self.width);
            for k in 0..take {
                let dr = next_dense + k / self.width;
                let slot = dr * self.width + k % self.width;
                cur.items[slot] = idx[k];
                cur.values[slot] = val[k];
                cur.mask[slot] = 1.0;
            }
            for dr in next_dense..next_dense + need {
                cur.segments[dr] = seg;
            }
            next_dense += need;
        }
        if !cur.segment_rows.is_empty() {
            out.push(cur);
        }
        out
    }

    fn empty_batch(&self) -> DenseBatch {
        DenseBatch {
            rows: self.batch_rows,
            width: self.width,
            items: vec![0; self.batch_rows * self.width],
            values: vec![0.0; self.batch_rows * self.width],
            mask: vec![0.0; self.batch_rows * self.width],
            segments: vec![0; self.batch_rows],
            segment_rows: Vec::new(),
        }
    }

    /// Padding waste of dense batching over a whole matrix vs. the naive
    /// strategy of padding every row to the global max length (§4.3's
    /// motivating comparison). Returns `(dense_waste, naive_waste)` as
    /// fractions of allocated slots.
    pub fn waste_comparison(&self, matrix: &Csr) -> (f64, f64) {
        let mut valid = 0usize;
        let mut dense_slots = 0usize;
        let mut max_len = 0usize;
        let mut nonempty = 0usize;
        for r in 0..matrix.rows {
            let len = matrix.row_len(r);
            if len == 0 {
                continue;
            }
            nonempty += 1;
            valid += len;
            dense_slots += self.dense_rows_for(len) * self.width;
            max_len = max_len.max(len);
        }
        if valid == 0 {
            return (0.0, 0.0);
        }
        let naive_slots = nonempty * max_len;
        (
            1.0 - valid as f64 / dense_slots as f64,
            1.0 - valid as f64 / naive_slots as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix_with_rows(rows: &[Vec<u32>]) -> Csr {
        let mut t = Vec::new();
        for (r, cols) in rows.iter().enumerate() {
            for &c in cols {
                t.push((r as u32, c, (r + 1) as f32));
            }
        }
        let max_col = rows.iter().flatten().copied().max().unwrap_or(0) as usize + 1;
        Csr::from_coo(rows.len(), max_col, &t)
    }

    #[test]
    fn short_rows_fit_one_dense_row() {
        let m = matrix_with_rows(&[vec![1, 2], vec![3]]);
        let b = DenseBatcher::new(4, 4);
        let batches = b.batch_rows_of(&m, &[0, 1]);
        assert_eq!(batches.len(), 1);
        let batch = &batches[0];
        assert_eq!(batch.num_segments(), 2);
        assert_eq!(batch.items[0..2], [1, 2]);
        assert_eq!(batch.mask[0..4], [1.0, 1.0, 0.0, 0.0]);
        assert_eq!(batch.items[4], 3);
        assert_eq!(batch.segments[0], 0);
        assert_eq!(batch.segments[1], 1);
    }

    #[test]
    fn long_row_spans_multiple_dense_rows() {
        let m = matrix_with_rows(&[(0..10).collect()]);
        let b = DenseBatcher::new(4, 4);
        let batches = b.batch_rows_of(&m, &[0]);
        assert_eq!(batches.len(), 1);
        let batch = &batches[0];
        // 10 items over width 4 → 3 dense rows, all segment 0.
        assert_eq!(batch.segments[0..3], [0, 0, 0]);
        assert_eq!(batch.valid_slots(), 10);
        let got: Vec<u32> = batch
            .items
            .iter()
            .zip(&batch.mask)
            .filter(|&(_, &m)| m != 0.0)
            .map(|(&i, _)| i)
            .collect();
        assert_eq!(got, (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn rows_never_split_across_batches() {
        // Batch capacity 2 dense rows; a 2-dense-row item after a 1-dense-row
        // item must start a new batch.
        let m = matrix_with_rows(&[vec![1, 2], (10..16).collect()]);
        let b = DenseBatcher::new(2, 4);
        let batches = b.batch_rows_of(&m, &[0, 1]);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].num_segments(), 1);
        assert_eq!(batches[1].num_segments(), 1);
        assert_eq!(batches[1].valid_slots(), 6);
    }

    #[test]
    fn empty_rows_are_skipped() {
        let m = Csr::from_coo(3, 5, &[(1, 1, 1.0)]);
        let b = DenseBatcher::new(2, 2);
        let batches = b.batch_rows_of(&m, &[0, 1, 2]);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].num_segments(), 1);
        assert_eq!(batches[0].segment_rows, vec![1]);
    }

    #[test]
    fn overlong_row_truncates_to_batch_capacity() {
        let m = matrix_with_rows(&[(0..100).collect()]);
        let b = DenseBatcher::new(2, 4); // capacity 8 slots
        let batches = b.batch_rows_of(&m, &[0]);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].valid_slots(), 8);
    }

    #[test]
    fn values_and_mask_align() {
        let m = matrix_with_rows(&[vec![7, 8, 9]]);
        let b = DenseBatcher::new(1, 4);
        let batch = &b.batch_rows_of(&m, &[0])[0];
        assert_eq!(batch.values[0..3], [1.0, 1.0, 1.0]);
        assert_eq!(batch.values[3], 0.0);
        assert_eq!(batch.padding_waste(), 0.25);
    }

    #[test]
    fn dense_batching_beats_naive_padding_on_long_tail() {
        // 1 giant row + many short rows: naive pads everything to 64.
        let mut rows: Vec<Vec<u32>> = vec![(0..64).collect()];
        for _ in 0..50 {
            rows.push(vec![1, 2, 3]);
        }
        let m = matrix_with_rows(&rows);
        let b = DenseBatcher::new(16, 8);
        let (dense_waste, naive_waste) = b.waste_comparison(&m);
        assert!(dense_waste < 0.7, "dense_waste={dense_waste}");
        assert!(naive_waste > 0.9, "naive_waste={naive_waste}");
        assert!(dense_waste < naive_waste);
    }

    #[test]
    fn all_segments_have_valid_source_rows() {
        let m = matrix_with_rows(&[vec![1], vec![2, 3], vec![4, 5, 6], vec![7]]);
        let b = DenseBatcher::new(3, 2);
        for batch in b.batch_rows_of(&m, &[0, 1, 2, 3]) {
            for &sr in &batch.segment_rows {
                assert!((sr as usize) < m.rows);
            }
            for (dr, &seg) in batch.segments.iter().enumerate() {
                let valid = batch.mask[dr * batch.width..(dr + 1) * batch.width]
                    .iter()
                    .any(|&m| m != 0.0);
                if valid {
                    assert!((seg as usize) < batch.num_segments());
                }
            }
        }
    }
}
