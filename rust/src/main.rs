//! `alx` — the launcher binary (L3 leader entrypoint).
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! alx generate  --variant in-dense --scale 0.01        # build a dataset
//! alx bank      --data g.alxcsr02 --out g.alxbank      # shard-major bank
//! alx train     [--config cfg.toml] [--key value ...]  # train + eval
//! alx train     --source edge-list --data edges.txt    # train on a file
//! alx train     --stream --spill --data g.alxcsr02     # out-of-core matrix
//! alx train     --stream --spill --spill-model ...     # matrix AND model out of core
//! alx train     --checkpoint-every 4 --eval-every 2    # session hooks
//! alx train     --resume run.ckpt                      # continue a run
//! alx worker    --port 7001                            # dist table-shard server
//! alx launch    --num-workers 4 --epochs 2             # multi-process training
//! alx serve     --checkpoint run.ckpt --port 7878      # Top-K server
//! alx serve     --w-bank w.alxtab --h-bank h.alxtab    # serve out of core
//! alx query     --port 7878 --user 42 --k 10           # one Top-K query
//! alx table1    --scale 0.001                          # Table 1 stats
//! alx table2    --scale 0.002 --epochs 8               # Table 2 recalls
//! alx fig4      --lambda 1e-4                          # precision study
//! alx fig5      --dims 16,32,64                        # solver study
//! alx fig6                                             # scaling analysis
//! alx grid      --coarse                               # λ×α grid search
//! alx info                                             # topology/env info
//! ```
//!
//! `train` is a thin driver over [`TrainSession`]: `--checkpoint-every`,
//! `--eval-every` and `--early-stop` install the matching epoch hooks, and
//! `--resume <ckpt>` restores the tables and epoch counter, then trains to
//! the configured `--epochs` total.

use alx::als::TrainConfig;
use alx::collectives::Collectives;
use alx::config::{AlxConfig, KvConfig};
use alx::coordinator::{grid_search, GridSpec, TrainSession};
use alx::harness;
use alx::serving::{serve, Client, Response, ServeModel, TopKRequest};
use alx::topo::Topology;
use alx::util::stats::human_bytes;
use alx::webgraph::{generate, Variant, VariantSpec};

/// Minimal `--key value` argument list (offline substitute for clap).
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.push((key.to_string(), argv[i + 1].clone()));
                    i += 2;
                } else {
                    flags.push((key.to_string(), "true".to_string()));
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

/// Resolve an AlxConfig from `--config` plus CLI overrides.
fn resolve_config(args: &Args) -> anyhow::Result<AlxConfig> {
    let mut kv = match args.get("config") {
        Some(path) => KvConfig::load(path)?,
        None => KvConfig::default(),
    };
    // CLI overrides (flat names mapped onto the sectioned keys).
    let map = [
        ("variant", "dataset.variant"),
        ("scale", "dataset.scale"),
        ("data-seed", "dataset.seed"),
        ("source", "data.source"),
        ("data", "data.path"),
        ("stream", "data.streaming"),
        ("ingest-budget-mb", "data.ingest_budget_mb"),
        ("chunk-rows", "data.chunk_rows"),
        ("spill", "data.spill"),
        ("spill-dir", "data.spill_dir"),
        ("resident-shards", "data.resident_shards"),
        ("spill-model", "model.spill"),
        ("model-spill-dir", "model.spill_dir"),
        ("resident-table-shards", "model.resident_table_shards"),
        ("checkpoint-every", "session.checkpoint_every"),
        ("eval-every", "session.eval_every"),
        ("early-stop", "session.early_stop_patience"),
        ("early-stop-recall", "session.early_stop_recall_k"),
        ("early-stop-recall-patience", "session.early_stop_recall_patience"),
        ("early-stop-recall-every", "session.early_stop_recall_every"),
        ("checkpoint", "session.checkpoint_path"),
        ("cores", "topology.cores"),
        ("dim", "train.dim"),
        ("epochs", "train.epochs"),
        ("lambda", "train.lambda"),
        ("alpha", "train.alpha"),
        ("solver", "train.solver"),
        ("solver-engine", "solver.engine"),
        ("block-dim", "solver.block_dim"),
        ("precision", "train.precision"),
        ("batch-rows", "train.batch_rows"),
        ("batch-width", "train.batch_width"),
        ("cg-iters", "train.cg_iters"),
        ("seed", "train.seed"),
        ("threads", "train.threads"),
        ("feed-depth", "train.feed_depth"),
        ("engine", "engine.kind"),
        ("artifacts", "engine.artifacts_dir"),
        ("approximate", "eval.approximate"),
        ("failpoints", "fault.points"),
        ("dist", "dist.mode"),
        ("topology", "dist.topology"),
        ("workers", "dist.workers"),
        ("heartbeat-ms", "dist.heartbeat_ms"),
        ("compute", "dist.compute"),
        ("port", "serve.port"),
        ("serve-threads", "serve.threads"),
        ("batch-window-us", "serve.batch_window_us"),
        ("batch-max", "serve.batch_max"),
        ("queue-depth", "serve.queue_depth"),
        ("cache-entries", "serve.cache_entries"),
        ("cache-ttl-ms", "serve.cache_ttl_ms"),
        ("mips-clusters", "serve.mips_clusters"),
        ("mips-probes", "serve.mips_probes"),
        ("serve-seed", "serve.seed"),
    ];
    for (flag, key) in map {
        if let Some(v) = args.get(flag) {
            // `--solver ialspp` selects the subspace *engine*; the inner
            // per-block factorization stays on `train.solver`.
            if flag == "solver" && matches!(v, "ialspp" | "ials++") {
                kv.set("solver.engine", "ialspp");
                continue;
            }
            kv.set(key, v);
        }
    }
    let cfg = AlxConfig::from_kv(&kv)?;
    // Arm fault injection before any IO happens. A live spec against a
    // binary without the `failpoints` feature is a hard error here, not a
    // silently-ignored flag.
    alx::util::fault::configure(&cfg.fault_points)
        .map_err(|e| anyhow::anyhow!("--failpoints '{}': {e}", cfg.fault_points))?;
    Ok(cfg)
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let spec = VariantSpec::preset(cfg.variant).scaled(cfg.scale);
    let g = generate(&spec, cfg.data_seed);
    println!(
        "{}: {} nodes, {} edges, locality {:.1}%, {} filtered",
        cfg.variant.name(),
        g.nodes(),
        g.edges(),
        100.0 * g.locality(),
        g.filtered_nodes
    );
    if let Some(path) = args.get("out") {
        let format = args.get("format").unwrap_or("csr02");
        anyhow::ensure!(
            matches!(format, "csr02" | "csr01"),
            "--format {format}: expected csr02|csr01"
        );
        alx::util::fault::failpoint("tool.generate")?;
        // Stage + rename like every other writer: an interrupted generate
        // must never leave a truncated dataset at the published path.
        alx::util::durable::write_atomic(
            std::path::Path::new(path),
            &format!("dataset {path}"),
            |f| match format {
                // The chunked format streams back through `alx train --stream`.
                "csr02" => alx::sparse::write_chunked(&g.adjacency, &mut *f, cfg.chunk_rows),
                _ => g.adjacency.write_to(f),
            },
        )?;
        println!("wrote {path} ({format})");
    }
    Ok(())
}

/// Convert any supported input (text edge list, `ALXCSR01`, `ALXCSR02`)
/// to the chunked `ALXCSR02` format. `ALXCSR02` inputs are re-chunked
/// stream-to-stream in bounded memory; the other formats are loaded whole
/// first (they are monolithic on disk by definition).
fn cmd_convert(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let input = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("convert needs --data <input file>"))?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("convert needs --out <output file>"))?;
    anyhow::ensure!(input != out, "--data and --out must differ");
    alx::util::fault::failpoint("tool.convert")?;
    let chunk_rows = cfg.chunk_rows;

    // Sniff the magic to pick the path.
    let mut head = [0u8; 8];
    {
        use std::io::Read;
        let mut f = std::fs::File::open(input)
            .map_err(|e| anyhow::anyhow!("open {input}: {e}"))?;
        let n = f.read(&mut head)?;
        if n < 8 {
            head = [0u8; 8]; // too short for any binary magic: treat as text
        }
    }
    // Write to a sibling temp file, then rename: `--data` and `--out`
    // naming the same file through different spellings (relative vs
    // absolute, symlinks, `dir/../`) must never truncate the input
    // before it has been read.
    let tmp = format!("{out}.tmp.{}", std::process::id());
    let convert = || -> anyhow::Result<(usize, usize, u64, u64)> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        let dims = if &head == alx::sparse::ALXCSR02_MAGIC {
            // Stream-to-stream re-chunk: one input + one output chunk.
            let mut r = alx::sparse::ChunkedReader::open(input, 0)
                .map_err(|e| anyhow::anyhow!("read {input}: {e}"))?;
            let h = *r.header();
            let mut cw =
                alx::sparse::ChunkedWriter::new(&mut w, h.rows, h.cols, h.nnz, chunk_rows)?;
            while let Some(chunk) =
                r.next_chunk().map_err(|e| anyhow::anyhow!("read {input}: {e}"))?
            {
                for i in 0..chunk.row_count() {
                    let (_, idx, val) = chunk.row(i);
                    cw.push_row(idx, val)?;
                }
            }
            cw.finish()?;
            (h.rows, h.cols, h.nnz, (h.rows as u64).div_ceil(chunk_rows as u64))
        } else {
            use alx::data::DataSource;
            let ds = alx::data::EdgeListSource::new(input).load()?;
            let m = &ds.matrix;
            alx::sparse::write_chunked(m, &mut w, chunk_rows)?;
            let chunks = (m.rows as u64).div_ceil(chunk_rows as u64);
            (m.rows, m.cols, m.nnz() as u64, chunks)
        };
        use std::io::Write;
        w.flush()?;
        // fsync before the rename publishes the file: rename durability is
        // only as good as the data it points at.
        w.get_ref().sync_all()?;
        Ok(dims)
    };
    let (rows, cols, nnz, chunks) = match convert() {
        Ok(dims) => dims,
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
    };
    std::fs::rename(&tmp, out)
        .map_err(|e| anyhow::anyhow!("rename {tmp} -> {out}: {e}"))?;
    println!(
        "converted {input} -> {out}: {rows}x{cols}, {nnz} entries, {chunks} chunks \
         of {chunk_rows} rows (ALXCSR02)"
    );
    Ok(())
}

/// Convert an `ALXCSR02` stream into a shard-major `ALXBANK01` bank
/// (optionally its transpose bank too) without ever materializing the
/// matrix: rows flow chunk by chunk into a spilling shard builder, which
/// writes each shard out the moment it completes.
fn cmd_bank(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let input = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("bank needs --data <input file.alxcsr02>"))?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("bank needs --out <output file.alxbank>"))?;
    anyhow::ensure!(input != out, "--data and --out must differ");
    alx::util::fault::failpoint("tool.bank")?;
    let shards = args.get_or("shards", cfg.cores)?;
    anyhow::ensure!(shards >= 1, "--shards must be >= 1");

    let budget = (cfg.ingest_budget_mb as u64) << 20;
    let mut r = alx::sparse::ChunkedReader::open(input, budget)
        .map_err(|e| anyhow::anyhow!("read {input}: {e}"))?;
    let h = *r.header();
    // Write to a sibling temp file, then rename (same crash/self-overwrite
    // discipline as `alx convert`).
    let tmp = format!("{out}.tmp.{}", std::process::id());
    let mut build = || -> anyhow::Result<()> {
        let mut b = alx::sparse::ShardedCsrBuilder::new(h.rows, h.cols, shards);
        b.spill_to(&tmp)?;
        while let Some(chunk) =
            r.next_chunk().map_err(|e| anyhow::anyhow!("read {input}: {e}"))?
        {
            for i in 0..chunk.row_count() {
                let (_, idx, val) = chunk.row(i);
                b.push_row(idx, val);
            }
        }
        b.finish_spilled()?;
        Ok(())
    };
    if let Err(e) = build() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, out).map_err(|e| anyhow::anyhow!("rename {tmp} -> {out}: {e}"))?;
    println!(
        "banked {input} -> {out}: {}x{}, {} entries, {shards} shards (ALXBANK01)",
        h.rows, h.cols, h.nnz
    );
    if let Some(tout) = args.get("transpose-out") {
        anyhow::ensure!(tout != out && tout != input, "--transpose-out must be a new file");
        // Bounded by --ingest-budget-mb, or the honest default when unset
        // (an unbounded group would materialize the whole transpose).
        let t_budget = match budget {
            0 => alx::sparse::DEFAULT_TRANSPOSE_SCRATCH_BYTES,
            b => b,
        };
        let bank = alx::sparse::CsrBank::open(out)?;
        // write_transpose_bank_budgeted stages into its own sibling tmp
        // file, fsyncs and renames, so no outer tmp dance is needed here.
        bank.write_transpose_bank_budgeted(tout, shards, t_budget)?;
        println!("transpose bank -> {tout}");
    }
    Ok(())
}

/// Structurally validate on-disk ALX artifacts (any of `ALXCSR01`,
/// `ALXCSR02`, `ALXBANK01`, `ALXTAB01`, `ALXCKPT2`): sniff the magic,
/// walk the headers/directories/chunks, and exit non-zero on the first
/// sign of truncation or corruption.
fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.positional.is_empty(),
        "verify needs at least one file: alx verify <path> [<path> ...]"
    );
    let mut failed = 0usize;
    for path in &args.positional {
        match alx::verify::verify_file(path) {
            Ok(r) => println!("{path}: {} ok — {}", r.format, r.summary),
            Err(e) => {
                eprintln!("{path}: FAILED — {e}");
                failed += 1;
            }
        }
    }
    anyhow::ensure!(
        failed == 0,
        "{failed} of {} file(s) failed verification",
        args.positional.len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let mut dataset_desc = if cfg.data_streaming {
        format!("streaming:{}", cfg.data_path)
    } else {
        match cfg.data_source.as_str() {
            "webgraph" => format!("{} scale={}", cfg.variant.name(), cfg.scale),
            _ => format!("{}:{}", cfg.data_source, cfg.data_path),
        }
    };
    if cfg.data_spill {
        dataset_desc.push_str(&format!(" [spill, resident_shards={}]", cfg.resident_shards));
    }
    if cfg.model_spill {
        dataset_desc.push_str(&format!(
            " [spill-model, resident_table_shards={}]",
            cfg.resident_table_shards
        ));
    }
    let solver_desc = match cfg.train.engine {
        alx::prelude::EngineKind::Qr => cfg.train.solver.name().to_string(),
        alx::prelude::EngineKind::IalsPp => {
            format!("ialspp(p={},inner={})", cfg.train.block_dim, cfg.train.solver.name())
        }
    };
    println!(
        "training {dataset_desc} d={} epochs={} λ={:.0e} α={:.0e} solver={solver_desc} precision={} engine={} cores={}",
        cfg.train.dim,
        cfg.train.epochs,
        cfg.train.lambda,
        cfg.train.alpha,
        cfg.train.precision.name(),
        cfg.engine,
        cfg.cores,
    );
    let mut session = match args.get("resume") {
        Some(path) => {
            let s = TrainSession::resume(path, cfg)?;
            println!(
                "resumed from {path} at epoch {} ({} epochs remaining)",
                s.trainer.current_epoch(),
                s.remaining_epochs()
            );
            s
        }
        None => TrainSession::from_config(cfg)?,
    };
    let report = session.run()?;
    // Final checkpoint whenever the user asked for checkpointing anywhere:
    // periodic hooks, an explicit --checkpoint flag, or a non-default
    // session.checkpoint_path in the config file.
    let want_final = session.cfg.checkpoint_every > 0
        || args.has("checkpoint")
        || session.cfg.checkpoint_path != AlxConfig::default().checkpoint_path;
    if want_final {
        session.checkpoint(&session.cfg.checkpoint_path)?;
        println!("checkpoint written to {}", session.cfg.checkpoint_path);
    }
    // gather/stats/solve/scatter are busy-time summed across worker
    // threads, so their total can exceed wall(s) × 1000.
    println!(
        "\nepoch  objective        wall(s)  simulated(s)  gather(ms)  stats(ms)  solve(ms)  scatter(ms)  comm"
    );
    for h in &report.history {
        println!(
            "{:>5}  {:>14.2}  {:>8.2}  {:>12.2}  {:>10.0}  {:>9.0}  {:>9.0}  {:>11.0}  {}",
            h.epoch,
            h.objective.unwrap_or(f64::NAN),
            h.seconds,
            h.simulated_seconds,
            h.gather_ms,
            h.stats_ms,
            h.solve_ms,
            h.scatter_ms,
            human_bytes(h.comm_bytes)
        );
    }
    let final_epoch = report.history.last().map(|h| h.epoch);
    for (epoch, recalls) in session.eval_log() {
        if Some(*epoch) == final_epoch {
            continue; // identical to the final report printed below
        }
        for r in recalls {
            println!("epoch {epoch:>3}: Recall@{:<3} = {:.4}", r.k, r.recall);
        }
    }
    println!();
    for r in &report.recalls {
        println!("Recall@{:<3} = {:.4}  ({} test rows)", r.k, r.recall, r.rows_evaluated);
    }
    if session.stopped() {
        println!("(stopped early: objective plateau)");
    }
    // Per-collective traffic: the same numbers for every transport — a
    // tcp run must print exactly what its local twin prints.
    let c = &report.comm;
    println!(
        "\ncollectives ({} transport):\n\
         {:<12} {:>8}  {:>12}\n\
         {:<12} {:>8}  {:>12}\n\
         {:<12} {:>8}  {:>12}\n\
         {:<12} {:>8}  {:>12}",
        session.trainer.collectives().name(),
        "collective", "ops", "bytes",
        "all-gather", c.all_gather_ops, human_bytes(c.all_gather_bytes),
        "all-reduce", c.all_reduce_ops, human_bytes(c.all_reduce_bytes),
        "total", c.all_gather_ops + c.all_reduce_ops, human_bytes(c.total_bytes()),
    );
    if let Some(ing) = &report.ingest {
        let budget = match ing.budget_bytes {
            0 => "unbounded".to_string(),
            b => human_bytes(b),
        };
        println!(
            "\nstreaming ingest: {} chunks, peak chunk {} (budget {budget})",
            ing.chunks,
            human_bytes(ing.peak_chunk_bytes),
        );
    }
    if let Some(sp) = &report.spill {
        println!(
            "spilled shards: banks {}, {} shard faults, {} prefetch hits ({:.0}% hit rate), \
             {} prefetches",
            human_bytes(sp.bank_bytes),
            sp.shard_faults,
            sp.prefetch_hits,
            100.0 * sp.hit_rate(),
            sp.prefetches,
        );
    }
    if let Some(ts) = &report.table_spill {
        println!(
            "spilled model:  banks {}, {} table-shard faults, {} prefetch hits \
             ({:.0}% hit rate), {} prefetches",
            human_bytes(ts.bank_bytes),
            ts.shard_faults,
            ts.prefetch_hits,
            100.0 * ts.hit_rate(),
            ts.prefetches,
        );
    }
    if report.peak_rss_bytes > 0 {
        println!("peak RSS: {}", human_bytes(report.peak_rss_bytes));
    }
    println!("\nprofiler breakdown:\n{}", session.trainer.profiler.report());
    Ok(())
}

/// Serve Top-K recommendations from a trained model over TCP. The model
/// comes from an `ALXCKPT2` checkpoint (optionally spilled to `ALXTAB01`
/// banks with `--spill-model`) or directly from a pair of existing banks
/// (`--w-bank`/`--h-bank`), which serve demand-paged without ever loading
/// the full tables. Blocks until a client sends SHUTDOWN.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let shards = args.get_or("shards", cfg.cores)?;
    anyhow::ensure!(shards >= 1, "--shards must be >= 1");
    let serve_cfg = cfg.serve.clone();
    let model = if let Some(ckpt) = args.get("checkpoint") {
        let spill_dir;
        let spill = if cfg.model_spill {
            spill_dir = if cfg.model_spill_dir.is_empty() {
                std::env::temp_dir().join(format!("alx_serve_{}", std::process::id()))
            } else {
                std::path::PathBuf::from(&cfg.model_spill_dir)
            };
            Some((spill_dir.as_path(), cfg.resident_table_shards))
        } else {
            None
        };
        ServeModel::from_checkpoint(
            std::path::Path::new(ckpt),
            shards,
            spill,
            serve_cfg.mips_clusters,
            serve_cfg.seed,
        )
        .map_err(|e| anyhow::anyhow!("load {ckpt}: {e} (try `alx verify {ckpt}`)"))?
    } else {
        let (Some(w), Some(h)) = (args.get("w-bank"), args.get("h-bank")) else {
            anyhow::bail!("serve needs --checkpoint <file> or both --w-bank and --h-bank");
        };
        ServeModel::from_banks(
            std::path::Path::new(w),
            std::path::Path::new(h),
            cfg.resident_table_shards,
            serve_cfg.mips_clusters,
            serve_cfg.seed,
        )
        .map_err(|e| anyhow::anyhow!("open banks {w}, {h}: {e} (try `alx verify`)"))?
    };
    println!(
        "model: {} users × {} items, d={}{}; index: {} clusters",
        model.users.rows,
        model.items.rows,
        model.dim(),
        if model.items.is_spilled() { " (bank-backed)" } else { "" },
        model.index.centroids.rows,
    );
    let mut handle = serve(std::sync::Arc::new(model), &serve_cfg)?;
    println!("listening on {} (send SHUTDOWN or `alx query --shutdown` to stop)", handle.addr());
    handle.wait();
    let s = handle.stats();
    println!(
        "served {} requests ({} cache hits, {} rejected, {} expired) in {} batches \
         (largest {}) over {} connections; {} malformed frames",
        s.requests,
        s.cache_hits,
        s.rejected,
        s.deadline_expired,
        s.batches,
        s.largest_batch,
        s.connections,
        s.malformed,
    );
    Ok(())
}

/// Minimal client for `alx serve` (CI smoke tests and ad-hoc queries).
/// Top-K output prints one `item score-bits score` line per result —
/// byte-identical across runs for identical server state, so responses
/// can be `cmp`-ed.
fn cmd_query(args: &Args) -> anyhow::Result<()> {
    let host = args.get("host").unwrap_or("127.0.0.1");
    let port: u16 = args.get_or("port", 0u16)?;
    anyhow::ensure!(port != 0, "query needs --port <port>");
    let addr = format!("{host}:{port}");
    let mut client = Client::connect(&addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    if args.has("ping") {
        match client.ping()? {
            Response::Ok => println!("pong"),
            other => anyhow::bail!("unexpected ping reply: {other:?}"),
        }
        return Ok(());
    }
    if args.has("shutdown") {
        match client.shutdown()? {
            Response::Ok => println!("shutdown acknowledged"),
            other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
        }
        return Ok(());
    }
    if args.has("malformed") {
        // Deliberately send an invalid opcode: the server must answer ERR
        // and stay up (the CI smoke checks exactly this).
        match client.send_raw(&[0xFF, 1, 2, 3])? {
            Some(Response::Err(msg)) => println!("server rejected frame: {msg}"),
            other => anyhow::bail!("expected an ERR reply, got {other:?}"),
        }
        return Ok(());
    }
    let exclude: Vec<u32> = match args.get("exclude") {
        None => vec![],
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("--exclude: {e}"))?,
    };
    let req = TopKRequest {
        user: args.get_or("user", 0u64)?,
        k: args.get_or("k", 10u32)?,
        probes: args.get_or("probes", 0u32)?,
        deadline_us: args.get_or("deadline-us", 0u32)?,
        exclude,
    };
    match client.topk(&req)? {
        Response::TopK(items) => {
            for (id, score) in items {
                println!("{id} {:08x} {score}", score.to_bits());
            }
        }
        Response::Err(msg) => anyhow::bail!("server error: {msg}"),
        other => anyhow::bail!("unexpected reply: {other:?}"),
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> anyhow::Result<()> {
    let scale = args.get_or("scale", 0.001)?;
    let seed = args.get_or("seed", 7u64)?;
    let rows = harness::run_table1(scale, seed);
    harness::print_table1(&rows, scale);
    Ok(())
}

fn cmd_table2(args: &Args) -> anyhow::Result<()> {
    let scale = args.get_or("scale", 0.002)?;
    let seed = args.get_or("seed", 7u64)?;
    let cores = args.get_or("cores", 8usize)?;
    let train = TrainConfig {
        dim: args.get_or("dim", 32usize)?,
        epochs: args.get_or("epochs", 8usize)?,
        lambda: args.get_or("lambda", 5e-3f32)?,
        alpha: args.get_or("alpha", 1e-4f32)?,
        batch_rows: 64,
        batch_width: 8,
        ..TrainConfig::default()
    };
    let mut rows = Vec::new();
    for v in Variant::ALL {
        rows.push(harness::run_table2_row(v, scale, &train, cores, seed)?);
    }
    harness::print_table2(&rows);
    Ok(())
}

fn cmd_fig4(args: &Args) -> anyhow::Result<()> {
    let series = harness::run_fig4(
        Variant::InDense,
        args.get_or("scale", 0.002)?,
        args.get_or("epochs", 8usize)?,
        args.get_or("dim", 16usize)?,
        args.get_or("lambda", 1e-4f32)?,
        args.get_or("cores", 4usize)?,
        args.get_or("seed", 7u64)?,
    )?;
    harness::print_fig4(&series);
    Ok(())
}

fn cmd_fig5(args: &Args) -> anyhow::Result<()> {
    let dims: Vec<usize> = args
        .get("dims")
        .unwrap_or("16,32,64,128")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let points = harness::run_fig5(
        Variant::InDense,
        args.get_or("scale", 0.002)?,
        &dims,
        args.get_or("cores", 4usize)?,
        args.get_or("seed", 7u64)?,
        None,
    )?;
    harness::print_fig5(&points);
    Ok(())
}

fn cmd_fig6(args: &Args) -> anyhow::Result<()> {
    let dim = args.get_or("dim", 128usize)?;
    let cores: Vec<usize> = args
        .get("cores")
        .unwrap_or("8,16,32,64,128,256,512,1024,2048")
        .split(',')
        .map(|s| s.trim().parse())
        .collect::<Result<_, _>>()?;
    let variants = [Variant::Sparse, Variant::Dense, Variant::DeSparse, Variant::DeDense];
    let points = harness::run_fig6(&variants, &cores, dim);
    harness::print_fig6(&points);
    Ok(())
}

fn cmd_grid(args: &Args) -> anyhow::Result<()> {
    let cfg = resolve_config(args)?;
    let spec = if args.has("coarse") { GridSpec::coarse() } else { GridSpec::default() };
    let points = grid_search(&cfg, &spec)?;
    println!("\nGrid search ({} cells), best first:", points.len());
    println!("{:>10} {:>10} {:>9} {:>9}", "lambda", "alpha", "R@20", "R@50");
    for p in &points {
        println!(
            "{:>10.0e} {:>10.0e} {:>9.3} {:>9.3}",
            p.lambda, p.alpha, p.recall_at_20, p.recall_at_50
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let cores = args.get_or("cores", 8usize)?;
    let topo = Topology::new(cores);
    println!("simulated TPU v3 slice: {} cores, torus {:?}", topo.num_cores, topo.torus);
    println!("  HBM/core: {}", human_bytes(topo.core.hbm_bytes));
    println!("  usable HBM total: {}", human_bytes(topo.total_usable_hbm()));
    println!("  link bandwidth: {:.0} GB/s × {} links", topo.core.link_bandwidth / 1e9, topo.core.links);
    println!("  effective compute: {:.1} TFLOP/s/core", topo.effective_flops() / 1e12);
    for v in Variant::ALL {
        let bytes = 2 * v.paper_nodes() * 128 * 2;
        println!(
            "  {}: tables need {} → min {} cores",
            v.name(),
            human_bytes(bytes),
            Topology::min_cores_for(bytes, &topo.core)
        );
    }
    if args.has("artifacts") {
        let rt = alx::runtime::Runtime::open(args.get("artifacts").unwrap())?;
        println!("\nartifacts ({}):", rt.platform());
        for e in rt.manifest().entries() {
            println!("  {} ({})", e.name, e.file);
        }
    }
    Ok(())
}

/// Run one distributed-training worker: bind, announce the address on
/// stdout (`ALX_WORKER_LISTENING host:port`), and serve collectives until
/// a coordinator sends SHUTDOWN.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    if let Some(spec) = args.get("failpoints") {
        alx::util::fault::configure(spec)
            .map_err(|e| anyhow::anyhow!("--failpoints '{spec}': {e}"))?;
    }
    let bind = match args.get("bind") {
        Some(b) => b.to_string(),
        None => format!("127.0.0.1:{}", args.get_or("port", 0u16)?),
    };
    alx::dist::run_worker(&bind)
}

/// Spawn a local worker fleet on ephemeral ports, then run `alx train`
/// against it in tcp mode. All remaining flags pass through to train, so
/// `alx launch --num-workers 4 --epochs 2 ...` is the multi-process twin
/// of the same `alx train ...` invocation. The fleet is shut down (and the
/// children reaped) whatever the training outcome.
fn cmd_launch(args: &Args) -> anyhow::Result<()> {
    let n: usize = args.get_or("num-workers", 4usize)?;
    anyhow::ensure!(n >= 1, "--num-workers must be >= 1");
    let exe = std::env::current_exe()?;
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..n {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker").arg("--port").arg("0");
        // Deterministic fault-injection rides on worker 0 only, so a
        // killed-worker drill has exactly one victim.
        if i == 0 {
            if let Some(spec) = args.get("worker-failpoints") {
                cmd.arg("--failpoints").arg(spec);
            }
        }
        cmd.stdout(std::process::Stdio::piped());
        let mut child = cmd.spawn().map_err(|e| anyhow::anyhow!("spawn worker {i}: {e}"))?;
        use std::io::BufRead;
        let stdout = child.stdout.take().expect("stdout was piped");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        loop {
            line.clear();
            let k = reader.read_line(&mut line)?;
            anyhow::ensure!(k > 0, "worker {i} exited before announcing its address");
            if let Some(rest) = line.trim().strip_prefix(alx::dist::WORKER_READY_PREFIX) {
                addrs.push(rest.trim().to_string());
                break;
            }
        }
        // Keep draining the child's stdout so its log writes never block
        // on a full pipe.
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(k) if k > 0) {
                sink.clear();
            }
        });
        children.push(child);
    }
    println!("launched {n} workers: {}", addrs.join(", "));
    let mut train_args = Args { positional: args.positional.clone(), flags: args.flags.clone() };
    train_args.flags.push(("dist".to_string(), "tcp".to_string()));
    train_args.flags.push(("workers".to_string(), addrs.join(",")));
    let result = cmd_train(&train_args);
    // Stop the fleet regardless of how training ended; a worker that
    // already died (or was fault-killed) just fails the connect.
    for addr in &addrs {
        if let Ok(mut s) = std::net::TcpStream::connect(addr) {
            let _ = alx::util::net::write_frame_capped(
                &mut s,
                &alx::dist::protocol::enc_shutdown(),
                alx::dist::protocol::MAX_FRAME,
            );
            let _ = alx::util::net::read_frame_capped(&mut s, alx::dist::protocol::MAX_FRAME);
        }
    }
    for mut c in children {
        let _ = c.wait();
    }
    result
}

fn usage() -> ! {
    eprintln!(
        "usage: alx <generate|convert|bank|verify|train|worker|launch|serve|query|table1|table2|fig4|fig5|fig6|grid|info> [--key value ...]\n\
         train flags: --source webgraph|edge-list --data <file> --resume <ckpt>\n\
                      --solver cg|cholesky|qr|ialspp --solver-engine qr|ialspp --block-dim <p>\n\
                      (ialspp = block-coordinate subspace solver; p must divide --dim)\n\
                      --dist local|tcp --workers host:p1,host:p2 --topology parameter-server|all-reduce\n\
                      --heartbeat-ms <ms> --compute coordinator|worker (multi-process training\n\
                      against `alx worker` processes; `worker` solves on the shard owners)\n\
         worker:      --port <p> | --bind <host:port> (serve table shards; prints ALX_WORKER_LISTENING)\n\
         launch:      --num-workers <n> [train flags...] (spawn a local fleet, train over it in tcp mode)\n\
                      [--worker-failpoints 'spec'] (arm fault injection on worker 0)\n\
                      --stream --ingest-budget-mb <MiB> (out-of-core ALXCSR02 ingestion)\n\
                      --spill --spill-dir <dir> --resident-shards <n> (demand-paged shard banks)\n\
                      --spill-model --resident-table-shards <n> (demand-paged W/H table banks;\n\
                      with --stream --spill neither the matrix nor the model is ever RAM-resident)\n\
                      --checkpoint <path> --checkpoint-every <k> --eval-every <k> --early-stop <k>\n\
                      --early-stop-recall <K> (stop on a Recall@K plateau)\n\
         convert:     --data <in: text|ALXCSR01|ALXCSR02> --out <file.alxcsr02> [--chunk-rows <n>]\n\
         bank:        --data <file.alxcsr02> --out <file.alxbank> [--shards <n>] [--transpose-out <f>]\n\
         generate:    --out <file> [--format csr02|csr01] [--chunk-rows <n>]\n\
         verify:      <path> [<path> ...] (validate any ALX artifact; non-zero exit on corruption)\n\
         serve:       --checkpoint <ckpt> | --w-bank <f> --h-bank <f> (bank-backed, out of core)\n\
                      --port <p> --serve-threads <n> --batch-window-us <µs> --batch-max <n>\n\
                      --cache-entries <n> --cache-ttl-ms <ms> --mips-clusters <c> --mips-probes <p>\n\
                      --spill-model --resident-table-shards <n> (serve a checkpoint demand-paged)\n\
         query:       --port <p> [--host <h>] --user <u> --k <n> [--probes <p>] [--exclude a,b,c]\n\
                      [--deadline-us <µs>] | --ping | --malformed | --shutdown\n\
         fault injection (builds with --features failpoints): --failpoints 'name=trigger[:action];...'\n\
         see the CLI cheatsheet in README.md"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "convert" => cmd_convert(&args),
        "bank" => cmd_bank(&args),
        "verify" => cmd_verify(&args),
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "launch" => cmd_launch(&args),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "table1" => cmd_table1(&args),
        "table2" => cmd_table2(&args),
        "fig4" => cmd_fig4(&args),
        "fig5" => cmd_fig5(&args),
        "fig6" => cmd_fig6(&args),
        "grid" => cmd_grid(&args),
        "info" => cmd_info(&args),
        _ => usage(),
    }
}
