//! Hyper-parameter grid search (paper §6.1).
//!
//! "Hyperparameter tuning over both norm penalty (λ) and unobserved weight
//! (α) has been indispensable for good results." The paper sweeps
//! λ ∈ {5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4} × α ∈ {1e-3, 5e-4, 1e-4, 5e-5,
//! 1e-5, 5e-6, 1e-6} per variant; Table 2 reports the best cell.

use super::TrainSession;
use crate::config::AlxConfig;
use crate::data::source_from_config;
use crate::eval::EvalConfig;

/// The sweep grids. Defaults are exactly the paper's §6.1 lists.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub lambdas: Vec<f32>,
    pub alphas: Vec<f32>,
    /// Metric to select on ("recall@20" like Table 2).
    pub select_k: usize,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            lambdas: vec![5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4],
            alphas: vec![1e-3, 5e-4, 1e-4, 5e-5, 1e-5, 5e-6, 1e-6],
            select_k: 20,
        }
    }
}

impl GridSpec {
    /// A reduced grid (corner + center points) for time-bounded runs.
    pub fn coarse() -> GridSpec {
        GridSpec {
            lambdas: vec![5e-2, 5e-3, 5e-4],
            alphas: vec![1e-3, 1e-5, 1e-6],
            select_k: 20,
        }
    }
}

/// One evaluated grid cell.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub lambda: f32,
    pub alpha: f32,
    pub recall_at_20: f64,
    pub recall_at_50: f64,
}

/// Run the grid over `(λ, α)` and return all cells, best first. A thin
/// driver over [`TrainSession`]: the dataset is loaded once and every grid
/// cell trains its own session over a clone of it.
pub fn grid_search(base: &AlxConfig, spec: &GridSpec) -> anyhow::Result<Vec<GridPoint>> {
    let dataset = source_from_config(base)?.load()?;
    let mut points = Vec::new();
    for &lambda in &spec.lambdas {
        for &alpha in &spec.alphas {
            let mut cfg = base.clone();
            cfg.train.lambda = lambda;
            cfg.train.alpha = alpha;
            cfg.train.compute_objective = false;
            let mut session = TrainSession::from_dataset(dataset.clone(), cfg, None)?;
            while session.remaining_epochs() > 0 {
                session.step()?;
            }
            let recalls = session.evaluate_with(&EvalConfig::default());
            let get = |k: usize| {
                recalls.iter().find(|r| r.k == k).map(|r| r.recall).unwrap_or(0.0)
            };
            let p = GridPoint {
                lambda,
                alpha,
                recall_at_20: get(20),
                recall_at_50: get(50),
            };
            crate::log_info!(
                "grid λ={lambda:.0e} α={alpha:.0e} → R@20={:.3} R@50={:.3}",
                p.recall_at_20,
                p.recall_at_50
            );
            points.push(p);
        }
    }
    let key = spec.select_k;
    points.sort_by(|a, b| {
        let (ra, rb) = match key {
            50 => (a.recall_at_50, b.recall_at_50),
            _ => (a.recall_at_20, b.recall_at_20),
        };
        rb.partial_cmp(&ra).unwrap()
    });
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::als::TrainConfig;

    #[test]
    fn grid_orders_by_selected_metric() {
        let base = AlxConfig {
            scale: 0.0005,
            cores: 2,
            train: TrainConfig {
                dim: 8,
                epochs: 2,
                batch_rows: 16,
                batch_width: 8,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        };
        let spec = GridSpec { lambdas: vec![5e-2, 5e-4], alphas: vec![1e-4], select_k: 20 };
        let points = grid_search(&base, &spec).unwrap();
        assert_eq!(points.len(), 2);
        assert!(points[0].recall_at_20 >= points[1].recall_at_20);
    }

    #[test]
    fn default_grid_matches_paper_lists() {
        let g = GridSpec::default();
        assert_eq!(g.lambdas.len(), 6);
        assert_eq!(g.alphas.len(), 7);
        assert_eq!(g.lambdas[0], 5e-2);
        assert_eq!(g.alphas[6], 1e-6);
    }
}
