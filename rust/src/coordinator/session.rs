//! Step-wise training sessions — the resumable, observable job API.
//!
//! The paper's largest WebGraph run takes 5.5 hours on 256 cores; jobs at
//! that scale cannot be fire-and-forget. A [`TrainSession`] owns the
//! dataset, split, topology and trainer, and exposes the lifecycle one
//! epoch at a time:
//!
//! * [`TrainSession::step`] — run one epoch, fire hooks, return its stats;
//! * [`TrainSession::evaluate`] — Recall@K on the held-out split, any time;
//! * [`TrainSession::checkpoint`] / [`TrainSession::resume`] — persist and
//!   restore mid-run state (atomic rename, bitwise-deterministic resume);
//! * [`EpochHook`]s — registrable callbacks after every epoch, with
//!   built-ins for eval-every-k ([`EvalEvery`]), checkpoint-every-k
//!   ([`CheckpointEvery`]) and early stopping ([`EarlyStopOnPlateau`]).
//!
//! [`super::Coordinator`] and [`super::grid_search`] are thin drivers over
//! sessions; the `alx train` CLI maps `--resume`, `--source`,
//! `--checkpoint-every` and `--eval-every` straight onto this API.

use super::RunReport;
use crate::als::{EpochStats, ObjectiveLogEntry, RecallLogEntry, SolveEngine, Trainer};
use crate::collectives::Collectives;
use crate::config::AlxConfig;
use crate::data::{
    source_from_config, spill_to_banks, DataSource, Dataset, DatasetInfo, IngestReport,
    StreamingSource,
};
use crate::eval::{evaluate, EvalConfig, RecallReport};
use crate::sparse::{split_to_shards, ShardedMatrix, TestRow};
use crate::topo::Topology;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// What a hook wants the session to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HookAction {
    /// Keep training.
    Continue,
    /// Stop the run after this epoch (e.g. objective plateau).
    Stop,
}

/// A callback fired after every completed epoch. Hooks receive the session
/// itself, so they can evaluate, checkpoint, or inspect history.
pub trait EpochHook {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction>;

    /// Called when the hook is installed on a **resumed** session:
    /// `prior` is the persisted `(epoch, objective)` log of every epoch
    /// that ran before the checkpoint, in order. Hooks with cross-epoch
    /// state (e.g. [`EarlyStopOnPlateau`]) replay it so a resumed run
    /// behaves exactly like an uninterrupted one; returning
    /// [`HookAction::Stop`] marks the session stopped immediately (the
    /// checkpoint was written at an epoch where the hook had already
    /// decided to stop). Default: no-op, continue.
    fn on_resume(&mut self, _prior: &[ObjectiveLogEntry]) -> HookAction {
        HookAction::Continue
    }

    /// The eval-metric twin of [`EpochHook::on_resume`]: `prior` is the
    /// persisted `(epoch, K, Recall@K)` log. [`EarlyStopOnRecall`] replays
    /// it to reconstruct its plateau state. Default: no-op, continue.
    fn on_resume_recalls(&mut self, _prior: &[RecallLogEntry]) -> HookAction {
        HookAction::Continue
    }
}

/// A training job with step-wise control: dataset + held-out test rows +
/// trainer, plus the epoch history and registered hooks.
///
/// The training matrix lives **only** inside the trainer, as per-shard
/// CSRs (and their transposes) — the session holds the dataset's shape
/// and provenance ([`DatasetInfo`]), not a second copy of the matrix.
pub struct TrainSession {
    pub cfg: AlxConfig,
    /// Shape and provenance of the loaded dataset.
    pub dataset: DatasetInfo,
    /// Held-out strong-generalization test rows.
    pub test: Vec<TestRow>,
    pub trainer: Trainer,
    /// Streaming-ingestion accounting (None for in-memory sources).
    pub ingest: Option<IngestReport>,
    history: Vec<EpochStats>,
    eval_log: Vec<(usize, Vec<RecallReport>)>,
    hooks: Vec<Box<dyn EpochHook>>,
    stopped: bool,
    /// `(epoch, objective)` log restored from a checkpoint (empty for
    /// fresh sessions); replayed into hooks as they are installed and
    /// persisted back out by [`TrainSession::checkpoint`].
    restored_objectives: Vec<ObjectiveLogEntry>,
    /// `(epoch, K, recall)` log restored from a checkpoint; the recall
    /// twin of `restored_objectives`.
    restored_recalls: Vec<RecallLogEntry>,
    /// Recall evals recorded by [`EarlyStopOnRecall`] this session
    /// (persisted by [`TrainSession::checkpoint`] for resume replay).
    recall_log: Vec<RecallLogEntry>,
    /// Scratch directories holding this session's spill banks — matrix
    /// (`ALXBANK01`) and/or model (`ALXTAB01`); the two live apart when
    /// `model.spill_dir` names its own base. Removed on drop; empty when
    /// everything is resident.
    spill_scratch: Vec<PathBuf>,
}

impl Drop for TrainSession {
    fn drop(&mut self) {
        // The spill banks are per-session scratch (resolve_scratch_dir
        // hands every session a unique directory, even under a user-set
        // base). Unlinking while the trainer still holds the maps is fine
        // on unix: the inodes live until unmapped.
        for dir in self.spill_scratch.drain(..) {
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

impl TrainSession {
    /// Build a session from a resolved config: the `[data]` section picks
    /// the source (`streaming = true` selects the out-of-core `ALXCSR02`
    /// path), and `[session]` keys (`checkpoint_every`, `eval_every`,
    /// `early_stop_patience`) install the matching hooks.
    pub fn from_config(cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        let mut session = Self::build_from_config(cfg, None)?;
        session.install_config_hooks();
        Ok(session)
    }

    /// Config-driven construction without hooks (shared by
    /// [`TrainSession::from_config`] and [`TrainSession::resume`]).
    fn build_from_config(
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        if cfg.data_streaming {
            anyhow::ensure!(
                !cfg.data_path.is_empty(),
                "data.streaming = true requires data.path (--data <file.alxcsr02>)"
            );
            let path = PathBuf::from(&cfg.data_path);
            Self::from_streaming(path, cfg, engine)
        } else {
            let source = source_from_config(&cfg)?;
            Self::with_engine(source.as_ref(), cfg, engine)
        }
    }

    /// Build a session over an explicit [`DataSource`] (no hooks installed).
    pub fn new(source: &dyn DataSource, cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        Self::with_engine(source, cfg, None)
    }

    /// [`TrainSession::new`] with an engine override (`None` → per-config).
    pub fn with_engine(
        source: &dyn DataSource,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let dataset = source.load()?;
        Self::from_dataset(dataset, cfg, engine)
    }

    /// Build a session over an already-loaded [`Dataset`]. The matrix is
    /// split and moved into sharded training storage; the session keeps
    /// only its [`DatasetInfo`]. With `[data] spill`, the shards (and
    /// their transposes) are written to `ALXBANK01` banks and reopened
    /// demand-paged, so steady-state training memory is bounded by
    /// `data.resident_shards` instead of the matrix. `[model] spill`
    /// additionally moves W and H into `ALXTAB01` banks (see
    /// [`TrainSession::assemble`]'s tail), so neither the matrix nor the
    /// model need fit in host RAM.
    pub fn from_dataset(
        dataset: Dataset,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let info = dataset.info();
        let sharded =
            split_to_shards(&dataset.matrix, cfg.cores, 0.9, 0.25, cfg.data_seed ^ 0x9);
        drop(dataset); // the monolithic matrix is no longer needed
        if cfg.data_spill {
            let dir = Self::resolve_spill_dir(&cfg);
            let (train, train_t) =
                spill_to_banks(sharded.train, sharded.train_t, &dir, cfg.resident_shards)?;
            let (train, train_t) = (Arc::new(train), Arc::new(train_t));
            return Self::assemble(
                info,
                train,
                train_t,
                sharded.test,
                None,
                cfg,
                engine,
                Some(dir),
            );
        }
        Self::assemble(
            info,
            Arc::new(sharded.train),
            Arc::new(sharded.train_t),
            sharded.test,
            None,
            cfg,
            engine,
            None,
        )
    }

    /// Build a session by streaming an `ALXCSR02` file: chunks flow
    /// through a bounded-memory cursor straight into per-shard CSRs, so
    /// peak ingestion memory is bounded by the chunk size, not the matrix
    /// size. Training is bitwise identical to the in-memory path on the
    /// same data. With `[data] spill`, shards are written straight into
    /// banks as they complete — the full matrix never exists in RAM at
    /// any point of the run.
    pub fn from_streaming(
        path: impl AsRef<Path>,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let budget = (cfg.ingest_budget_mb as u64) << 20;
        let source = StreamingSource::new(path.as_ref(), budget);
        if cfg.data_spill {
            let dir = Self::resolve_spill_dir(&cfg);
            let s = source.load_split_spilled(
                cfg.cores,
                0.9,
                0.25,
                cfg.data_seed ^ 0x9,
                &dir,
                cfg.resident_shards,
            )?;
            let (train, train_t) = (Arc::new(s.train), Arc::new(s.train_t));
            return Self::assemble(
                s.info,
                train,
                train_t,
                s.test,
                Some(s.ingest),
                cfg,
                engine,
                Some(dir),
            );
        }
        let s = source.load_split(cfg.cores, 0.9, 0.25, cfg.data_seed ^ 0x9)?;
        let (train, train_t) = (Arc::new(s.train), Arc::new(s.train_t));
        Self::assemble(s.info, train, train_t, s.test, Some(s.ingest), cfg, engine, None)
    }

    /// A fresh scratch directory — unique per process *and* per session —
    /// under `base` when set, else under the system temp dir. Uniqueness
    /// is load-bearing: bank files are truncated on create, so two
    /// sessions (concurrent runs, or sequential sessions in one process)
    /// must never share a directory while one still has its banks mapped.
    /// The directory is removed when the session drops.
    fn resolve_scratch_dir(base: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
        let base = if base.is_empty() { std::env::temp_dir() } else { PathBuf::from(base) };
        base.join(format!("alx_spill_{}_{}", std::process::id(), seq))
    }

    /// Where this session's matrix spill banks live (see
    /// [`TrainSession::resolve_scratch_dir`]).
    fn resolve_spill_dir(cfg: &AlxConfig) -> PathBuf {
        Self::resolve_scratch_dir(&cfg.spill_dir)
    }

    /// Shared tail of every constructor: resolve the engine, build the
    /// trainer over the sharded matrix (resident or bank-backed), spill
    /// the model tables into `ALXTAB01` banks when `[model] spill` asks
    /// for it (reusing the matrix scratch dir when there is one and
    /// `model.spill_dir` does not name its own base, so
    /// `--stream --spill --spill-model` keeps all of a session's banks
    /// together by default), and assemble the session.
    fn assemble(
        info: DatasetInfo,
        train: Arc<dyn ShardedMatrix>,
        train_t: Arc<dyn ShardedMatrix>,
        test: Vec<TestRow>,
        ingest: Option<IngestReport>,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
        scratch: Option<PathBuf>,
    ) -> anyhow::Result<TrainSession> {
        let topo = Topology::new(cfg.cores);
        let engine: Box<dyn SolveEngine> = match engine {
            Some(e) => e,
            None => match cfg.engine.as_str() {
                "xla" => Box::new(crate::runtime::XlaEngine::new(
                    &cfg.artifacts_dir,
                    cfg.train.solver.name(),
                    cfg.train.dim,
                    cfg.train.batch_rows,
                    cfg.train.batch_width,
                )?),
                // Same engine (and thread-budget split) Trainer::new uses,
                // so `train.threads` reaches the per-segment fan-out here.
                _ => Trainer::default_engine(&cfg.train, &topo),
            },
        };
        let mut scratch: Vec<PathBuf> = scratch.into_iter().collect();
        let trainer = if cfg.model_spill {
            // A user-set model.spill_dir always wins (W/H may need a
            // bigger disk than the matrix banks); otherwise the model
            // banks share the matrix scratch dir when there is one. The
            // tables are initialized straight into the banks — peak
            // table memory during construction is one shard.
            let dir = match scratch.first() {
                Some(dir) if cfg.model_spill_dir.is_empty() => dir.clone(),
                _ => {
                    let dir = Self::resolve_scratch_dir(&cfg.model_spill_dir);
                    scratch.push(dir.clone());
                    dir
                }
            };
            Trainer::from_sharded_spilled(
                train,
                train_t,
                cfg.train.clone(),
                topo,
                engine,
                &dir,
                cfg.resident_table_shards,
            )?
        } else {
            Trainer::from_sharded(train, train_t, cfg.train.clone(), topo, engine)?
        };
        let mut trainer = trainer;
        if cfg.dist.mode == crate::dist::DistMode::Tcp {
            // Real multi-process transport: connect the worker fleet and
            // ship the freshly initialized tables to their authoritative
            // owners. A later checkpoint restore re-pushes through the
            // same fabric (see Trainer::load_checkpoint).
            let fabric = crate::dist::TcpCollectives::connect(&cfg.dist)?;
            crate::log_info!(
                "dist: attached {} over {} workers",
                fabric.name(),
                fabric.num_workers()
            );
            trainer.attach_collectives(Arc::new(fabric))?;
        }
        Ok(TrainSession {
            cfg,
            dataset: info,
            test,
            trainer,
            ingest,
            history: Vec::new(),
            eval_log: Vec::new(),
            hooks: Vec::new(),
            stopped: false,
            restored_objectives: Vec::new(),
            restored_recalls: Vec::new(),
            recall_log: Vec::new(),
            spill_scratch: scratch,
        })
    }

    /// Restore a session from a checkpoint using the config's data source
    /// (what `alx train --resume <ckpt>` does, streaming included). The
    /// config must describe the same dataset/model shape the checkpoint
    /// was written from.
    pub fn resume(path: impl AsRef<Path>, cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        let mut session = Self::build_from_config(cfg, None)?;
        session.load_checkpoint_file(path.as_ref())?;
        session.install_config_hooks();
        Ok(session)
    }

    /// [`TrainSession::resume`] over an explicit source/engine (no hooks).
    pub fn resume_with(
        path: impl AsRef<Path>,
        source: &dyn DataSource,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let mut session = Self::with_engine(source, cfg, engine)?;
        session.load_checkpoint_file(path.as_ref())?;
        Ok(session)
    }

    /// Load checkpoint state (tables, epoch counter, objective log) into
    /// this freshly-built session.
    fn load_checkpoint_file(&mut self, path: &Path) -> anyhow::Result<()> {
        crate::util::fault::failpoint("ckpt.read")?;
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .map_err(|e| anyhow::anyhow!("open checkpoint {}: {e}", path.display()))?,
        );
        let (objectives, recalls) = self.trainer.load_checkpoint(&mut f)?;
        self.restored_objectives = objectives;
        self.restored_recalls = recalls;
        crate::log_info!(
            "resumed {} from {} at epoch {}",
            self.dataset.name,
            path.display(),
            self.trainer.current_epoch()
        );
        Ok(())
    }

    /// Install the hooks the `[session]` config keys ask for.
    ///
    /// Order matters for one pair: [`EarlyStopOnRecall`] must run
    /// **before** [`CheckpointEvery`], so a checkpoint written at a
    /// recall-eval epoch already contains that epoch's recall-log entry
    /// and a resumed run replays to exactly the same state as the
    /// uninterrupted one. (The objective log has no such constraint —
    /// `step` records it before any hook fires.) Code registering these
    /// hooks by hand should keep the same order.
    pub fn install_config_hooks(&mut self) {
        if self.cfg.eval_every > 0 {
            self.add_hook(Box::new(EvalEvery::new(self.cfg.eval_every)));
        }
        if self.cfg.early_stop_recall_k > 0 {
            self.add_hook(Box::new(EarlyStopOnRecall::new(
                self.cfg.early_stop_recall_k,
                self.cfg.early_stop_recall_every,
                self.cfg.early_stop_recall_patience,
                1e-4,
            )));
        }
        if self.cfg.checkpoint_every > 0 {
            self.add_hook(Box::new(CheckpointEvery::new(
                self.cfg.checkpoint_every,
                self.cfg.checkpoint_path.clone(),
            )));
        }
        if self.cfg.early_stop_patience > 0 {
            self.add_hook(Box::new(EarlyStopOnPlateau::new(self.cfg.early_stop_patience, 1e-4)));
        }
    }

    /// Register an epoch hook (fires after every [`TrainSession::step`]).
    /// On a resumed session the hook first replays the persisted
    /// pre-checkpoint objective log, so cross-epoch hook state (early
    /// stopping) continues exactly where the uninterrupted run would be —
    /// including the case where the checkpoint was written in the very
    /// epoch the hook stopped at (the replay then stops the session
    /// before it trains a single extra epoch).
    pub fn add_hook(&mut self, mut hook: Box<dyn EpochHook>) {
        if !self.restored_objectives.is_empty()
            && hook.on_resume(&self.restored_objectives) == HookAction::Stop
        {
            self.stopped = true;
        }
        if !self.restored_recalls.is_empty()
            && hook.on_resume_recalls(&self.restored_recalls) == HookAction::Stop
        {
            self.stopped = true;
        }
        self.hooks.push(hook);
    }

    /// Epochs still to run before the configured total is reached.
    pub fn remaining_epochs(&self) -> usize {
        self.cfg.train.epochs.saturating_sub(self.trainer.current_epoch())
    }

    /// Whether a hook has requested the run to stop.
    pub fn stopped(&self) -> bool {
        self.stopped
    }

    /// Epoch stats recorded by this session (resumed sessions only record
    /// the epochs they ran themselves).
    pub fn history(&self) -> &[EpochStats] {
        &self.history
    }

    /// `(epoch, recalls)` pairs recorded by [`EvalEvery`] hooks.
    pub fn eval_log(&self) -> &[(usize, Vec<RecallReport>)] {
        &self.eval_log
    }

    /// Run one epoch, record it, and fire the registered hooks.
    pub fn step(&mut self) -> anyhow::Result<EpochStats> {
        anyhow::ensure!(!self.stopped, "session stopped (a hook requested early stop)");
        let stats = self.trainer.run_epoch()?;
        self.history.push(stats.clone());
        // Take the hooks out so they can borrow the session mutably.
        let mut hooks = std::mem::take(&mut self.hooks);
        let mut failure = None;
        for hook in hooks.iter_mut() {
            match hook.after_epoch(self, &stats) {
                Ok(HookAction::Continue) => {}
                Ok(HookAction::Stop) => self.stopped = true,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // Keep hooks a hook may have registered during the sweep.
        let added = std::mem::replace(&mut self.hooks, hooks);
        self.hooks.extend(added);
        match failure {
            Some(e) => Err(e),
            None => Ok(stats),
        }
    }

    /// Drive the session to the configured epoch count (or an early stop)
    /// and evaluate. The resumable equivalent of the old fire-and-forget
    /// `Coordinator::run`.
    pub fn run(&mut self) -> anyhow::Result<RunReport> {
        while !self.stopped && self.remaining_epochs() > 0 {
            self.step()?;
        }
        // Reuse the final-epoch eval if an EvalEvery hook just produced it
        // (the exact top-k pass is the expensive part of a large run).
        let recalls = match self.eval_log.last() {
            Some((epoch, recalls)) if *epoch == self.trainer.current_epoch() => recalls.clone(),
            _ => self.evaluate()?,
        };
        let history = self.history.clone();
        let epoch_seconds_mean =
            history.iter().map(|h| h.seconds).sum::<f64>() / history.len().max(1) as f64;
        let comm = history.last().map(|h| h.comm_bytes).unwrap_or(0);
        // Spill accounting: present exactly when the matrices (resp. the
        // model tables) live in banks (bank_bytes is 0 when resident).
        let spill = Some(self.trainer.spill_stats()).filter(|s| s.bank_bytes > 0);
        let table_spill = Some(self.trainer.table_spill_stats()).filter(|s| s.bank_bytes > 0);
        Ok(RunReport {
            epoch_seconds_mean,
            simulated_epoch_seconds: self.trainer.simulated_epoch_seconds(),
            comm_bytes_per_epoch: comm,
            comm: self.trainer.comm.snapshot(),
            history,
            recalls,
            peak_rss_bytes: crate::util::mem::peak_rss_bytes(),
            ingest: self.ingest.clone(),
            spill,
            table_spill,
        })
    }

    /// Evaluate Recall@{20,50} on the held-out strong-generalization rows.
    pub fn evaluate(&self) -> anyhow::Result<Vec<RecallReport>> {
        let eval_cfg = EvalConfig {
            approximate: self.cfg.approximate_eval,
            ..EvalConfig::default()
        };
        Ok(evaluate(&self.trainer, &self.test, &eval_cfg))
    }

    /// Evaluate with an explicit eval config.
    pub fn evaluate_with(&self, eval_cfg: &EvalConfig) -> Vec<RecallReport> {
        evaluate(&self.trainer, &self.test, eval_cfg)
    }

    /// Write a checkpoint of the current model state to `path` (write to a
    /// sibling tmp file, then rename, so a crash never corrupts the last
    /// good checkpoint).
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        // Per-process tmp name so concurrent writers to the same path
        // degrade to last-rename-wins instead of interleaving one file.
        let tmp =
            PathBuf::from(format!("{}.tmp.{}", path.display(), std::process::id()));
        // Persist the full (epoch, objective) and (epoch, K, recall)
        // sequences — pre-resume epochs plus this session's own — so hooks
        // can reconstruct their state.
        let mut objective_log = self.restored_objectives.clone();
        objective_log.extend(self.history.iter().map(|h| (h.epoch as u64, h.objective)));
        let mut recall_log = self.restored_recalls.clone();
        recall_log.extend(self.recall_log.iter().copied());
        let write = || -> anyhow::Result<()> {
            crate::util::fault::failpoint("ckpt.write")?;
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .map_err(|e| anyhow::anyhow!("create {}: {e}", tmp.display()))?,
            );
            self.trainer.save_checkpoint_with(&mut f, &objective_log, &recall_log)?;
            use std::io::Write;
            f.flush()?;
            // fsync before the rename: otherwise a power loss can persist
            // the rename with unwritten data, destroying the previous good
            // checkpoint the atomic-rename dance is meant to protect.
            f.get_ref().sync_all()?;
            Ok(())
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        if let Err(e) = crate::util::fault::failpoint("ckpt.publish") {
            let _ = std::fs::remove_file(&tmp);
            return Err(e.into());
        }
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
        crate::log_info!(
            "checkpoint @ epoch {} -> {}",
            self.trainer.current_epoch(),
            path.display()
        );
        Ok(())
    }
}

/// Built-in hook: evaluate every `k` epochs and record the result in the
/// session's [`TrainSession::eval_log`].
pub struct EvalEvery {
    every: usize,
}

impl EvalEvery {
    pub fn new(every: usize) -> EvalEvery {
        EvalEvery { every: every.max(1) }
    }
}

impl EpochHook for EvalEvery {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        if stats.epoch % self.every == 0 {
            let recalls = session.evaluate()?;
            for r in &recalls {
                crate::log_info!("epoch {}: Recall@{} = {:.4}", stats.epoch, r.k, r.recall);
            }
            session.eval_log.push((stats.epoch, recalls));
        }
        Ok(HookAction::Continue)
    }
}

/// Built-in hook: checkpoint every `k` epochs (overwriting `path`, so the
/// file always holds the latest resumable state).
pub struct CheckpointEvery {
    every: usize,
    path: PathBuf,
}

impl CheckpointEvery {
    pub fn new(every: usize, path: impl Into<PathBuf>) -> CheckpointEvery {
        CheckpointEvery { every: every.max(1), path: path.into() }
    }
}

impl EpochHook for CheckpointEvery {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        if stats.epoch % self.every == 0 {
            session.checkpoint(&self.path)?;
        }
        Ok(HookAction::Continue)
    }
}

/// Built-in hook: stop when the training objective has not improved by at
/// least `min_rel_improvement` (relative) for `patience` consecutive
/// epochs. A no-op when `train.compute_objective` is off.
///
/// Plateau state survives checkpoint/resume: checkpoints persist the
/// per-epoch objective log, and on resume the hook replays it (via
/// [`EpochHook::on_resume`]) to reconstruct `best`/`epochs_since_best`
/// exactly — a resumed run stops at the same epoch as an uninterrupted
/// one (`tests/session_resume.rs`).
pub struct EarlyStopOnPlateau {
    patience: usize,
    min_rel_improvement: f64,
    best: f64,
    epochs_since_best: usize,
    warned: bool,
}

impl EarlyStopOnPlateau {
    pub fn new(patience: usize, min_rel_improvement: f64) -> EarlyStopOnPlateau {
        EarlyStopOnPlateau {
            patience: patience.max(1),
            min_rel_improvement,
            best: f64::INFINITY,
            epochs_since_best: 0,
            warned: false,
        }
    }

    /// Fold one epoch's objective into the plateau state; `true` when the
    /// plateau has lasted `patience` epochs (the stop condition). Shared
    /// by the live path and the resume replay, so both walk the exact
    /// same state machine.
    fn observe(&mut self, obj: f64) -> bool {
        if !self.best.is_finite() || obj < self.best * (1.0 - self.min_rel_improvement) {
            self.best = obj;
            self.epochs_since_best = 0;
            false
        } else {
            self.epochs_since_best += 1;
            self.epochs_since_best >= self.patience
        }
    }
}

impl EpochHook for EarlyStopOnPlateau {
    fn after_epoch(
        &mut self,
        _session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        let Some(obj) = stats.objective else {
            if !self.warned {
                crate::log_warn!(
                    "early-stop hook inactive: train.compute_objective is disabled"
                );
                self.warned = true;
            }
            return Ok(HookAction::Continue);
        };
        if self.observe(obj) {
            crate::log_info!(
                "early stop @ epoch {}: objective plateau ({} epochs without {}% improvement)",
                stats.epoch,
                self.patience,
                self.min_rel_improvement * 100.0
            );
            return Ok(HookAction::Stop);
        }
        Ok(HookAction::Continue)
    }

    fn on_resume(&mut self, prior: &[ObjectiveLogEntry]) -> HookAction {
        // Replay the pre-checkpoint objectives through the same state
        // machine. If the plateau was already reached at the checkpoint
        // epoch (a `--checkpoint-every 1` checkpoint is written *before*
        // this hook fires in the same epoch), the resumed session must
        // stop right away, exactly like the uninterrupted run did.
        let mut stop = false;
        for &(_, obj) in prior {
            if let Some(obj) = obj {
                stop = self.observe(obj) || stop;
            }
        }
        if stop {
            HookAction::Stop
        } else {
            HookAction::Continue
        }
    }
}

/// Built-in hook: evaluate Recall@`k` every `every` epochs and stop when
/// it has not improved by at least `min_delta` (absolute) for `patience`
/// consecutive evals — the *eval-metric* early stopper, for runs where
/// the training objective keeps creeping down long after the retrieval
/// quality has saturated.
///
/// Each eval is recorded in the session's recall log, which checkpoints
/// persist (the `RCLG` section of `ALXCKPT2`) and resume replays through
/// [`EpochHook::on_resume_recalls`] — so a resumed run stops at exactly
/// the epoch the uninterrupted one would have, like
/// [`EarlyStopOnPlateau`].
pub struct EarlyStopOnRecall {
    k: usize,
    every: usize,
    patience: usize,
    min_delta: f64,
    best: f64,
    evals_since_best: usize,
    warned: bool,
}

impl EarlyStopOnRecall {
    pub fn new(k: usize, every: usize, patience: usize, min_delta: f64) -> EarlyStopOnRecall {
        EarlyStopOnRecall {
            k,
            every: every.max(1),
            patience: patience.max(1),
            min_delta,
            best: f64::NEG_INFINITY,
            evals_since_best: 0,
            warned: false,
        }
    }

    /// Fold one eval's Recall@K into the plateau state; `true` when the
    /// metric has stalled for `patience` evals. Shared by the live path
    /// and the resume replay, so both walk the same state machine.
    fn observe(&mut self, recall: f64) -> bool {
        if !self.best.is_finite() || recall > self.best + self.min_delta {
            self.best = recall;
            self.evals_since_best = 0;
            false
        } else {
            self.evals_since_best += 1;
            self.evals_since_best >= self.patience
        }
    }
}

impl EpochHook for EarlyStopOnRecall {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        if stats.epoch % self.every != 0 {
            return Ok(HookAction::Continue);
        }
        // Reuse an eval another hook (EvalEvery) already ran this epoch —
        // the exact top-k pass is the expensive part of a large run.
        let recalls = match session.eval_log.last() {
            Some((epoch, recalls)) if *epoch == stats.epoch => recalls.clone(),
            _ => {
                let recalls = session.evaluate()?;
                session.eval_log.push((stats.epoch, recalls.clone()));
                recalls
            }
        };
        let Some(r) = recalls.iter().find(|r| r.k == self.k) else {
            if !self.warned {
                crate::log_warn!(
                    "recall early-stop hook inactive: eval does not report Recall@{}",
                    self.k
                );
                self.warned = true;
            }
            return Ok(HookAction::Continue);
        };
        let recall = r.recall;
        // The persisted recall log resume replays from.
        session.recall_log.push((stats.epoch as u64, self.k as u32, recall));
        if self.observe(recall) {
            crate::log_info!(
                "early stop @ epoch {}: Recall@{} plateau ({} evals without +{} improvement)",
                stats.epoch,
                self.k,
                self.patience,
                self.min_delta
            );
            return Ok(HookAction::Stop);
        }
        Ok(HookAction::Continue)
    }

    fn on_resume_recalls(&mut self, prior: &[RecallLogEntry]) -> HookAction {
        // Replay only the evals this hook's K produced, in order; if the
        // plateau was already reached at the checkpoint epoch, stop the
        // resumed session before it trains a single extra epoch.
        let mut stop = false;
        for &(_, k, recall) in prior {
            if k as usize == self.k {
                stop = self.observe(recall) || stop;
            }
        }
        if stop {
            HookAction::Stop
        } else {
            HookAction::Continue
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::als::TrainConfig;
    use crate::data::InMemorySource;
    use crate::sparse::Csr;
    use crate::util::Pcg64;

    fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for u in 0..users as u32 {
            let comm = (u as usize) % 2;
            for _ in 0..6 {
                let item = if rng.next_f64() < 0.9 {
                    comm * (items / 2) + rng.range(0, items / 2)
                } else {
                    rng.range(0, items)
                };
                t.push((u, item as u32, 1.0));
            }
        }
        Csr::from_coo(users, items, &t)
    }

    fn tiny_cfg(epochs: usize) -> AlxConfig {
        AlxConfig {
            cores: 3,
            train: TrainConfig {
                dim: 8,
                epochs,
                lambda: 0.05,
                alpha: 0.01,
                batch_rows: 16,
                batch_width: 4,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    fn tiny_session(epochs: usize) -> TrainSession {
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        TrainSession::new(&source, tiny_cfg(epochs)).unwrap()
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("alx_session_{}_{}.ckpt", tag, std::process::id()))
    }

    #[test]
    fn step_matches_configured_epochs() {
        let mut s = tiny_session(3);
        assert_eq!(s.remaining_epochs(), 3);
        let st = s.step().unwrap();
        assert_eq!(st.epoch, 1);
        assert_eq!(s.remaining_epochs(), 2);
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        assert_eq!(s.history().len(), 3);
        let objs: Vec<f64> = s.history().iter().map(|h| h.objective.unwrap()).collect();
        assert!(objs.last().unwrap() < objs.first().unwrap(), "objective: {objs:?}");
    }

    #[test]
    fn run_returns_report_and_evaluates() {
        let mut s = tiny_session(2);
        let report = s.run().unwrap();
        assert_eq!(report.history.len(), 2);
        assert!(!report.recalls.is_empty());
        // A second run() call trains nothing further.
        let report2 = s.run().unwrap();
        assert_eq!(report2.history.len(), 2);
    }

    #[test]
    fn eval_every_hook_records_log() {
        let mut s = tiny_session(4);
        s.add_hook(Box::new(EvalEvery::new(2)));
        s.run().unwrap();
        let epochs: Vec<usize> = s.eval_log().iter().map(|(e, _)| *e).collect();
        assert_eq!(epochs, vec![2, 4]);
        assert!(!s.eval_log()[0].1.is_empty());
    }

    #[test]
    fn checkpoint_every_hook_writes_resumable_file() {
        let path = tmp_path("hook");
        let mut s = tiny_session(3);
        s.add_hook(Box::new(CheckpointEvery::new(3, &path)));
        s.run().unwrap();
        assert!(path.exists(), "hook should have written {path:?}");
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        let resumed = TrainSession::resume_with(&path, &source, tiny_cfg(3), None).unwrap();
        assert_eq!(resumed.trainer.current_epoch(), 3);
        assert_eq!(resumed.remaining_epochs(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn early_stop_hook_halts_on_plateau() {
        let mut s = tiny_session(50);
        // Demand an absurd 90% per-epoch improvement: plateau immediately.
        s.add_hook(Box::new(EarlyStopOnPlateau::new(2, 0.9)));
        let report = s.run().unwrap();
        assert!(s.stopped());
        assert!(report.history.len() < 50, "ran {} epochs", report.history.len());
        // Stepping a stopped session is an error.
        assert!(s.step().is_err());
    }

    #[test]
    fn config_hooks_installed_from_session_keys() {
        let path = tmp_path("cfgkeys");
        let cfg = AlxConfig {
            scale: 0.0008,
            cores: 2,
            checkpoint_every: 2,
            eval_every: 2,
            checkpoint_path: path.display().to_string(),
            train: TrainConfig {
                dim: 8,
                epochs: 2,
                batch_rows: 16,
                batch_width: 4,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        };
        let mut s = TrainSession::from_config(cfg).unwrap();
        s.run().unwrap();
        assert_eq!(s.eval_log().len(), 1);
        assert!(path.exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recall_early_stop_halts_on_plateau() {
        let mut s = tiny_session(50);
        // Demand an impossible +1.0 recall improvement: the first eval
        // sets the best, every later one counts toward the plateau.
        s.add_hook(Box::new(EarlyStopOnRecall::new(20, 1, 2, 1.0)));
        let report = s.run().unwrap();
        assert!(s.stopped());
        assert_eq!(report.history.len(), 3, "first eval + 2 plateau evals");
        // Its evals land in the session eval log too.
        assert_eq!(s.eval_log().len(), 3);
        assert_eq!(s.recall_log.len(), 3);
    }

    #[test]
    fn recall_early_stop_state_survives_resume() {
        let path = tmp_path("recall_resume");
        let hook = || Box::new(EarlyStopOnRecall::new(20, 1, 2, 1.0));
        // Uninterrupted reference run.
        let mut full = tiny_session(50);
        full.add_hook(hook());
        full.run().unwrap();
        let stop_epoch = full.trainer.current_epoch();

        // Interrupted run: checkpoint after epoch 1 (hook already fired).
        let mut first = tiny_session(50);
        first.add_hook(hook());
        first.step().unwrap();
        first.checkpoint(&path).unwrap();
        drop(first);

        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        let mut resumed = TrainSession::resume_with(&path, &source, tiny_cfg(50), None).unwrap();
        resumed.add_hook(hook());
        assert!(!resumed.stopped(), "one eval is no plateau yet");
        resumed.run().unwrap();
        assert_eq!(resumed.trainer.current_epoch(), stop_epoch);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recall_stop_epoch_checkpoint_resumes_stopped() {
        // EarlyStopOnRecall registered before CheckpointEvery (the
        // documented — and config-driven — order): the checkpoint written
        // in the stop epoch already holds that epoch's recall entry, so
        // the resumed session replays to Stop before training a single
        // extra epoch.
        let path = tmp_path("recall_stop_ckpt");
        let mut s = tiny_session(50);
        s.add_hook(Box::new(EarlyStopOnRecall::new(20, 1, 2, 1.0)));
        s.add_hook(Box::new(CheckpointEvery::new(1, &path)));
        s.run().unwrap();
        let stop_epoch = s.trainer.current_epoch();
        drop(s);

        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        let mut resumed = TrainSession::resume_with(&path, &source, tiny_cfg(50), None).unwrap();
        resumed.add_hook(Box::new(EarlyStopOnRecall::new(20, 1, 2, 1.0)));
        assert_eq!(resumed.trainer.current_epoch(), stop_epoch);
        assert!(resumed.stopped(), "stop-epoch checkpoint must resume stopped");
        assert!(resumed.step().is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_rejects_mismatched_model_shape() {
        let path = tmp_path("mismatch");
        let mut s = tiny_session(2);
        s.step().unwrap();
        s.checkpoint(&path).unwrap();
        // Different dim: the checkpoint must be rejected.
        let mut cfg = tiny_cfg(2);
        cfg.train.dim = 16;
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        assert!(TrainSession::resume_with(&path, &source, cfg, None).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
