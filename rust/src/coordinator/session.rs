//! Step-wise training sessions — the resumable, observable job API.
//!
//! The paper's largest WebGraph run takes 5.5 hours on 256 cores; jobs at
//! that scale cannot be fire-and-forget. A [`TrainSession`] owns the
//! dataset, split, topology and trainer, and exposes the lifecycle one
//! epoch at a time:
//!
//! * [`TrainSession::step`] — run one epoch, fire hooks, return its stats;
//! * [`TrainSession::evaluate`] — Recall@K on the held-out split, any time;
//! * [`TrainSession::checkpoint`] / [`TrainSession::resume`] — persist and
//!   restore mid-run state (atomic rename, bitwise-deterministic resume);
//! * [`EpochHook`]s — registrable callbacks after every epoch, with
//!   built-ins for eval-every-k ([`EvalEvery`]), checkpoint-every-k
//!   ([`CheckpointEvery`]) and early stopping ([`EarlyStopOnPlateau`]).
//!
//! [`super::Coordinator`] and [`super::grid_search`] are thin drivers over
//! sessions; the `alx train` CLI maps `--resume`, `--source`,
//! `--checkpoint-every` and `--eval-every` straight onto this API.

use super::RunReport;
use crate::als::{EpochStats, SolveEngine, Trainer};
use crate::config::AlxConfig;
use crate::data::{source_from_config, DataSource, Dataset};
use crate::eval::{evaluate, EvalConfig, RecallReport};
use crate::sparse::{split_strong_generalization, Split};
use crate::topo::Topology;
use std::path::{Path, PathBuf};

/// What a hook wants the session to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HookAction {
    /// Keep training.
    Continue,
    /// Stop the run after this epoch (e.g. objective plateau).
    Stop,
}

/// A callback fired after every completed epoch. Hooks receive the session
/// itself, so they can evaluate, checkpoint, or inspect history.
pub trait EpochHook {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction>;
}

/// A training job with step-wise control: dataset + split + trainer, plus
/// the epoch history and registered hooks.
pub struct TrainSession {
    pub cfg: AlxConfig,
    pub dataset: Dataset,
    pub split: Split,
    pub trainer: Trainer,
    history: Vec<EpochStats>,
    eval_log: Vec<(usize, Vec<RecallReport>)>,
    hooks: Vec<Box<dyn EpochHook>>,
    stopped: bool,
}

impl TrainSession {
    /// Build a session from a resolved config: the `[data]` section picks
    /// the source, and `[session]` keys (`checkpoint_every`, `eval_every`,
    /// `early_stop_patience`) install the matching hooks.
    pub fn from_config(cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        let source = source_from_config(&cfg)?;
        let mut session = Self::new(source.as_ref(), cfg)?;
        session.install_config_hooks();
        Ok(session)
    }

    /// Build a session over an explicit [`DataSource`] (no hooks installed).
    pub fn new(source: &dyn DataSource, cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        Self::with_engine(source, cfg, None)
    }

    /// [`TrainSession::new`] with an engine override (`None` → per-config).
    pub fn with_engine(
        source: &dyn DataSource,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let dataset = source.load()?;
        Self::from_dataset(dataset, cfg, engine)
    }

    /// Build a session over an already-loaded [`Dataset`].
    pub fn from_dataset(
        dataset: Dataset,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let split =
            split_strong_generalization(&dataset.matrix, 0.9, 0.25, cfg.data_seed ^ 0x9);
        let topo = Topology::new(cfg.cores);
        let engine: Box<dyn SolveEngine> = match engine {
            Some(e) => e,
            None => match cfg.engine.as_str() {
                "xla" => Box::new(crate::runtime::XlaEngine::new(
                    &cfg.artifacts_dir,
                    cfg.train.solver.name(),
                    cfg.train.dim,
                    cfg.train.batch_rows,
                    cfg.train.batch_width,
                )?),
                // Same engine (and thread-budget split) Trainer::new uses,
                // so `train.threads` reaches the per-segment fan-out here.
                _ => Trainer::default_engine(&cfg.train, &topo),
            },
        };
        let trainer = Trainer::with_engine(&split.train, cfg.train.clone(), topo, engine)?;
        Ok(TrainSession {
            cfg,
            dataset,
            split,
            trainer,
            history: Vec::new(),
            eval_log: Vec::new(),
            hooks: Vec::new(),
            stopped: false,
        })
    }

    /// Restore a session from a checkpoint using the config's data source
    /// (what `alx train --resume <ckpt>` does). The config must describe
    /// the same dataset/model shape the checkpoint was written from.
    pub fn resume(path: impl AsRef<Path>, cfg: AlxConfig) -> anyhow::Result<TrainSession> {
        let source = source_from_config(&cfg)?;
        let mut session = Self::resume_with(path, source.as_ref(), cfg, None)?;
        session.install_config_hooks();
        Ok(session)
    }

    /// [`TrainSession::resume`] over an explicit source/engine (no hooks).
    pub fn resume_with(
        path: impl AsRef<Path>,
        source: &dyn DataSource,
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<TrainSession> {
        let path = path.as_ref();
        let mut session = Self::with_engine(source, cfg, engine)?;
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .map_err(|e| anyhow::anyhow!("open checkpoint {}: {e}", path.display()))?,
        );
        session.trainer.load_checkpoint(&mut f)?;
        crate::log_info!(
            "resumed {} from {} at epoch {}",
            session.dataset.name,
            path.display(),
            session.trainer.current_epoch()
        );
        Ok(session)
    }

    /// Install the hooks the `[session]` config keys ask for.
    pub fn install_config_hooks(&mut self) {
        if self.cfg.eval_every > 0 {
            self.add_hook(Box::new(EvalEvery::new(self.cfg.eval_every)));
        }
        if self.cfg.checkpoint_every > 0 {
            self.add_hook(Box::new(CheckpointEvery::new(
                self.cfg.checkpoint_every,
                self.cfg.checkpoint_path.clone(),
            )));
        }
        if self.cfg.early_stop_patience > 0 {
            self.add_hook(Box::new(EarlyStopOnPlateau::new(self.cfg.early_stop_patience, 1e-4)));
        }
    }

    /// Register an epoch hook (fires after every [`TrainSession::step`]).
    pub fn add_hook(&mut self, hook: Box<dyn EpochHook>) {
        self.hooks.push(hook);
    }

    /// Epochs still to run before the configured total is reached.
    pub fn remaining_epochs(&self) -> usize {
        self.cfg.train.epochs.saturating_sub(self.trainer.current_epoch())
    }

    /// Whether a hook has requested the run to stop.
    pub fn stopped(&self) -> bool {
        self.stopped
    }

    /// Epoch stats recorded by this session (resumed sessions only record
    /// the epochs they ran themselves).
    pub fn history(&self) -> &[EpochStats] {
        &self.history
    }

    /// `(epoch, recalls)` pairs recorded by [`EvalEvery`] hooks.
    pub fn eval_log(&self) -> &[(usize, Vec<RecallReport>)] {
        &self.eval_log
    }

    /// Run one epoch, record it, and fire the registered hooks.
    pub fn step(&mut self) -> anyhow::Result<EpochStats> {
        anyhow::ensure!(!self.stopped, "session stopped (a hook requested early stop)");
        let stats = self.trainer.run_epoch()?;
        self.history.push(stats.clone());
        // Take the hooks out so they can borrow the session mutably.
        let mut hooks = std::mem::take(&mut self.hooks);
        let mut failure = None;
        for hook in hooks.iter_mut() {
            match hook.after_epoch(self, &stats) {
                Ok(HookAction::Continue) => {}
                Ok(HookAction::Stop) => self.stopped = true,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // Keep hooks a hook may have registered during the sweep.
        let added = std::mem::replace(&mut self.hooks, hooks);
        self.hooks.extend(added);
        match failure {
            Some(e) => Err(e),
            None => Ok(stats),
        }
    }

    /// Drive the session to the configured epoch count (or an early stop)
    /// and evaluate. The resumable equivalent of the old fire-and-forget
    /// `Coordinator::run`.
    pub fn run(&mut self) -> anyhow::Result<RunReport> {
        while !self.stopped && self.remaining_epochs() > 0 {
            self.step()?;
        }
        // Reuse the final-epoch eval if an EvalEvery hook just produced it
        // (the exact top-k pass is the expensive part of a large run).
        let recalls = match self.eval_log.last() {
            Some((epoch, recalls)) if *epoch == self.trainer.current_epoch() => recalls.clone(),
            _ => self.evaluate()?,
        };
        let history = self.history.clone();
        let epoch_seconds_mean =
            history.iter().map(|h| h.seconds).sum::<f64>() / history.len().max(1) as f64;
        let comm = history.last().map(|h| h.comm_bytes).unwrap_or(0);
        Ok(RunReport {
            epoch_seconds_mean,
            simulated_epoch_seconds: self.trainer.simulated_epoch_seconds(),
            comm_bytes_per_epoch: comm,
            history,
            recalls,
        })
    }

    /// Evaluate Recall@{20,50} on the held-out strong-generalization rows.
    pub fn evaluate(&self) -> anyhow::Result<Vec<RecallReport>> {
        let eval_cfg = EvalConfig {
            approximate: self.cfg.approximate_eval,
            ..EvalConfig::default()
        };
        Ok(evaluate(&self.trainer, &self.split.test, &eval_cfg))
    }

    /// Evaluate with an explicit eval config.
    pub fn evaluate_with(&self, eval_cfg: &EvalConfig) -> Vec<RecallReport> {
        evaluate(&self.trainer, &self.split.test, eval_cfg)
    }

    /// Write a checkpoint of the current model state to `path` (write to a
    /// sibling tmp file, then rename, so a crash never corrupts the last
    /// good checkpoint).
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        // Per-process tmp name so concurrent writers to the same path
        // degrade to last-rename-wins instead of interleaving one file.
        let tmp =
            PathBuf::from(format!("{}.tmp.{}", path.display(), std::process::id()));
        let write = || -> anyhow::Result<()> {
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .map_err(|e| anyhow::anyhow!("create {}: {e}", tmp.display()))?,
            );
            self.trainer.save_checkpoint(&mut f)?;
            use std::io::Write;
            f.flush()?;
            // fsync before the rename: otherwise a power loss can persist
            // the rename with unwritten data, destroying the previous good
            // checkpoint the atomic-rename dance is meant to protect.
            f.get_ref().sync_all()?;
            Ok(())
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
        crate::log_info!(
            "checkpoint @ epoch {} -> {}",
            self.trainer.current_epoch(),
            path.display()
        );
        Ok(())
    }
}

/// Built-in hook: evaluate every `k` epochs and record the result in the
/// session's [`TrainSession::eval_log`].
pub struct EvalEvery {
    every: usize,
}

impl EvalEvery {
    pub fn new(every: usize) -> EvalEvery {
        EvalEvery { every: every.max(1) }
    }
}

impl EpochHook for EvalEvery {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        if stats.epoch % self.every == 0 {
            let recalls = session.evaluate()?;
            for r in &recalls {
                crate::log_info!("epoch {}: Recall@{} = {:.4}", stats.epoch, r.k, r.recall);
            }
            session.eval_log.push((stats.epoch, recalls));
        }
        Ok(HookAction::Continue)
    }
}

/// Built-in hook: checkpoint every `k` epochs (overwriting `path`, so the
/// file always holds the latest resumable state).
pub struct CheckpointEvery {
    every: usize,
    path: PathBuf,
}

impl CheckpointEvery {
    pub fn new(every: usize, path: impl Into<PathBuf>) -> CheckpointEvery {
        CheckpointEvery { every: every.max(1), path: path.into() }
    }
}

impl EpochHook for CheckpointEvery {
    fn after_epoch(
        &mut self,
        session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        if stats.epoch % self.every == 0 {
            session.checkpoint(&self.path)?;
        }
        Ok(HookAction::Continue)
    }
}

/// Built-in hook: stop when the training objective has not improved by at
/// least `min_rel_improvement` (relative) for `patience` consecutive
/// epochs. A no-op when `train.compute_objective` is off.
///
/// Hook state is in-memory only: checkpoints persist model state, not
/// hooks, so a resumed run restarts plateau tracking from scratch. The
/// bitwise resume ≡ uninterrupted contract covers the training state
/// (tables, epoch counter, per-epoch stats); where a run *stops* under
/// early stopping can differ across an interruption.
pub struct EarlyStopOnPlateau {
    patience: usize,
    min_rel_improvement: f64,
    best: f64,
    epochs_since_best: usize,
    warned: bool,
}

impl EarlyStopOnPlateau {
    pub fn new(patience: usize, min_rel_improvement: f64) -> EarlyStopOnPlateau {
        EarlyStopOnPlateau {
            patience: patience.max(1),
            min_rel_improvement,
            best: f64::INFINITY,
            epochs_since_best: 0,
            warned: false,
        }
    }
}

impl EpochHook for EarlyStopOnPlateau {
    fn after_epoch(
        &mut self,
        _session: &mut TrainSession,
        stats: &EpochStats,
    ) -> anyhow::Result<HookAction> {
        let Some(obj) = stats.objective else {
            if !self.warned {
                crate::log_warn!(
                    "early-stop hook inactive: train.compute_objective is disabled"
                );
                self.warned = true;
            }
            return Ok(HookAction::Continue);
        };
        if !self.best.is_finite() || obj < self.best * (1.0 - self.min_rel_improvement) {
            self.best = obj;
            self.epochs_since_best = 0;
        } else {
            self.epochs_since_best += 1;
            if self.epochs_since_best >= self.patience {
                crate::log_info!(
                    "early stop @ epoch {}: objective plateau ({} epochs without {}% improvement)",
                    stats.epoch,
                    self.patience,
                    self.min_rel_improvement * 100.0
                );
                return Ok(HookAction::Stop);
            }
        }
        Ok(HookAction::Continue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::als::TrainConfig;
    use crate::data::InMemorySource;
    use crate::sparse::Csr;
    use crate::util::Pcg64;

    fn community_matrix(users: usize, items: usize, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for u in 0..users as u32 {
            let comm = (u as usize) % 2;
            for _ in 0..6 {
                let item = if rng.next_f64() < 0.9 {
                    comm * (items / 2) + rng.range(0, items / 2)
                } else {
                    rng.range(0, items)
                };
                t.push((u, item as u32, 1.0));
            }
        }
        Csr::from_coo(users, items, &t)
    }

    fn tiny_cfg(epochs: usize) -> AlxConfig {
        AlxConfig {
            cores: 3,
            train: TrainConfig {
                dim: 8,
                epochs,
                lambda: 0.05,
                alpha: 0.01,
                batch_rows: 16,
                batch_width: 4,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    fn tiny_session(epochs: usize) -> TrainSession {
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        TrainSession::new(&source, tiny_cfg(epochs)).unwrap()
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("alx_session_{}_{}.ckpt", tag, std::process::id()))
    }

    #[test]
    fn step_matches_configured_epochs() {
        let mut s = tiny_session(3);
        assert_eq!(s.remaining_epochs(), 3);
        let st = s.step().unwrap();
        assert_eq!(st.epoch, 1);
        assert_eq!(s.remaining_epochs(), 2);
        while s.remaining_epochs() > 0 {
            s.step().unwrap();
        }
        assert_eq!(s.history().len(), 3);
        let objs: Vec<f64> = s.history().iter().map(|h| h.objective.unwrap()).collect();
        assert!(objs.last().unwrap() < objs.first().unwrap(), "objective: {objs:?}");
    }

    #[test]
    fn run_returns_report_and_evaluates() {
        let mut s = tiny_session(2);
        let report = s.run().unwrap();
        assert_eq!(report.history.len(), 2);
        assert!(!report.recalls.is_empty());
        // A second run() call trains nothing further.
        let report2 = s.run().unwrap();
        assert_eq!(report2.history.len(), 2);
    }

    #[test]
    fn eval_every_hook_records_log() {
        let mut s = tiny_session(4);
        s.add_hook(Box::new(EvalEvery::new(2)));
        s.run().unwrap();
        let epochs: Vec<usize> = s.eval_log().iter().map(|(e, _)| *e).collect();
        assert_eq!(epochs, vec![2, 4]);
        assert!(!s.eval_log()[0].1.is_empty());
    }

    #[test]
    fn checkpoint_every_hook_writes_resumable_file() {
        let path = tmp_path("hook");
        let mut s = tiny_session(3);
        s.add_hook(Box::new(CheckpointEvery::new(3, &path)));
        s.run().unwrap();
        assert!(path.exists(), "hook should have written {path:?}");
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        let resumed = TrainSession::resume_with(&path, &source, tiny_cfg(3), None).unwrap();
        assert_eq!(resumed.trainer.current_epoch(), 3);
        assert_eq!(resumed.remaining_epochs(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn early_stop_hook_halts_on_plateau() {
        let mut s = tiny_session(50);
        // Demand an absurd 90% per-epoch improvement: plateau immediately.
        s.add_hook(Box::new(EarlyStopOnPlateau::new(2, 0.9)));
        let report = s.run().unwrap();
        assert!(s.stopped());
        assert!(report.history.len() < 50, "ran {} epochs", report.history.len());
        // Stepping a stopped session is an error.
        assert!(s.step().is_err());
    }

    #[test]
    fn config_hooks_installed_from_session_keys() {
        let path = tmp_path("cfgkeys");
        let cfg = AlxConfig {
            scale: 0.0008,
            cores: 2,
            checkpoint_every: 2,
            eval_every: 2,
            checkpoint_path: path.display().to_string(),
            train: TrainConfig {
                dim: 8,
                epochs: 2,
                batch_rows: 16,
                batch_width: 4,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        };
        let mut s = TrainSession::from_config(cfg).unwrap();
        s.run().unwrap();
        assert_eq!(s.eval_log().len(), 1);
        assert!(path.exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_rejects_mismatched_model_shape() {
        let path = tmp_path("mismatch");
        let mut s = tiny_session(2);
        s.step().unwrap();
        s.checkpoint(&path).unwrap();
        // Different dim: the checkpoint must be rejected.
        let mut cfg = tiny_cfg(2);
        cfg.train.dim = 16;
        let source = InMemorySource::new("community", community_matrix(60, 40, 3));
        assert!(TrainSession::resume_with(&path, &source, cfg, None).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
