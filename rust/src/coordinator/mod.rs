//! The experiment coordinator — the leader process of the L3 layer.
//!
//! Owns the full job lifecycle the `alx` launcher and the examples drive:
//! dataset synthesis → strong-generalization split → topology/capacity
//! planning → engine selection (native or XLA/PJRT) → epoch loop with
//! eval hooks → reports. The hyper-parameter grid-search driver of §6.1
//! lives here too.

pub mod grid;
pub mod pipeline;

pub use grid::{grid_search, GridPoint, GridSpec};
pub use pipeline::{BatchFeeder, BoundedQueue, CloseGuard, FEED_CHUNK_ROWS};

use crate::als::{SolveEngine, Trainer};
use crate::config::AlxConfig;
use crate::eval::{evaluate, EvalConfig, RecallReport};
use crate::sparse::{split_strong_generalization, Split};
use crate::topo::Topology;
use crate::webgraph::{generate, GeneratedGraph, VariantSpec};

/// End-of-run report.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub history: Vec<crate::als::EpochStats>,
    pub recalls: Vec<RecallReport>,
    pub epoch_seconds_mean: f64,
    pub simulated_epoch_seconds: f64,
    pub comm_bytes_per_epoch: u64,
}

/// Coordinator: dataset + split + trainer, ready to run.
pub struct Coordinator {
    pub cfg: AlxConfig,
    pub graph: GeneratedGraph,
    pub split: Split,
    pub trainer: Trainer,
}

impl Coordinator {
    /// Prepare a job from a resolved config (native engine).
    pub fn prepare(cfg: AlxConfig) -> anyhow::Result<Coordinator> {
        let engine: Option<Box<dyn SolveEngine>> = None;
        Self::prepare_with(cfg, engine)
    }

    /// Prepare with an explicit engine override (`None` → per-config).
    pub fn prepare_with(
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<Coordinator> {
        let spec = VariantSpec::preset(cfg.variant).scaled(cfg.scale);
        crate::log_info!(
            "generating {} at scale {} (~{} nodes)",
            cfg.variant.name(),
            cfg.scale,
            spec.nodes
        );
        let graph = generate(&spec, cfg.data_seed);
        let split = split_strong_generalization(&graph.adjacency, 0.9, 0.25, cfg.data_seed ^ 0x9);
        let topo = Topology::new(cfg.cores);

        let engine: Box<dyn SolveEngine> = match engine {
            Some(e) => e,
            None => match cfg.engine.as_str() {
                "xla" => Box::new(crate::runtime::XlaEngine::new(
                    &cfg.artifacts_dir,
                    cfg.train.solver.name(),
                    cfg.train.dim,
                    cfg.train.batch_rows,
                    cfg.train.batch_width,
                )?),
                // Same engine (and thread-budget split) Trainer::new uses,
                // so `train.threads` reaches the per-segment fan-out here.
                _ => Trainer::default_engine(&cfg.train, &topo),
            },
        };

        let trainer = Trainer::with_engine(&split.train, cfg.train.clone(), topo, engine)?;
        Ok(Coordinator { cfg, graph, split, trainer })
    }

    /// Train for the configured number of epochs and evaluate.
    pub fn run(&mut self) -> anyhow::Result<RunReport> {
        let history = self.trainer.fit()?;
        let recalls = self.evaluate()?;
        let epoch_seconds_mean =
            history.iter().map(|h| h.seconds).sum::<f64>() / history.len().max(1) as f64;
        let comm = history.last().map(|h| h.comm_bytes).unwrap_or(0);
        Ok(RunReport {
            epoch_seconds_mean,
            simulated_epoch_seconds: self.trainer.simulated_epoch_seconds(),
            comm_bytes_per_epoch: comm,
            history,
            recalls,
        })
    }

    /// Evaluate Recall@{20,50} on the held-out strong-generalization rows.
    pub fn evaluate(&self) -> anyhow::Result<Vec<RecallReport>> {
        let eval_cfg = EvalConfig {
            approximate: self.cfg.approximate_eval,
            ..EvalConfig::default()
        };
        Ok(evaluate(&self.trainer, &self.split.test, &eval_cfg))
    }

    /// Evaluate with an explicit eval config.
    pub fn evaluate_with(&self, eval_cfg: &EvalConfig) -> Vec<RecallReport> {
        evaluate(&self.trainer, &self.split.test, eval_cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::als::TrainConfig;

    fn tiny_cfg() -> AlxConfig {
        AlxConfig {
            scale: 0.0008, // ~400 nodes of WebGraph-in-dense
            cores: 4,
            train: TrainConfig {
                dim: 16,
                epochs: 4,
                lambda: 0.03,
                alpha: 0.01,
                batch_rows: 32,
                batch_width: 8,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    #[test]
    fn end_to_end_learns_structure() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        let report = c.run().unwrap();
        assert_eq!(report.history.len(), 4);
        let r20 = report.recalls.iter().find(|r| r.k == 20).unwrap();
        // The synthetic graph has strong domain structure; even a tiny
        // model should beat random by a wide margin (random ≈ 20/400).
        assert!(r20.recall > 0.3, "recall@20 = {}", r20.recall);
        assert!(r20.rows_evaluated > 10);
    }

    #[test]
    fn objective_improves_end_to_end() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        let report = c.run().unwrap();
        let first = report.history.first().unwrap().objective.unwrap();
        let last = report.history.last().unwrap().objective.unwrap();
        assert!(last < first, "objective {first} -> {last}");
    }

    #[test]
    fn approximate_eval_close_to_exact() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        c.trainer.fit().unwrap();
        let exact = c.evaluate_with(&EvalConfig::default());
        let approx = c.evaluate_with(&EvalConfig {
            approximate: true,
            mips_probes: 6,
            ..EvalConfig::default()
        });
        let e20 = exact.iter().find(|r| r.k == 20).unwrap().recall;
        let a20 = approx.iter().find(|r| r.k == 20).unwrap().recall;
        // Approximate MIPS is a lower bound but should be in the ballpark
        // (paper: "a lower bound of true recall with high probability").
        assert!(a20 <= e20 + 0.05, "approx {a20} should not exceed exact {e20}");
        assert!(a20 > e20 * 0.5, "approx {a20} too far below exact {e20}");
    }
}
