//! The experiment coordinator — the leader process of the L3 layer.
//!
//! The job lifecycle itself (dataset acquisition → strong-generalization
//! split → topology/capacity planning → engine selection → step-wise epoch
//! loop with hooks → checkpoints → reports) lives in [`session`]: a
//! [`TrainSession`] is the resumable, observable unit of work every driver
//! builds on. This module keeps two thin drivers over sessions:
//!
//! * [`Coordinator`] — the original fire-and-forget WebGraph runner, now a
//!   compat shim that wraps a session over a
//!   [`crate::data::WebGraphSource`];
//! * [`grid_search`] — the §6.1 hyper-parameter sweep, one session per
//!   grid cell.

pub mod grid;
pub mod pipeline;
pub mod session;

pub use grid::{grid_search, GridPoint, GridSpec};
pub use pipeline::{BatchFeeder, BoundedQueue, CloseGuard, FEED_CHUNK_ROWS};
pub use session::{
    CheckpointEvery, EarlyStopOnPlateau, EarlyStopOnRecall, EpochHook, EvalEvery, HookAction,
    TrainSession,
};

use crate::als::SolveEngine;
use crate::config::AlxConfig;
use crate::data::{DataSource, IngestReport, WebGraphSource};
use crate::eval::{EvalConfig, RecallReport};
use crate::sparse::SpillStats;
use crate::webgraph::GeneratedGraph;

/// End-of-run report.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub history: Vec<crate::als::EpochStats>,
    pub recalls: Vec<RecallReport>,
    pub epoch_seconds_mean: f64,
    pub simulated_epoch_seconds: f64,
    pub comm_bytes_per_epoch: u64,
    /// Per-collective op/byte totals for the whole run — the transport
    /// conformance oracle (a `tcp` run must equal its `local` twin).
    pub comm: crate::collectives::CommSnapshot,
    /// Peak resident set size of the process at the end of the run
    /// (`VmHWM`; 0 on platforms without procfs).
    pub peak_rss_bytes: u64,
    /// Streaming-ingestion accounting (None for in-memory sources).
    pub ingest: Option<IngestReport>,
    /// Spilled-shard accounting — bank bytes, shard faults, prefetch hits
    /// (None when the matrices are fully resident).
    pub spill: Option<SpillStats>,
    /// Spilled-model accounting — table-bank bytes, table-shard faults,
    /// prefetch hits for W + H combined (None when the model is fully
    /// resident).
    pub table_spill: Option<SpillStats>,
}

/// Compat shim: the classic WebGraph job driver. Wraps a [`TrainSession`]
/// over a [`WebGraphSource`]; `cfg`, `split` and `trainer` are reachable
/// through `Deref`, so existing callers keep working unchanged. New code
/// should drive [`TrainSession`] directly (checkpoints, hooks, resume).
pub struct Coordinator {
    /// Generator provenance of the synthetic dataset.
    pub graph: GeneratedGraph,
    /// The underlying session (also reachable via `Deref`).
    pub session: TrainSession,
}

impl std::ops::Deref for Coordinator {
    type Target = TrainSession;

    fn deref(&self) -> &TrainSession {
        &self.session
    }
}

impl std::ops::DerefMut for Coordinator {
    fn deref_mut(&mut self) -> &mut TrainSession {
        &mut self.session
    }
}

impl Coordinator {
    /// Prepare a job from a resolved config (native engine).
    pub fn prepare(cfg: AlxConfig) -> anyhow::Result<Coordinator> {
        let engine: Option<Box<dyn SolveEngine>> = None;
        Self::prepare_with(cfg, engine)
    }

    /// Prepare with an explicit engine override (`None` → per-config).
    pub fn prepare_with(
        cfg: AlxConfig,
        engine: Option<Box<dyn SolveEngine>>,
    ) -> anyhow::Result<Coordinator> {
        let source = WebGraphSource::from_config(&cfg);
        let dataset = source.load()?;
        let meta = dataset
            .graph
            .clone()
            .expect("webgraph source always yields generator metadata");
        // Rebuild the classic GeneratedGraph view for compat callers; the
        // adjacency clone is the price of this shim only — plain sessions
        // keep the matrix solely inside the trainer's sharded storage.
        let graph = GeneratedGraph {
            adjacency: dataset.matrix.clone(),
            domains: meta.domains,
            num_domains: meta.num_domains,
            filtered_nodes: meta.filtered_nodes,
        };
        let session = TrainSession::from_dataset(dataset, cfg, engine)?;
        Ok(Coordinator { graph, session })
    }

    /// Train to the configured epoch count and evaluate (a thin driver
    /// over [`TrainSession::run`]).
    pub fn run(&mut self) -> anyhow::Result<RunReport> {
        self.session.run()
    }

    /// Evaluate Recall@{20,50} on the held-out strong-generalization rows.
    pub fn evaluate(&self) -> anyhow::Result<Vec<RecallReport>> {
        self.session.evaluate()
    }

    /// Evaluate with an explicit eval config.
    pub fn evaluate_with(&self, eval_cfg: &EvalConfig) -> Vec<RecallReport> {
        self.session.evaluate_with(eval_cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::als::TrainConfig;

    fn tiny_cfg() -> AlxConfig {
        AlxConfig {
            scale: 0.0008, // ~400 nodes of WebGraph-in-dense
            cores: 4,
            train: TrainConfig {
                dim: 16,
                epochs: 4,
                lambda: 0.03,
                alpha: 0.01,
                batch_rows: 32,
                batch_width: 8,
                ..TrainConfig::default()
            },
            ..AlxConfig::default()
        }
    }

    #[test]
    fn end_to_end_learns_structure() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        let report = c.run().unwrap();
        assert_eq!(report.history.len(), 4);
        let r20 = report.recalls.iter().find(|r| r.k == 20).unwrap();
        // The synthetic graph has strong domain structure; even a tiny
        // model should beat random by a wide margin (random ≈ 20/400).
        assert!(r20.recall > 0.3, "recall@20 = {}", r20.recall);
        assert!(r20.rows_evaluated > 10);
    }

    #[test]
    fn objective_improves_end_to_end() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        let report = c.run().unwrap();
        let first = report.history.first().unwrap().objective.unwrap();
        let last = report.history.last().unwrap().objective.unwrap();
        assert!(last < first, "objective {first} -> {last}");
    }

    #[test]
    fn approximate_eval_close_to_exact() {
        let mut c = Coordinator::prepare(tiny_cfg()).unwrap();
        c.trainer.fit().unwrap();
        let exact = c.evaluate_with(&EvalConfig::default());
        let approx = c.evaluate_with(&EvalConfig {
            approximate: true,
            mips_probes: 6,
            ..EvalConfig::default()
        });
        let e20 = exact.iter().find(|r| r.k == 20).unwrap().recall;
        let a20 = approx.iter().find(|r| r.k == 20).unwrap().recall;
        // Approximate MIPS is a lower bound but should be in the ballpark
        // (paper: "a lower bound of true recall with high probability").
        assert!(a20 <= e20 + 0.05, "approx {a20} should not exceed exact {e20}");
        assert!(a20 > e20 * 0.5, "approx {a20} too far below exact {e20}");
    }

    #[test]
    fn coordinator_fields_reachable_through_deref() {
        let c = Coordinator::prepare(tiny_cfg()).unwrap();
        // The compat surface: cfg/test/trainer as before, graph inherent.
        assert_eq!(c.cfg.train.dim, 16);
        assert!(c.test.len() < c.graph.nodes());
        assert_eq!(c.trainer.current_epoch(), 0);
        assert_eq!(c.dataset.rows, c.graph.nodes());
    }
}
