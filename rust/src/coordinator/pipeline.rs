//! Host input pipeline (paper Fig. 1): "a batch of data consisting of
//! several user histories are continuously fed from the host CPU to TPU
//! devices connected to that host".
//!
//! [`BatchFeeder`] prepares dense batches on a background host thread and
//! hands them to the consumer through a bounded queue, so batching (host
//! work) overlaps gather/solve/scatter (device work) — the same
//! producer/consumer overlap a real TPU input pipeline provides. The
//! queue is deliberately bounded (default 4) to model finite host-side
//! staging memory and to exert backpressure on the producer.

use crate::densebatch::{DenseBatch, DenseBatcher};
use crate::sparse::Csr;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Bounded blocking queue.
struct Bounded<T> {
    q: Mutex<(VecDeque<T>, bool)>, // (items, producer_done)
    cap: usize,
    cv: Condvar,
}

impl<T> Bounded<T> {
    fn new(cap: usize) -> Self {
        Bounded { q: Mutex::new((VecDeque::new(), false)), cap, cv: Condvar::new() }
    }

    fn push(&self, item: T) {
        let mut g = self.q.lock().unwrap();
        while g.0.len() >= self.cap {
            g = self.cv.wait(g).unwrap();
        }
        g.0.push_back(item);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.q.lock().unwrap().1 = true;
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<T> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(item) = g.0.pop_front() {
                self.cv.notify_all();
                return Some(item);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Streams dense batches for a set of rows, prepared on a host thread.
pub struct BatchFeeder {
    queue: Arc<Bounded<DenseBatch>>,
    producer: Option<std::thread::JoinHandle<()>>,
}

impl BatchFeeder {
    /// Start feeding batches of `rows` of `matrix`. `depth` bounds the
    /// number of staged batches (host memory / backpressure).
    pub fn start(matrix: Arc<Csr>, rows: Vec<u32>, batcher: DenseBatcher, depth: usize) -> Self {
        let queue = Arc::new(Bounded::new(depth.max(1)));
        let q2 = Arc::clone(&queue);
        let producer = std::thread::spawn(move || {
            // Produce incrementally (chunk of rows at a time) so staging
            // memory stays bounded even for huge shards.
            let chunk = 512usize;
            for ids in rows.chunks(chunk) {
                for batch in batcher.batch_rows_of(&matrix, ids) {
                    q2.push(batch);
                }
            }
            q2.close();
        });
        BatchFeeder { queue, producer: Some(producer) }
    }

    /// Next prepared batch, blocking until one is staged; `None` when the
    /// row stream is exhausted.
    pub fn next(&self) -> Option<DenseBatch> {
        self.queue.pop()
    }
}

impl Drop for BatchFeeder {
    fn drop(&mut self) {
        // Drain so the producer can finish, then join.
        while self.queue.pop().is_some() {}
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn matrix(rows: usize) -> Csr {
        let mut rng = Pcg64::new(5);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = 1 + rng.range(0, 10);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, 50) as u32);
            }
            for c in seen {
                t.push((r, c, 1.0));
            }
        }
        Csr::from_coo(rows, 50, &t)
    }

    #[test]
    fn feeder_yields_same_batches_as_direct_batching() {
        let m = Arc::new(matrix(100));
        let batcher = DenseBatcher::new(16, 4);
        let rows: Vec<u32> = (0..100).collect();

        // NOTE: the feeder chunks rows (512 > 100 here, so one chunk) —
        // identical batching to the direct call.
        let direct = batcher.batch_rows_of(&m, &rows);
        let feeder = BatchFeeder::start(Arc::clone(&m), rows, batcher, 4);
        let mut streamed = Vec::new();
        while let Some(b) = feeder.next() {
            streamed.push(b);
        }
        assert_eq!(streamed.len(), direct.len());
        for (a, b) in streamed.iter().zip(&direct) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // With depth 1 and a slow consumer, the producer cannot run ahead:
        // at no point can more than depth+1 batches exist outside the
        // consumer. Indirect check: everything still arrives, in order.
        let m = Arc::new(matrix(60));
        let batcher = DenseBatcher::new(4, 4);
        let rows: Vec<u32> = (0..60).collect();
        let feeder = BatchFeeder::start(Arc::clone(&m), rows.clone(), batcher.clone(), 1);
        let mut seen_rows = Vec::new();
        while let Some(b) = feeder.next() {
            std::thread::sleep(std::time::Duration::from_micros(200));
            seen_rows.extend(b.segment_rows.iter().copied());
        }
        let expected: Vec<u32> =
            rows.iter().copied().filter(|&r| m.row_len(r as usize) > 0).collect();
        assert_eq!(seen_rows, expected);
    }

    #[test]
    fn dropping_mid_stream_does_not_deadlock() {
        let m = Arc::new(matrix(500));
        let batcher = DenseBatcher::new(4, 4);
        let feeder = BatchFeeder::start(Arc::clone(&m), (0..500).collect(), batcher, 2);
        let _first = feeder.next();
        drop(feeder); // must join the producer cleanly
    }
}
