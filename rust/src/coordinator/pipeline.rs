//! Host input pipeline (paper Fig. 1): "a batch of data consisting of
//! several user histories are continuously fed from the host CPU to TPU
//! devices connected to that host".
//!
//! [`BatchFeeder`] prepares dense batches on a background host thread and
//! hands them to the consumer through a bounded queue, so batching (host
//! work) overlaps gather/solve/scatter (device work) — the same
//! producer/consumer overlap a real TPU input pipeline provides. The
//! queue is deliberately bounded (default 4) to model finite host-side
//! staging memory and to exert backpressure on the producer.

use crate::densebatch::{DenseBatch, DenseBatcher};
use crate::sparse::RowMatrix;
use crate::util::timer::Profiler;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Rows batched per producer step: staging memory stays bounded even for
/// huge shards, and the batch stream is a pure function of the row list
/// (chunking included), so every consumer sees the same batches in the
/// same order regardless of thread timing.
pub const FEED_CHUNK_ROWS: usize = 512;

/// Bounded blocking MPMC queue — the backpressure primitive behind both
/// the [`BatchFeeder`] and the trainer's double-buffered scatter stage.
pub struct BoundedQueue<T> {
    q: Mutex<(VecDeque<T>, bool)>, // (items, producer_done)
    cap: usize,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue { q: Mutex::new((VecDeque::new(), false)), cap: cap.max(1), cv: Condvar::new() }
    }

    /// Block until there is room, then enqueue. Once the queue is closed
    /// the item is dropped instead — a producer must never block forever
    /// on a consumer that is gone (see [`CloseGuard`]).
    pub fn push(&self, item: T) {
        let mut g = self.q.lock().unwrap();
        while g.0.len() >= self.cap && !g.1 {
            g = self.cv.wait(g).unwrap();
        }
        if g.1 {
            return;
        }
        g.0.push_back(item);
        self.cv.notify_all();
    }

    /// Mark the stream finished; pending items still drain.
    pub fn close(&self) {
        self.q.lock().unwrap().1 = true;
        self.cv.notify_all();
    }

    /// Blocking dequeue; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(item) = g.0.pop_front() {
                self.cv.notify_all();
                return Some(item);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Closes a [`BoundedQueue`] when dropped. Pipeline stages hold one so a
/// panic in either stage closes the queue during unwinding, unblocking
/// the peer stage instead of deadlocking the epoch: the consumer's `pop`
/// drains and returns `None`, and a producer's `push` stops blocking.
pub struct CloseGuard<'a, T>(pub &'a BoundedQueue<T>);

impl<T> Drop for CloseGuard<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Streams dense batches for a set of rows, prepared on a host thread.
pub struct BatchFeeder {
    queue: Arc<BoundedQueue<DenseBatch>>,
    producer: Option<std::thread::JoinHandle<()>>,
}

impl BatchFeeder {
    /// Start feeding batches of `rows` of `matrix`. `depth` bounds the
    /// number of staged batches (host memory / backpressure). Generic over
    /// [`RowMatrix`] so shard-local [`crate::sparse::ShardedCsr`] storage
    /// feeds exactly like a monolithic [`crate::sparse::Csr`].
    pub fn start<M: RowMatrix + Send + Sync + 'static>(
        matrix: Arc<M>,
        rows: Vec<u32>,
        batcher: DenseBatcher,
        depth: usize,
    ) -> Self {
        Self::start_profiled(matrix, rows, batcher, depth, None)
    }

    /// [`BatchFeeder::start`] with host batching time accounted under the
    /// profiler's `densebatch` bucket (the trainer's epoch breakdown).
    pub fn start_profiled<M: RowMatrix + Send + Sync + 'static>(
        matrix: Arc<M>,
        rows: Vec<u32>,
        batcher: DenseBatcher,
        depth: usize,
        profiler: Option<Arc<Profiler>>,
    ) -> Self {
        let queue = Arc::new(BoundedQueue::new(depth));
        let q2 = Arc::clone(&queue);
        let producer = std::thread::spawn(move || {
            // Closes the queue however this thread exits (panic included),
            // so the consumer can never block on a dead producer.
            let _guard = CloseGuard(&q2);
            for ids in rows.chunks(FEED_CHUNK_ROWS) {
                let batches = match &profiler {
                    Some(p) => {
                        p.time("densebatch", || batcher.batch_rows_of(matrix.as_ref(), ids))
                    }
                    None => batcher.batch_rows_of(matrix.as_ref(), ids),
                };
                for batch in batches {
                    q2.push(batch);
                }
            }
        });
        BatchFeeder { queue, producer: Some(producer) }
    }

    /// Next prepared batch, blocking until one is staged; `None` when the
    /// row stream is exhausted.
    pub fn next(&self) -> Option<DenseBatch> {
        self.queue.pop()
    }
}

impl Drop for BatchFeeder {
    fn drop(&mut self) {
        // Drain so the producer can finish, then join.
        while self.queue.pop().is_some() {}
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use crate::util::Pcg64;

    fn matrix(rows: usize) -> Csr {
        let mut rng = Pcg64::new(5);
        let mut t = Vec::new();
        for r in 0..rows as u32 {
            let len = 1 + rng.range(0, 10);
            let mut seen = std::collections::HashSet::new();
            while seen.len() < len {
                seen.insert(rng.range(0, 50) as u32);
            }
            for c in seen {
                t.push((r, c, 1.0));
            }
        }
        Csr::from_coo(rows, 50, &t)
    }

    #[test]
    fn feeder_yields_same_batches_as_direct_batching() {
        let m = Arc::new(matrix(100));
        let batcher = DenseBatcher::new(16, 4);
        let rows: Vec<u32> = (0..100).collect();

        // NOTE: the feeder chunks rows (512 > 100 here, so one chunk) —
        // identical batching to the direct call.
        let direct = batcher.batch_rows_of(&m, &rows);
        let feeder = BatchFeeder::start(Arc::clone(&m), rows, batcher, 4);
        let mut streamed = Vec::new();
        while let Some(b) = feeder.next() {
            streamed.push(b);
        }
        assert_eq!(streamed.len(), direct.len());
        for (a, b) in streamed.iter().zip(&direct) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // With depth 1 and a slow consumer, the producer cannot run ahead:
        // at no point can more than depth+1 batches exist outside the
        // consumer. Indirect check: everything still arrives, in order.
        let m = Arc::new(matrix(60));
        let batcher = DenseBatcher::new(4, 4);
        let rows: Vec<u32> = (0..60).collect();
        let feeder = BatchFeeder::start(Arc::clone(&m), rows.clone(), batcher.clone(), 1);
        let mut seen_rows = Vec::new();
        while let Some(b) = feeder.next() {
            std::thread::sleep(std::time::Duration::from_micros(200));
            seen_rows.extend(b.segment_rows.iter().copied());
        }
        let expected: Vec<u32> =
            rows.iter().copied().filter(|&r| m.row_len(r as usize) > 0).collect();
        assert_eq!(seen_rows, expected);
    }

    #[test]
    fn feeder_chunking_is_deterministic_past_chunk_boundary() {
        // More rows than FEED_CHUNK_ROWS: the stream must equal direct
        // batching applied chunk by chunk, independent of consumer timing.
        let rows_n = FEED_CHUNK_ROWS + 173;
        let m = Arc::new(matrix(rows_n));
        let batcher = DenseBatcher::new(8, 4);
        let rows: Vec<u32> = (0..rows_n as u32).collect();
        let mut expected = Vec::new();
        for ids in rows.chunks(FEED_CHUNK_ROWS) {
            expected.extend(batcher.batch_rows_of(&m, ids));
        }
        let feeder = BatchFeeder::start(Arc::clone(&m), rows, batcher, 3);
        let mut streamed = Vec::new();
        while let Some(b) = feeder.next() {
            streamed.push(b);
        }
        assert_eq!(streamed, expected);
    }

    #[test]
    fn bounded_queue_fifo_and_close_semantics() {
        let q = BoundedQueue::new(2);
        q.push(1);
        q.push(2);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // stays closed
    }

    #[test]
    fn push_after_close_drops_instead_of_blocking() {
        // A full, closed queue must not block the producer (the panic
        // recovery path: CloseGuard closed it because the consumer died).
        let q = BoundedQueue::new(1);
        q.push(1);
        q.close();
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn dropping_mid_stream_does_not_deadlock() {
        let m = Arc::new(matrix(500));
        let batcher = DenseBatcher::new(4, 4);
        let feeder = BatchFeeder::start(Arc::clone(&m), (0..500).collect(), batcher, 2);
        let _first = feeder.next();
        drop(feeder); // must join the producer cleanly
    }
}
