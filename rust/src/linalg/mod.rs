//! Dense linear algebra for the ALS normal equations.
//!
//! The paper's per-row solve (Algorithm 1 line 10 / Algorithm 2 line 17) is
//! a `d×d` symmetric positive-definite system `(αG + λI + Σ h⊗h) w = Σ y·h`.
//! §4.5 compares four solvers — LU, QR, Cholesky and Conjugate Gradients —
//! and finds CG scales best on the MXU. All four are implemented here for
//! the native engine and mirrored in `python/compile/model.py` for the XLA
//! engine, so Figure 5 can be regenerated on either path.

pub mod mat;
pub mod solvers;

pub use mat::{syrk_rankk_upper, syrk_rankk_upper_scalar, syrk_update, Mat, Vecf, SYRK_CHUNK_ROWS};
pub use solvers::{
    batched_ialspp_parallel, batched_solve, batched_solve_parallel, ialspp_solve, solve_cg,
    solve_cholesky, solve_lu, solve_qr, SolveOptions, SolverKind,
};
