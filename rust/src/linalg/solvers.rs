//! The four linear-system solvers compared in the paper's §4.5 / Figure 5.
//!
//! All solve `A x = b` with `A` the `d×d` ALS normal matrix
//! `αG + λI + Σ h⊗h` — symmetric and (with λ>0) positive definite. LU and
//! QR do not exploit symmetry (the paper includes them as the generic
//! alternatives), Cholesky does, and CG is the iterative MXU-friendly
//! option the paper ultimately recommends.
//!
//! A `bf16_accumulate` option rounds every accumulation step to bfloat16 —
//! used by `als::PrecisionPolicy::NaiveBf16` to reproduce the Figure 4
//! training collapse.

use super::mat::{dot, Mat};
use crate::util::bf16::Bf16;

/// Which linear solver the ALS step uses (paper §4.5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    Lu,
    Qr,
    Cholesky,
    /// Conjugate gradients with a fixed iteration budget (defaults to ~d/4,
    /// matching the paper's observation that a few MXU-heavy iterations
    /// suffice for the well-conditioned regularized normal equations).
    Cg,
}

impl SolverKind {
    pub const ALL: [SolverKind; 4] = [SolverKind::Lu, SolverKind::Qr, SolverKind::Cholesky, SolverKind::Cg];

    pub fn name(self) -> &'static str {
        match self {
            SolverKind::Lu => "lu",
            SolverKind::Qr => "qr",
            SolverKind::Cholesky => "cholesky",
            SolverKind::Cg => "cg",
        }
    }

    pub fn parse(s: &str) -> Option<SolverKind> {
        match s.to_ascii_lowercase().as_str() {
            "lu" => Some(SolverKind::Lu),
            "qr" => Some(SolverKind::Qr),
            "cholesky" | "chol" => Some(SolverKind::Cholesky),
            "cg" | "conjugate-gradients" => Some(SolverKind::Cg),
            _ => None,
        }
    }

    /// Stable wire/on-disk code (dist SOLVE_PASS frames).
    pub fn code(self) -> u8 {
        match self {
            SolverKind::Lu => 0,
            SolverKind::Qr => 1,
            SolverKind::Cholesky => 2,
            SolverKind::Cg => 3,
        }
    }

    pub fn from_code(code: u8) -> Option<SolverKind> {
        match code {
            0 => Some(SolverKind::Lu),
            1 => Some(SolverKind::Qr),
            2 => Some(SolverKind::Cholesky),
            3 => Some(SolverKind::Cg),
            _ => None,
        }
    }
}

/// Options shared by the solver entry points.
#[derive(Clone, Copy, Debug)]
pub struct SolveOptions {
    /// CG iteration budget; `0` means `max(8, d/4)`.
    pub cg_iters: usize,
    /// Round accumulations to bf16 (Figure 4's "naive bf16" mode).
    pub bf16_accumulate: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions { cg_iters: 0, bf16_accumulate: false }
    }
}

/// Internal accumulation step. The solver's arithmetic units accumulate in
/// f32 even in the paper's bf16 configuration (TPU MXU/VPU semantics), so
/// this is a pass-through; the bf16 damage happens to the solver *inputs*
/// (statistics rounded by `als::stats`) and *outputs* (rounded in
/// [`solve`]) — which is exactly the Figure 4 failure mode.
#[inline]
fn acc(x: f32, _opts: &SolveOptions) -> f32 {
    x
}

/// Solve via LU decomposition with partial pivoting (in-place Doolittle).
pub fn solve_lu(a: &Mat, b: &[f32], opts: &SolveOptions) -> Vec<f32> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    let mut lu = a.data.clone();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // Pivot: largest |value| in column k at/below row k.
        let mut p = k;
        let mut best = lu[k * n + k].abs();
        for r in k + 1..n {
            let v = lu[r * n + k].abs();
            if v > best {
                best = v;
                p = r;
            }
        }
        if p != k {
            for c in 0..n {
                lu.swap(k * n + c, p * n + c);
            }
            piv.swap(k, p);
        }
        let pivot = lu[k * n + k];
        if pivot == 0.0 {
            continue; // singular column; downstream produces inf/nan like XLA would
        }
        for r in k + 1..n {
            let m = acc(lu[r * n + k] / pivot, opts);
            lu[r * n + k] = m;
            if m != 0.0 {
                for c in k + 1..n {
                    lu[r * n + c] = acc(lu[r * n + c] - m * lu[k * n + c], opts);
                }
            }
        }
    }
    // Forward substitution (Ly = Pb).
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[piv[i]];
        for j in 0..i {
            s = acc(s - lu[i * n + j] * y[j], opts);
        }
        y[i] = s;
    }
    // Back substitution (Ux = y).
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s = acc(s - lu[i * n + j] * x[j], opts);
        }
        x[i] = s / lu[i * n + i];
    }
    x
}

/// Solve via Householder QR: `A = QR`, `x = R⁻¹ Qᵀ b`.
pub fn solve_qr(a: &Mat, b: &[f32], opts: &SolveOptions) -> Vec<f32> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let mut r = a.data.clone();
    let mut qtb = b.to_vec();
    let mut v = vec![0.0f32; n];
    for k in 0..n {
        // Householder vector for column k.
        let mut norm_sq = 0.0f32;
        for i in k..n {
            let x = r[i * n + k];
            v[i] = x;
            norm_sq = acc(norm_sq + x * x, opts);
        }
        let norm = norm_sq.sqrt();
        if norm == 0.0 {
            continue;
        }
        let alpha = if v[k] >= 0.0 { -norm } else { norm };
        v[k] -= alpha;
        // ‖v‖² computed directly from the reflector (the sign choice above
        // guarantees |v[k]| ≥ norm, so this never cancels to zero).
        let mut vsq = 0.0f32;
        for i in k..n {
            vsq = acc(vsq + v[i] * v[i], opts);
        }
        let vsq = vsq.max(f32::MIN_POSITIVE);
        // Apply H = I - 2 v vᵀ / (vᵀv) to R (cols k..) and to qtb.
        for c in k..n {
            let mut s = 0.0f32;
            for i in k..n {
                s = acc(s + v[i] * r[i * n + c], opts);
            }
            let f = 2.0 * s / vsq;
            for i in k..n {
                r[i * n + c] = acc(r[i * n + c] - f * v[i], opts);
            }
        }
        let mut s = 0.0f32;
        for i in k..n {
            s = acc(s + v[i] * qtb[i], opts);
        }
        let f = 2.0 * s / vsq;
        for i in k..n {
            qtb[i] = acc(qtb[i] - f * v[i], opts);
        }
    }
    // Back substitution on R.
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = qtb[i];
        for j in i + 1..n {
            s = acc(s - r[i * n + j] * x[j], opts);
        }
        x[i] = s / r[i * n + i];
    }
    x
}

/// Solve via Cholesky (`A = L Lᵀ`), the classic choice for SPD normal
/// equations. Fails softly (NaNs) when A is not positive definite — which
/// is exactly what happens mid-training in naive-bf16 mode.
pub fn solve_cholesky(a: &Mat, b: &[f32], opts: &SolveOptions) -> Vec<f32> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let mut l = vec![0.0f32; n * n];
    for i in 0..n {
        // Row i against rows j <= i: the k-sums are dot products of the
        // already-computed row prefixes — contiguous, vectorized.
        let (prev, cur) = l.split_at_mut(i * n);
        let li = &mut cur[..n];
        for j in 0..i {
            let lj = &prev[j * n..j * n + j];
            let s = a[(i, j)] - dot(&li[..j], lj);
            li[j] = s / prev[j * n + j];
        }
        let s = a[(i, i)] - dot(&li[..i], &li[..i]);
        li[i] = acc(s, opts).sqrt(); // NaN if s < 0 (not PD)
    }
    // Ly = b
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let s = b[i] - dot(&l[i * n..i * n + i], &y[..i]);
        y[i] = acc(s, opts) / l[i * n + i];
    }
    // Lᵀx = y
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = acc(s, opts) / l[i * n + i];
    }
    x
}

/// Solve via conjugate gradients. The per-iteration work is one mat-vec —
/// the operation that maps onto the MXU, which is why the paper finds CG
/// the fastest option at large d (§4.5).
pub fn solve_cg(a: &Mat, b: &[f32], opts: &SolveOptions) -> Vec<f32> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    // Budget: the regularized ALS normal equations are well conditioned, so
    // convergence (rel. residual < 1e-6) typically takes 10-30 iterations;
    // 2n is a safe ceiling with early exit.
    let iters = if opts.cg_iters == 0 { (2 * n).max(8) } else { opts.cg_iters };
    let mut x = vec![0.0f32; n];
    let mut r = b.to_vec(); // r = b - A·0
    let mut p = r.clone();
    let mut rs_old = dot(&r, &r);
    if rs_old == 0.0 {
        return x;
    }
    // Relative-residual stop: 1e-4 matches the f32 accuracy the ALS step
    // needs (solution error ~ tol·κ, and κ is small for the regularized
    // normal equations). Tightening to 1e-6 costs ~2× more iterations for
    // no recall/objective change — measured in EXPERIMENTS.md §Perf.
    let stop = 1e-4 * rs_old.sqrt();
    for _ in 0..iters {
        let ap = a.matvec(&p);
        let pap = dot(&p, &ap);
        if pap.abs() < f32::MIN_POSITIVE {
            break;
        }
        let alpha = rs_old / pap;
        for i in 0..n {
            x[i] = acc(x[i] + alpha * p[i], opts);
            r[i] = acc(r[i] - alpha * ap[i], opts);
        }
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() < stop {
            break;
        }
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = acc(r[i] + beta * p[i], opts);
        }
        rs_old = rs_new;
    }
    x
}

/// Dispatch a single solve. In naive-bf16 mode the solution is rounded to
/// bfloat16 on the way out (it is about to be stored/communicated in bf16
/// anyway — this is the paper's end-to-end-bf16 configuration).
pub fn solve(kind: SolverKind, a: &Mat, b: &[f32], opts: &SolveOptions) -> Vec<f32> {
    let mut x = match kind {
        SolverKind::Lu => solve_lu(a, b, opts),
        SolverKind::Qr => solve_qr(a, b, opts),
        SolverKind::Cholesky => solve_cholesky(a, b, opts),
        SolverKind::Cg => solve_cg(a, b, opts),
    };
    if opts.bf16_accumulate {
        for v in x.iter_mut() {
            *v = Bf16::round(*v);
        }
    }
    x
}

/// Solve a batch of systems `A_s x_s = b_s` (the "Solve" stage of Fig. 1).
/// `as_` holds S packed `d×d` matrices, `bs` S packed `d`-vectors; returns S
/// packed solutions.
pub fn batched_solve(
    kind: SolverKind,
    d: usize,
    as_: &[f32],
    bs: &[f32],
    opts: &SolveOptions,
) -> Vec<f32> {
    let s = bs.len() / d;
    assert_eq!(as_.len(), s * d * d);
    assert_eq!(bs.len(), s * d);
    let mut out = vec![0.0f32; s * d];
    let mut a = Mat::zeros(d, d);
    for i in 0..s {
        a.data.copy_from_slice(&as_[i * d * d..(i + 1) * d * d]);
        let x = solve(kind, &a, &bs[i * d..(i + 1) * d], opts);
        out[i * d..(i + 1) * d].copy_from_slice(&x);
    }
    out
}

/// [`batched_solve`] fanned out over `workers` threads. Each segment's
/// system is independent, so the solutions are bitwise identical to the
/// serial path for every worker count.
pub fn batched_solve_parallel(
    kind: SolverKind,
    d: usize,
    as_: &[f32],
    bs: &[f32],
    opts: &SolveOptions,
    workers: usize,
) -> Vec<f32> {
    let s = bs.len() / d;
    assert_eq!(as_.len(), s * d * d);
    assert_eq!(bs.len(), s * d);
    if workers <= 1 || s <= 1 {
        return batched_solve(kind, d, as_, bs, opts);
    }
    let solutions = crate::util::threads::parallel_map_indexed_with(workers, s, |i| {
        let a = Mat::from_rows(d, d, &as_[i * d * d..(i + 1) * d * d]);
        solve(kind, &a, &bs[i * d..(i + 1) * d], opts)
    });
    let mut out = Vec::with_capacity(s * d);
    for x in solutions {
        out.extend_from_slice(&x);
    }
    out
}

/// iALS++ subspace solve (Rendle et al., arxiv 2110.14044): instead of a
/// full `d×d` factorization, run `sweeps` rounds of block-coordinate
/// (block Gauss-Seidel) updates over `d / block_dim` blocks of size
/// `block_dim`, solving one `block_dim × block_dim` subsystem per block
/// with `kind` as the sub-block solver. Starting from `x = 0`, a fixed
/// sweep count makes the result a pure function of `(A, b)` — no
/// tolerance-dependent early exit — so the trainer's bitwise-determinism
/// contract holds unchanged.
///
/// Cost per sweep is `O(d² + d·block_dim²)` versus the direct solvers'
/// `O(d³)`; the ALS normal equations are regularized and strongly
/// diagonally dominant, so a few sweeps land close enough for the outer
/// ALS iteration to keep converging (the engine uses 3).
///
/// `block_dim` must divide `d` (config parsing enforces this); with
/// `block_dim == d` the first sweep is an exact solve and further sweeps
/// are idempotent.
pub fn ialspp_solve(
    kind: SolverKind,
    a: &Mat,
    b: &[f32],
    opts: &SolveOptions,
    block_dim: usize,
    sweeps: usize,
) -> Vec<f32> {
    let d = a.rows;
    assert_eq!(a.cols, d);
    assert_eq!(b.len(), d);
    assert!(block_dim > 0 && block_dim <= d && d % block_dim == 0, "block_dim must divide d");
    let p = block_dim;
    let mut x = vec![0.0f32; d];
    let mut abb = Mat::zeros(p, p);
    let mut rhs = vec![0.0f32; p];
    for _ in 0..sweeps.max(1) {
        let mut b0 = 0;
        while b0 < d {
            // rhs_t = b[t] − Σ_{j∉B} A[t,j]·x[j], computed as the full row
            // dot minus the in-block dot (fixed formula, deterministic).
            for t in 0..p {
                let i = b0 + t;
                let arow = a.row(i);
                let full = dot(arow, &x);
                let inblk = dot(&arow[b0..b0 + p], &x[b0..b0 + p]);
                rhs[t] = acc(b[i] - (full - inblk), opts);
                for u in 0..p {
                    abb.data[t * p + u] = arow[b0 + u];
                }
            }
            let xb = solve(kind, &abb, &rhs, opts);
            x[b0..b0 + p].copy_from_slice(&xb);
            b0 += p;
        }
    }
    x
}

/// Batched [`ialspp_solve`] fanned out over `workers` threads with the
/// same fixed per-index work assignment as [`batched_solve_parallel`], so
/// solutions are bitwise identical to serial for every worker count.
pub fn batched_ialspp_parallel(
    kind: SolverKind,
    d: usize,
    as_: &[f32],
    bs: &[f32],
    opts: &SolveOptions,
    block_dim: usize,
    sweeps: usize,
    workers: usize,
) -> Vec<f32> {
    let s = bs.len() / d;
    assert_eq!(as_.len(), s * d * d);
    assert_eq!(bs.len(), s * d);
    let solve_one = |i: usize| {
        let a = Mat::from_rows(d, d, &as_[i * d * d..(i + 1) * d * d]);
        ialspp_solve(kind, &a, &bs[i * d..(i + 1) * d], opts, block_dim, sweeps)
    };
    let solutions: Vec<Vec<f32>> = if workers <= 1 || s <= 1 {
        (0..s).map(solve_one).collect()
    } else {
        crate::util::threads::parallel_map_indexed_with(workers, s, solve_one)
    };
    let mut out = Vec::with_capacity(s * d);
    for x in solutions {
        out.extend_from_slice(&x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Random SPD matrix A = MᵀM + c·I.
    fn random_spd(n: usize, rng: &mut Pcg64) -> Mat {
        let m = Mat::randn(n + 3, n, 1.0, rng);
        let mut a = m.gramian();
        for i in 0..n {
            a[(i, i)] += 0.5;
        }
        a
    }

    fn residual(a: &Mat, x: &[f32], b: &[f32]) -> f32 {
        let ax = a.matvec(x);
        let num: f32 = ax.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f32>().sqrt();
        let den: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-20);
        num / den
    }

    #[test]
    fn all_solvers_agree_on_spd_systems() {
        let mut rng = Pcg64::new(31);
        for &n in &[1usize, 2, 4, 8, 16, 32] {
            let a = random_spd(n, &mut rng);
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let opts = SolveOptions::default();
            for kind in SolverKind::ALL {
                let x = solve(kind, &a, &b, &opts);
                let r = residual(&a, &x, &b);
                assert!(r < 5e-3, "{kind:?} n={n} residual={r}");
            }
        }
    }

    #[test]
    fn lu_handles_nonsymmetric() {
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 0.5, 3.0]);
        let b = [5.0f32, 10.0];
        let x = solve_lu(&a, &b, &SolveOptions::default());
        assert!(residual(&a, &x, &b) < 1e-5);
    }

    #[test]
    fn lu_pivots_on_zero_diagonal() {
        // Leading zero forces a row swap.
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let b = [3.0f32, 7.0];
        let x = solve_lu(&a, &b, &SolveOptions::default());
        assert!((x[0] - 7.0).abs() < 1e-6 && (x[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn qr_handles_nonsymmetric() {
        let a = Mat::from_rows(3, 3, &[1.0, 2.0, 0.0, 0.0, 1.0, 1.0, 2.0, 0.0, 1.0]);
        let b = [1.0f32, 2.0, 3.0];
        let x = solve_qr(&a, &b, &SolveOptions::default());
        assert!(residual(&a, &x, &b) < 1e-5);
    }

    #[test]
    fn cholesky_rejects_indefinite_with_nan() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        let b = [1.0f32, 1.0];
        let x = solve_cholesky(&a, &b, &SolveOptions::default());
        assert!(x.iter().any(|v| v.is_nan()));
    }

    #[test]
    fn cg_converges_fast_on_well_conditioned() {
        let mut rng = Pcg64::new(37);
        let n = 64;
        let a = {
            let mut a = random_spd(n, &mut rng);
            for i in 0..n {
                a[(i, i)] += 10.0; // strong regularization -> tiny condition number
            }
            a
        };
        let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let x = solve_cg(&a, &b, &SolveOptions { cg_iters: 32, ..Default::default() });
        assert!(residual(&a, &x, &b) < 1e-3, "residual={}", residual(&a, &x, &b));
    }

    #[test]
    fn cg_zero_rhs_returns_zero() {
        let a = Mat::eye(4);
        let x = solve_cg(&a, &[0.0; 4], &SolveOptions::default());
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn batched_solve_matches_individual() {
        let mut rng = Pcg64::new(41);
        let d = 8;
        let s = 5;
        let mut as_ = Vec::new();
        let mut bs = Vec::new();
        for _ in 0..s {
            as_.extend_from_slice(&random_spd(d, &mut rng).data);
            bs.extend((0..d).map(|_| rng.next_f32()));
        }
        let opts = SolveOptions::default();
        let xs = batched_solve(SolverKind::Cholesky, d, &as_, &bs, &opts);
        for i in 0..s {
            let a = Mat::from_rows(d, d, &as_[i * d * d..(i + 1) * d * d]);
            let x1 = solve_cholesky(&a, &bs[i * d..(i + 1) * d], &opts);
            assert_eq!(&xs[i * d..(i + 1) * d], &x1[..]);
        }
    }

    #[test]
    fn bf16_accumulation_degrades_but_runs() {
        let mut rng = Pcg64::new(43);
        let n = 16;
        let a = random_spd(n, &mut rng);
        let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let opts = SolveOptions { bf16_accumulate: true, ..Default::default() };
        let x = solve(SolverKind::Cholesky, &a, &b, &opts);
        // Should produce finite output on a well-conditioned system but
        // rounded to bf16 (visibly larger residual than f32).
        let r = residual(&a, &x, &b);
        assert!(x.iter().all(|v| v.is_finite()));
        for &v in &x {
            assert_eq!(v, Bf16::round(v), "solution must be bf16-representable");
        }
        let x32 = solve(SolverKind::Cholesky, &a, &b, &SolveOptions::default());
        let r32 = residual(&a, &x32, &b);
        assert!(r >= r32, "bf16 path should not be more accurate: {r} vs {r32}");
    }

    #[test]
    fn ialspp_full_block_is_exact() {
        // block_dim == d: the first sweep is a direct solve.
        let mut rng = Pcg64::new(51);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let opts = SolveOptions::default();
        let x = ialspp_solve(SolverKind::Cholesky, &a, &b, &opts, n, 1);
        let x3 = ialspp_solve(SolverKind::Cholesky, &a, &b, &opts, n, 3);
        assert!(residual(&a, &x, &b) < 5e-3);
        assert_eq!(x, x3, "extra sweeps on the full block must be idempotent");
    }

    #[test]
    fn ialspp_converges_on_regularized_systems() {
        // The ALS regime: SPD with a strengthened diagonal. A few sweeps
        // of p-blocks must land near the direct solution.
        let mut rng = Pcg64::new(53);
        for &(n, p) in &[(16usize, 4usize), (32, 8), (64, 16)] {
            let mut a = random_spd(n, &mut rng);
            for i in 0..n {
                a[(i, i)] += 2.0;
            }
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let opts = SolveOptions::default();
            let x = ialspp_solve(SolverKind::Cholesky, &a, &b, &opts, p, 3);
            let r = residual(&a, &x, &b);
            assert!(r < 0.05, "n={n} p={p} residual={r}");
        }
    }

    #[test]
    fn batched_ialspp_parallel_bitwise_matches_serial() {
        let mut rng = Pcg64::new(57);
        let (d, p, s) = (16usize, 4usize, 7usize);
        let mut as_ = Vec::new();
        let mut bs = Vec::new();
        for _ in 0..s {
            as_.extend_from_slice(&random_spd(d, &mut rng).data);
            bs.extend((0..d).map(|_| rng.next_f32()));
        }
        let opts = SolveOptions::default();
        let serial = batched_ialspp_parallel(SolverKind::Qr, d, &as_, &bs, &opts, p, 3, 1);
        for workers in [2usize, 4, 8] {
            let par = batched_ialspp_parallel(SolverKind::Qr, d, &as_, &bs, &opts, p, 3, workers);
            assert_eq!(serial, par, "ialspp batch differs at workers={workers}");
        }
    }

    #[test]
    fn solver_kind_parse_roundtrip() {
        for k in SolverKind::ALL {
            assert_eq!(SolverKind::parse(k.name()), Some(k));
        }
        assert_eq!(SolverKind::parse("nope"), None);
    }
}
