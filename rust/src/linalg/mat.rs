//! Row-major dense matrix and vector helpers.
//!
//! Dimensions in ALX are small (`d ≤ 256`) but the *batch* of systems is
//! large, so the layout favours cache-friendly row access and the hot
//! kernels (`syrk_update`, `matmul_at_a`) are written as blocked loops the
//! compiler auto-vectorizes.

/// Convenience alias for an owned f32 vector.
pub type Vecf = Vec<f32>;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Random matrix with i.i.d. `N(0, scale²)` entries.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_normal() as f32 * scale).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose (out of place).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims must match");
        let mut out = Mat::zeros(self.rows, other.cols);
        // i-k-j loop order: unit-stride inner loop over `other` rows.
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (k, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Gramian `selfᵀ · self` exploiting symmetry (SYRK). Rows feed the
    /// blocked rank-k kernel in contiguous chunks — bitwise identical to
    /// row-at-a-time [`syrk_update`]s, one pass over `G` per chunk instead
    /// of one per row.
    pub fn gramian(&self) -> Mat {
        let d = self.cols;
        let mut g = Mat::zeros(d, d);
        if d > 0 {
            for chunk in self.data.chunks(SYRK_CHUNK_ROWS * d) {
                syrk_rankk_upper(&mut g.data, d, chunk);
            }
        }
        // Mirror the upper triangle into the lower.
        for i in 0..d {
            for j in 0..i {
                g.data[i * d + j] = g.data[j * d + i];
            }
        }
        g
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f32]) -> Vecf {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }

    /// Frobenius norm squared.
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max absolute element-wise difference to `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Round every entry to bf16 storage precision in place.
    pub fn round_bf16(&mut self) {
        crate::util::bf16::round_slice(&mut self.data);
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let o = i * 4;
        acc[0] += a[o] * b[o];
        acc[1] += a[o + 1] * b[o + 1];
        acc[2] += a[o + 2] * b[o + 2];
        acc[3] += a[o + 3] * b[o + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Rank-1 symmetric update of the packed row-major `d×d` buffer:
/// `G[i,j] += w * h[i]*h[j]` for the upper triangle `j >= i`.
#[inline]
pub fn syrk_update(g: &mut [f32], h: &[f32], w: f32) {
    let d = h.len();
    debug_assert_eq!(g.len(), d * d);
    for i in 0..d {
        let hi = w * h[i];
        if hi == 0.0 {
            continue;
        }
        // Zipped-slice form: no bounds checks, auto-vectorizes.
        let grow = &mut g[i * d + i..(i + 1) * d];
        for (gv, &hv) in grow.iter_mut().zip(&h[i..]) {
            *gv += hi * hv;
        }
    }
}

/// Rows per chunk fed to [`syrk_rankk_upper`] by the gramian/stats hot
/// paths: 16 × d=128 × 4 B = 8 KiB of staged rows, comfortably L1.
pub const SYRK_CHUNK_ROWS: usize = 16;

/// Rank-k symmetric update of the packed row-major `d×d` buffer:
/// `G[i,j] += Σ_s rows[s][i]·rows[s][j]` for the upper triangle `j ≥ i`,
/// where `rows` packs `k = rows.len()/d` rows back to back.
///
/// **Bitwise identical** to `k` sequential `syrk_update(g, row_s, 1.0)`
/// calls: every `G[i,j]` entry receives its per-row contributions as
/// separate IEEE f32 multiply-then-add operations in row (slot) order,
/// with the same `h[i] == 0.0` row skip, and nothing is reassociated or
/// FMA-contracted. The win is memory traffic: one read+write pass over
/// `G`'s upper triangle per *chunk* of k rows instead of per row — the
/// entry stays in a register across all k contributions.
///
/// With `--features simd` on x86_64 an AVX2 variant is dispatched at
/// runtime; its lane-vertical accumulation performs the same scalar
/// operation sequence per entry, so it is bitwise identical too (proven
/// by `simd_dispatch_matches_scalar` here and the SIMD identity test in
/// `tests/solver_equivalence.rs`).
pub fn syrk_rankk_upper(g: &mut [f32], d: usize, rows: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd::avx2_available() {
            // SAFETY: AVX2 presence checked at runtime.
            unsafe { simd::syrk_rankk_upper_avx2(g, d, rows) };
            return;
        }
    }
    syrk_rankk_upper_scalar(g, d, rows)
}

/// Scalar reference for [`syrk_rankk_upper`]; public so the SIMD path can
/// be proven bitwise-identical against it regardless of feature flags.
pub fn syrk_rankk_upper_scalar(g: &mut [f32], d: usize, rows: &[f32]) {
    if d == 0 {
        return;
    }
    debug_assert_eq!(g.len(), d * d);
    debug_assert_eq!(rows.len() % d, 0);
    let k = rows.len() / d;
    const NB: usize = 16;
    for i in 0..d {
        let grow = &mut g[i * d..(i + 1) * d];
        let mut j = i;
        // Full register-blocked tiles: a fixed-size accumulator array the
        // compiler keeps in vector registers (constant trip count).
        while j + NB <= d {
            let mut acc = [0.0f32; NB];
            acc.copy_from_slice(&grow[j..j + NB]);
            for s in 0..k {
                let hrow = &rows[s * d..(s + 1) * d];
                let hi = hrow[i];
                if hi == 0.0 {
                    continue;
                }
                let hj = &hrow[j..j + NB];
                for t in 0..NB {
                    acc[t] += hi * hj[t];
                }
            }
            grow[j..j + NB].copy_from_slice(&acc);
            j += NB;
        }
        // Tail entries one at a time, contributions still in slot order.
        while j < d {
            let mut a = grow[j];
            for s in 0..k {
                let hi = rows[s * d + i];
                if hi == 0.0 {
                    continue;
                }
                a += hi * rows[s * d + j];
            }
            grow[j] = a;
            j += 1;
        }
    }
}

/// AVX2 variant of the rank-k update (`--features simd`, x86_64 only).
/// Uses `_mm256_mul_ps` + `_mm256_add_ps` — never FMA — with lane-vertical
/// accumulation, so each `G[i,j]` sees exactly the scalar kernel's
/// operation sequence and the result is bitwise identical.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    pub fn avx2_available() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn syrk_rankk_upper_avx2(g: &mut [f32], d: usize, rows: &[f32]) {
        use std::arch::x86_64::*;
        if d == 0 {
            return;
        }
        debug_assert_eq!(g.len(), d * d);
        debug_assert_eq!(rows.len() % d, 0);
        let k = rows.len() / d;
        for i in 0..d {
            let grow = &mut g[i * d..(i + 1) * d];
            let mut j = i;
            while j + 16 <= d {
                let mut acc0 = _mm256_loadu_ps(grow.as_ptr().add(j));
                let mut acc1 = _mm256_loadu_ps(grow.as_ptr().add(j + 8));
                for s in 0..k {
                    let hi = *rows.get_unchecked(s * d + i);
                    if hi == 0.0 {
                        continue;
                    }
                    let vhi = _mm256_set1_ps(hi);
                    let hj = rows.as_ptr().add(s * d + j);
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(vhi, _mm256_loadu_ps(hj)));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(vhi, _mm256_loadu_ps(hj.add(8))));
                }
                _mm256_storeu_ps(grow.as_mut_ptr().add(j), acc0);
                _mm256_storeu_ps(grow.as_mut_ptr().add(j + 8), acc1);
                j += 16;
            }
            while j < d {
                let mut a = grow[j];
                for s in 0..k {
                    let hi = *rows.get_unchecked(s * d + i);
                    if hi == 0.0 {
                        continue;
                    }
                    a += hi * *rows.get_unchecked(s * d + j);
                }
                grow[j] = a;
                j += 1;
            }
        }
    }
}

/// Mirror the upper triangle of a packed `d×d` buffer into the lower.
pub fn symmetrize_upper(g: &mut [f32], d: usize) {
    debug_assert_eq!(g.len(), d * d);
    for i in 0..d {
        for j in 0..i {
            g[i * d + j] = g[j * d + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn matmul_matches_hand_example() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(5, 5, 1.0, &mut rng);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gramian_matches_explicit_ata() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(17, 6, 1.0, &mut rng);
        let g = a.gramian();
        let explicit = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&explicit) < 1e-4);
    }

    #[test]
    fn gramian_is_symmetric() {
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(9, 4, 2.0, &mut rng);
        let g = a.gramian();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(4);
        let a = Mat::randn(3, 7, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(5);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32 + 0.5).collect();
        let xm = Mat::from_rows(4, 1, &x);
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..10 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let expect: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }

    #[test]
    fn rankk_update_bitwise_equals_sequential_rank1() {
        let mut rng = Pcg64::new(77);
        // Cover sub-tile dims, tile-boundary dims and tails, with and
        // without exact zeros (the row-skip path must match exactly).
        for &d in &[1usize, 3, 7, 15, 16, 17, 33, 48, 64] {
            for &k in &[1usize, 2, 5, 16, 31] {
                let mut rows: Vec<f32> =
                    (0..k * d).map(|_| rng.next_normal() as f32).collect();
                // Sprinkle exact zeros and a negative zero.
                for idx in (0..rows.len()).step_by(7) {
                    rows[idx] = 0.0;
                }
                if !rows.is_empty() {
                    rows[0] = -0.0;
                }
                let mut g_ref: Vec<f32> =
                    (0..d * d).map(|_| rng.next_normal() as f32).collect();
                let mut g_blk = g_ref.clone();
                for s in 0..k {
                    syrk_update(&mut g_ref, &rows[s * d..(s + 1) * d], 1.0);
                }
                syrk_rankk_upper_scalar(&mut g_blk, d, &rows);
                assert_eq!(g_ref, g_blk, "blocked kernel diverges at d={d} k={k}");
            }
        }
    }

    #[test]
    fn simd_dispatch_matches_scalar() {
        // With `--features simd` this pins AVX2 == scalar bitwise; without
        // it the dispatcher must be a transparent alias of the scalar path.
        let mut rng = Pcg64::new(78);
        for &d in &[8usize, 16, 24, 31, 64, 128] {
            let k = 16;
            let rows: Vec<f32> = (0..k * d)
                .map(|i| if i % 11 == 0 { 0.0 } else { rng.next_normal() as f32 })
                .collect();
            let g0: Vec<f32> = (0..d * d).map(|_| rng.next_normal() as f32).collect();
            let mut g_scalar = g0.clone();
            let mut g_dispatch = g0;
            syrk_rankk_upper_scalar(&mut g_scalar, d, &rows);
            syrk_rankk_upper(&mut g_dispatch, d, &rows);
            assert_eq!(g_scalar, g_dispatch, "dispatch diverges at d={d}");
        }
    }

    #[test]
    fn gramian_unchanged_by_blocked_kernel() {
        // The blocked gramian must produce the exact bits of the
        // row-at-a-time formulation it replaced.
        let mut rng = Pcg64::new(79);
        for &(rows, d) in &[(1usize, 4usize), (17, 6), (40, 16), (100, 33)] {
            let a = Mat::randn(rows, d, 1.0, &mut rng);
            let g = a.gramian();
            let mut g_ref = vec![0.0f32; d * d];
            for r in 0..rows {
                syrk_update(&mut g_ref, a.row(r), 1.0);
            }
            symmetrize_upper(&mut g_ref, d);
            assert_eq!(g.data, g_ref, "gramian diverges at {rows}x{d}");
        }
    }

    #[test]
    fn syrk_equals_outer_product_sum() {
        let mut rng = Pcg64::new(6);
        let d = 5;
        let h: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut g = vec![0.0f32; d * d];
        syrk_update(&mut g, &h, 2.0);
        symmetrize_upper(&mut g, d);
        for i in 0..d {
            for j in 0..d {
                assert!((g[i * d + j] - 2.0 * h[i] * h[j]).abs() < 1e-6);
            }
        }
    }
}
