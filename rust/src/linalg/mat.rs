//! Row-major dense matrix and vector helpers.
//!
//! Dimensions in ALX are small (`d ≤ 256`) but the *batch* of systems is
//! large, so the layout favours cache-friendly row access and the hot
//! kernels (`syrk_update`, `matmul_at_a`) are written as blocked loops the
//! compiler auto-vectorizes.

/// Convenience alias for an owned f32 vector.
pub type Vecf = Vec<f32>;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Random matrix with i.i.d. `N(0, scale²)` entries.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_normal() as f32 * scale).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose (out of place).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "inner dims must match");
        let mut out = Mat::zeros(self.rows, other.cols);
        // i-k-j loop order: unit-stride inner loop over `other` rows.
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (k, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Gramian `selfᵀ · self` exploiting symmetry (SYRK).
    pub fn gramian(&self) -> Mat {
        let d = self.cols;
        let mut g = Mat::zeros(d, d);
        for r in 0..self.rows {
            syrk_update(&mut g.data, self.row(r), 1.0);
        }
        // Mirror the upper triangle into the lower.
        for i in 0..d {
            for j in 0..i {
                g.data[i * d + j] = g.data[j * d + i];
            }
        }
        g
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f32]) -> Vecf {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }

    /// Frobenius norm squared.
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max absolute element-wise difference to `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Round every entry to bf16 storage precision in place.
    pub fn round_bf16(&mut self) {
        crate::util::bf16::round_slice(&mut self.data);
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product with 4-way unrolling (auto-vectorizes well).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let o = i * 4;
        acc[0] += a[o] * b[o];
        acc[1] += a[o + 1] * b[o + 1];
        acc[2] += a[o + 2] * b[o + 2];
        acc[3] += a[o + 3] * b[o + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Rank-1 symmetric update of the packed row-major `d×d` buffer:
/// `G[i,j] += w * h[i]*h[j]` for the upper triangle `j >= i`.
#[inline]
pub fn syrk_update(g: &mut [f32], h: &[f32], w: f32) {
    let d = h.len();
    debug_assert_eq!(g.len(), d * d);
    for i in 0..d {
        let hi = w * h[i];
        if hi == 0.0 {
            continue;
        }
        // Zipped-slice form: no bounds checks, auto-vectorizes.
        let grow = &mut g[i * d + i..(i + 1) * d];
        for (gv, &hv) in grow.iter_mut().zip(&h[i..]) {
            *gv += hi * hv;
        }
    }
}

/// Mirror the upper triangle of a packed `d×d` buffer into the lower.
pub fn symmetrize_upper(g: &mut [f32], d: usize) {
    debug_assert_eq!(g.len(), d * d);
    for i in 0..d {
        for j in 0..i {
            g[i * d + j] = g[j * d + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn matmul_matches_hand_example() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(1);
        let a = Mat::randn(5, 5, 1.0, &mut rng);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gramian_matches_explicit_ata() {
        let mut rng = Pcg64::new(2);
        let a = Mat::randn(17, 6, 1.0, &mut rng);
        let g = a.gramian();
        let explicit = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&explicit) < 1e-4);
    }

    #[test]
    fn gramian_is_symmetric() {
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(9, 4, 2.0, &mut rng);
        let g = a.gramian();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(4);
        let a = Mat::randn(3, 7, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(5);
        let a = Mat::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32 + 0.5).collect();
        let xm = Mat::from_rows(4, 1, &x);
        let y = a.matvec(&x);
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..10 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let expect: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }

    #[test]
    fn syrk_equals_outer_product_sum() {
        let mut rng = Pcg64::new(6);
        let d = 5;
        let h: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let mut g = vec![0.0f32; d * d];
        syrk_update(&mut g, &h, 2.0);
        symmetrize_upper(&mut g, d);
        for i in 0..d {
            for j in 0..d {
                assert!((g[i * d + j] - 2.0 * h[i] * h[j]).abs() < 1e-6);
            }
        }
    }
}
