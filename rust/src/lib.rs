//! # ALX-RS — Large Scale Matrix Factorization, reproduced in Rust + JAX + Pallas
//!
//! Reproduction of *"ALX: Large Scale Matrix Factorization on TPUs"*
//! (Mehta, Rendle, Krichene, Zhang, 2021). The paper's distributed
//! Alternating-Least-Squares architecture — sharded embedding tables,
//! `sharded_gather` / batched solve / `sharded_scatter` over a TPU torus,
//! dense batching, mixed bf16/f32 precision, and a CG-first solver stack —
//! is implemented as a three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: sharded tables, simulated-torus
//!   collectives, dense batcher, epoch scheduler, evaluation and the CLI.
//! * **L2 (python/compile/model.py)** — the per-batch ALS compute graph in
//!   JAX, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the sufficient
//!   statistics and gramian hot-spots, lowered inside the L2 graph.
//!
//! At runtime the [`runtime`] module loads the AOT artifacts through PJRT;
//! python is never on the training path.
//!
//! The public job API is session-based: a [`data::DataSource`] acquires
//! the matrix and a [`coordinator::TrainSession`] drives the lifecycle
//! step by step, with checkpoint/resume and per-epoch hooks
//! (`eval_every`, `checkpoint_every`, early stopping). See the crate
//! README and `examples/quickstart.rs`.

// Numeric-kernel style: indexed loops deliberately mirror the paper's
// algebra, and the hot-path entry points thread many explicit knobs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod als;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod densebatch;
pub mod dist;
pub mod eval;
pub mod harness;
pub mod linalg;
pub mod runtime;
pub mod serving;
pub mod sharding;
pub mod sparse;
pub mod topo;
pub mod util;
pub mod verify;
pub mod webgraph;

/// Most commonly used types, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::als::{
        EngineKind, EpochStats, PrecisionPolicy, SolverKind, TrainConfig, Trainer,
    };
    pub use crate::collectives::{Collectives, CommSnapshot, TableId};
    pub use crate::config::AlxConfig;
    pub use crate::coordinator::{
        CheckpointEvery, Coordinator, EarlyStopOnPlateau, EarlyStopOnRecall, EpochHook,
        EvalEvery, HookAction, RunReport, TrainSession,
    };
    pub use crate::data::{
        DataSource, Dataset, DatasetInfo, EdgeListSource, InMemorySource, IngestReport,
        StreamingSource, WebGraphSource,
    };
    pub use crate::densebatch::{DenseBatch, DenseBatcher};
    pub use crate::dist::{DistConfig, DistMode, DistTopology, TcpCollectives, Worker};
    pub use crate::eval::{recall_at_k, EvalConfig, RecallReport};
    pub use crate::linalg::Mat;
    pub use crate::serving::{serve, Client, ServeConfig, ServeModel, ServerHandle, TopKRequest};
    pub use crate::sharding::{ShardedTable, Storage, TableStorage};
    pub use crate::sparse::{Csr, CsrStorage, MmapBank, RowMatrix, ShardedCsr, SpillStats};
    pub use crate::topo::Topology;
    pub use crate::webgraph::{Variant, VariantSpec};
}
